//! Learned vs random rotation parameters (paper §5.5 / Table 3 axis,
//! open question §10.3): refine quaternion banks on a correlated
//! calibration set and compare held-out reconstruction MSE.
//!
//! Run: `cargo run --release --example learned_rotations`

use isoquant::quant::learn::{learn, LearnOptions};
use isoquant::quant::{mse, Stage1, Stage1Config, Variant};
use isoquant::util::bench::Table;
use isoquant::util::prng::Rng;

/// Correlated data: per-4-block energy concentrated on a dominant
/// direction — the regime where the rotation choice matters (paper
/// eq. 40's worst case for coordinate-wise quantization).
fn correlated(rng: &mut Rng, n: usize, d: usize, rho: f32) -> Vec<f32> {
    let mut x = vec![0.0f32; n * d];
    for r in 0..n {
        for b in 0..d / 4 {
            let base = rng.gaussian() as f32;
            let eps = 1.0 - rho;
            x[r * d + b * 4] = base;
            x[r * d + b * 4 + 1] = rho * base + eps * rng.gaussian() as f32;
            x[r * d + b * 4 + 2] = rho * 0.8 * base + eps * rng.gaussian() as f32;
            x[r * d + b * 4 + 3] = rho * 0.6 * base + eps * rng.gaussian() as f32;
        }
    }
    x
}

fn main() {
    let d = 64;
    let n_train = 256;
    let n_test = 512;
    let mut rng = Rng::new(11);

    println!("learned vs random rotations (b=2, correlated calibration data)\n");
    let mut table = Table::new(&[
        "variant",
        "corr",
        "random MSE",
        "learned MSE",
        "improvement",
        "train Δ",
    ]);
    for variant in [Variant::IsoFull, Variant::IsoFast, Variant::Planar2D] {
        for rho in [0.5f32, 0.9] {
            let train = correlated(&mut rng, n_train, d, rho);
            let test = correlated(&mut rng, n_test, d, rho);
            let cfg = Stage1Config::new(variant, d, 2);
            let opts = LearnOptions {
                iters: 80,
                ..Default::default()
            };
            let (learned, before, after) = learn(cfg.clone(), &train, n_train, &opts);
            let random = Stage1::new(cfg);
            let mut out = vec![0.0f32; test.len()];
            random.roundtrip_batch(&test, &mut out, n_test);
            let mse_rand = mse(&test, &out);
            learned.roundtrip_batch(&test, &mut out, n_test);
            let mse_learn = mse(&test, &out);
            table.row(vec![
                variant.name().to_string(),
                format!("{rho:.1}"),
                format!("{mse_rand:.5}"),
                format!("{mse_learn:.5}"),
                format!("{:+.1}%", 100.0 * (1.0 - mse_learn / mse_rand)),
                format!("{before:.5} → {after:.5}"),
            ]);
        }
    }
    table.print();
    println!(
        "\n(held-out improvement confirms §5.5's learned parameterization is usable;\n\
         on isotropic data learned ≈ random, as the paper conjectures in §10.3)"
    );
}
