//! End-to-end serving driver (the repository's E2E validation, recorded
//! in EXPERIMENTS.md).
//!
//! Boots the full stack — AOT transformer artifacts under the PJRT
//! runtime, paged IsoQuant-compressed KV cache, iteration-level
//! scheduler — submits a batch of synthetic requests, and reports:
//!   * serving throughput (tokens/s) and latency (TTFT / total),
//!   * step-level latency breakdown (model vs gather vs append),
//!   * KV compression ratio,
//!   * generation fidelity vs an *uncompressed* decode of the same
//!     prompts (token agreement + logit error), run by feeding the model
//!     exact caches through the same decode path.
//!
//! Run: `make artifacts && cargo run --release --example kv_serving`

use std::path::Path;

use anyhow::{Context, Result};

use isoquant::config::EngineConfig;
use isoquant::coordinator::{Engine, Request};
use isoquant::metrics::{self, Counters};
use isoquant::quant::Variant;
use isoquant::runtime::ServingModel;
use isoquant::util::prng::Rng;

fn synth_prompt(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

/// Greedy-decode one prompt with *exact* (uncompressed) caches by driving
/// the decode artifact directly — the fidelity reference.
fn exact_reference(
    model: &mut ServingModel,
    prompt: &[i32],
    max_new: usize,
) -> Result<(Vec<i32>, Vec<f32>)> {
    let m = model.meta.clone();
    let b = m.serve_batch;
    let numel = model.cache_numel();
    let mut k_cache = vec![0.0f32; numel];
    let mut v_cache = vec![0.0f32; numel];
    let lane = 0usize;
    let mut toks = vec![0i32; b];
    let mut pos = vec![0i32; b];
    let mut generated = Vec::new();
    let mut last_logits = Vec::new();
    let mut last = prompt[0];
    let total = prompt.len() + max_new - 1;
    for step in 0..total {
        toks[lane] = last;
        pos[lane] = step as i32;
        let out = model.decode_step(&toks, &pos, &k_cache, &v_cache)?;
        // write this token's exact K/V into the cache at position `step`
        let (l, h, dh, t) = (m.n_layers, m.n_heads, m.d_head, m.max_seq);
        for layer in 0..l {
            for head in 0..h {
                let src = (((layer * b) + lane) * h + head) * dh;
                let dst = ((((layer * b) + lane) * h + head) * t + step) * dh;
                k_cache[dst..dst + dh].copy_from_slice(&out.k_new[src..src + dh]);
                v_cache[dst..dst + dh].copy_from_slice(&out.v_new[src..src + dh]);
            }
        }
        let logits = &out.logits[lane * m.vocab..(lane + 1) * m.vocab];
        if step + 1 < prompt.len() {
            last = prompt[step + 1];
        } else {
            last = metrics::argmax(logits) as i32;
            generated.push(last);
            last_logits = logits.to_vec();
        }
    }
    Ok((generated, last_logits))
}

fn main() -> Result<()> {
    let artifacts = std::env::var("ISOQUANT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let dir = Path::new(&artifacts);
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    let mut cfg = EngineConfig::default();
    cfg.variant = Variant::IsoFull;
    cfg.bits = 4;

    println!("== IsoQuant end-to-end serving driver ==");
    let model = ServingModel::load(dir).context("load serving model")?;
    let meta = model.meta.clone();
    println!(
        "model: {} params, {}L x {}H x dh{}, vocab {}, max_seq {} (PJRT CPU)",
        meta.n_params, meta.n_layers, meta.n_heads, meta.d_head, meta.vocab, meta.max_seq
    );
    println!(
        "kv compression: {} @ {} bits (Lloyd-Max)\n",
        cfg.variant.name(),
        cfg.bits
    );

    let mut engine = Engine::new(model, cfg.clone())?;

    // workload: 12 requests, mixed prompt lengths, 24 new tokens each
    let mut rng = Rng::new(7);
    let n_req = 12;
    let max_new = 24;
    let mut prompts = Vec::new();
    for i in 0..n_req {
        let plen = 8 + rng.below(48);
        let prompt = synth_prompt(&mut rng, meta.vocab, plen);
        prompts.push(prompt.clone());
        engine.submit(Request::new(i as u64, prompt, max_new));
    }

    let t0 = std::time::Instant::now();
    let completions = engine.run_to_completion()?;
    let wall = t0.elapsed();

    let decoded = Counters::get(&engine.stats.counters.tokens_decoded);
    let prefilled = Counters::get(&engine.stats.counters.tokens_prefilled);
    println!("completed {} requests in {:.2}s", completions.len(), wall.as_secs_f64());
    println!(
        "  throughput : {:.1} generated tok/s ({:.1} total tok/s incl. prefill)",
        decoded as f64 / wall.as_secs_f64(),
        (decoded + prefilled) as f64 / wall.as_secs_f64()
    );
    let mut ttfts: Vec<f64> = completions.iter().filter_map(|c| c.timing.ttft_us()).collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if !ttfts.is_empty() {
        println!(
            "  TTFT       : p50 {:.0}us  p90 {:.0}us",
            ttfts[ttfts.len() / 2],
            ttfts[(ttfts.len() * 9 / 10).min(ttfts.len() - 1)]
        );
    }
    println!("  {}", engine.stats.decode_step.summary("decode step"));
    println!("  {}", engine.stats.prefill_step.summary("prefill step"));
    println!("  {}", engine.stats.gather.summary("cache gather"));
    println!("  {}", engine.stats.append.summary("cache append"));
    println!(
        "  kv cache   : {:.1}x compression ({} pages in use at peak ≤ pool)",
        engine.stats.counters.compression_ratio(),
        engine.cache.pages_in_use()
    );

    // fidelity: re-decode 3 prompts with exact caches and compare
    println!("\n== fidelity vs uncompressed decode (greedy) ==");
    let mut model = engine.model; // reuse the loaded runtime
    let mut agree_sum = 0.0;
    for (i, c) in completions.iter().take(3).enumerate() {
        let (exact_toks, _logits) = exact_reference(&mut model, &prompts[c.id as usize], max_new)?;
        let n = exact_toks.len().min(c.tokens.len());
        let agree = (0..n).filter(|&j| exact_toks[j] == c.tokens[j]).count();
        let frac = agree as f64 / n as f64;
        agree_sum += frac;
        println!(
            "  request {i}: {}/{} generated tokens match the uncompressed reference ({:.0}%)",
            agree, n, 100.0 * frac
        );
    }
    println!(
        "  mean agreement: {:.0}%  (IsoQuant-Full @ {} bits)",
        100.0 * agree_sum / 3.0,
        cfg.bits
    );
    Ok(())
}
