//! Attention-logit preservation under KV compression (§9.6 items 1–2):
//! for every variant × bit width, compress K/V with stage-1 (optionally
//! plus QJL stage-2 inner-product correction) and measure
//!   * attention logit MSE and max error,
//!   * attention output relative L2 / cosine,
//!   * top-1 attention-target agreement,
//! against exact attention.  Also cross-checks the native attention
//! implementation against the AOT `attention_scorer` HLO when artifacts
//! are present.
//!
//! Run: `cargo run --release --example attention_fidelity`

use isoquant::attention;
use isoquant::metrics;
use isoquant::quant::residual::TwoStage;
use isoquant::quant::{Stage1, Stage1Config, Variant};
use isoquant::runtime::{HostTensor, Runtime};
use isoquant::util::bench::Table;
use isoquant::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let (h, t, dh) = (4usize, 128usize, 64usize);
    let mut rng = Rng::new(3);
    let q = rng.gaussian_vec_f32(h * dh);
    let k = rng.gaussian_vec_f32(h * t * dh);
    let v = rng.gaussian_vec_f32(h * t * dh);

    println!("attention fidelity: H={h}, T={t}, d_head={dh} (exact vs compressed K/V)\n");
    let mut table = Table::new(&[
        "variant",
        "bits",
        "logit MSE",
        "max|Δlogit|",
        "out rel L2",
        "out cosine",
        "top1 agree",
    ]);
    for variant in [
        Variant::Rotor3D,
        Variant::IsoFull,
        Variant::IsoFast,
        Variant::Planar2D,
        Variant::Grouped8D,
    ] {
        for bits in [2u8, 3, 4] {
            let s = Stage1::new(Stage1Config::new(variant, dh, bits));
            // measure through the packed batch path (encode_batch →
            // decode_batch): the exact bytes the serving KV cache stores
            let rep = attention::fidelity_compressed(&s, &q, &k, &v, h, t, dh);
            table.row(vec![
                variant.name().to_string(),
                bits.to_string(),
                format!("{:.5}", rep.logit_mse),
                format!("{:.4}", rep.logit_max_err),
                format!("{:.4}", rep.out_rel_l2),
                format!("{:.4}", rep.out_cosine),
                format!("{:.2}", rep.top1_attention),
            ]);
        }
    }
    table.print();

    // stage-2 residual correction on the *logits* (inner products):
    // ⟨q, k⟩ ≈ ⟨q, k̂⟩ + QJL(q, k - k̂)  (paper §8)
    println!("\nQJL residual correction of attention logits (IsoQuant-Full, m=256):\n");
    let mut table = Table::new(&["bits", "logit MSE (stage-1)", "logit MSE (+stage-2)"]);
    for bits in [2u8, 3, 4] {
        let s = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, bits));
        let two = TwoStage::new(s.clone(), 256, 0xFEED);
        let scale = 1.0 / (dh as f32).sqrt();
        let (mut e1, mut e2) = (0.0f64, 0.0f64);
        let mut count = 0usize;
        for hh in 0..h {
            let qh = &q[hh * dh..(hh + 1) * dh];
            for tt in 0..t {
                let kv = &k[hh * t * dh + tt * dh..][..dh];
                let truth: f32 = qh.iter().zip(kv).map(|(&a, &b)| a * b).sum::<f32>() * scale;
                let code = two.encode(kv);
                let mut k_hat = vec![0.0f32; dh];
                two.stage1.decode(&code.stage1_bytes, &mut k_hat);
                let base: f32 =
                    qh.iter().zip(&k_hat).map(|(&a, &b)| a * b).sum::<f32>() * scale;
                let corrected = two.inner_product(qh, &code) * scale;
                e1 += ((base - truth) as f64).powi(2);
                e2 += ((corrected - truth) as f64).powi(2);
                count += 1;
            }
        }
        table.row(vec![
            bits.to_string(),
            format!("{:.6}", e1 / count as f64),
            format!("{:.6}", e2 / count as f64),
        ]);
    }
    table.print();

    // cross-check native attention vs the AOT scorer HLO, if built
    let dir = isoquant::runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::load(&dir)?;
        let spec = rt.manifest.artifact("attention_scorer")?.clone();
        let b = spec.inputs[0].shape[0];
        let t_a = spec.inputs[1].shape[2];
        let h_a = spec.inputs[0].shape[1];
        let dh_a = spec.inputs[0].shape[2];
        let mut rng = Rng::new(9);
        let qb = rng.gaussian_vec_f32(b * h_a * dh_a);
        let kb = rng.gaussian_vec_f32(b * h_a * t_a * dh_a);
        let vb = rng.gaussian_vec_f32(b * h_a * t_a * dh_a);
        let outs = rt.run_f32(
            "attention_scorer",
            &[
                HostTensor::F32(qb.clone(), vec![b, h_a, dh_a]),
                HostTensor::F32(kb.clone(), vec![b, h_a, t_a, dh_a]),
                HostTensor::F32(vb.clone(), vec![b, h_a, t_a, dh_a]),
            ],
        )?;
        // native, batched by slicing
        let mut worst = 0.0f64;
        for bb in 0..b {
            let (out, logits) = attention::attend(
                &qb[bb * h_a * dh_a..(bb + 1) * h_a * dh_a],
                &kb[bb * h_a * t_a * dh_a..(bb + 1) * h_a * t_a * dh_a],
                &vb[bb * h_a * t_a * dh_a..(bb + 1) * h_a * t_a * dh_a],
                h_a,
                t_a,
                dh_a,
            );
            for (i, &x) in out.iter().enumerate() {
                worst = worst.max((x as f64 - outs[0][bb * h_a * dh_a + i] as f64).abs());
            }
            for (i, &x) in logits.iter().enumerate() {
                worst = worst.max((x as f64 - outs[1][bb * h_a * t_a + i] as f64).abs());
            }
        }
        println!("\nnative attention vs AOT attention_scorer HLO: max|Δ| = {worst:.2e}");
        assert!(worst < 1e-4, "native and HLO attention disagree");
        let _ = metrics::cosine(&qb, &qb); // keep metrics linked in this example
    } else {
        println!("\n(artifacts not built — skipping native-vs-HLO attention cross-check)");
    }
    Ok(())
}
