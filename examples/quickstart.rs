//! Quickstart: compress and reconstruct a batch of vectors with every
//! IsoQuant operating point, printing MSE, compression ratio, and the
//! latency of the fused stage-1 path.
//!
//! Run: `cargo run --release --example quickstart`

use isoquant::quant::{mse, Stage1, Stage1Config, Variant};
use isoquant::util::bench::{Bencher, Table};
use isoquant::util::prng::Rng;

fn main() {
    let d = 128; // a common LLM head dimension (paper's primary setting)
    let n = 8192; // the paper's benchmark batch size
    let bits = 4;

    // synthetic vectors, as in the paper's §9 protocol
    let mut rng = Rng::new(42);
    let x = rng.gaussian_vec_f32(n * d);
    let power = x.iter().map(|&v| (v * v) as f64).sum::<f64>() / x.len() as f64;

    println!("IsoQuant quickstart: d={d}, batch={n}, bits={bits}, f32\n");
    let mut table = Table::new(&[
        "variant",
        "MSE",
        "rel MSE",
        "bytes/vec",
        "us/batch",
        "speedup vs rotor",
    ]);

    let bencher = Bencher::quick();
    let mut rotor_us = f64::NAN;
    for variant in [
        Variant::Rotor3D, // the RotorQuant baseline first, as reference
        Variant::IsoFull,
        Variant::IsoFast,
        Variant::Planar2D,
    ] {
        let stage = Stage1::new(Stage1Config::new(variant, d, bits));
        let mut out = vec![0.0f32; n * d];
        let r = bencher.run(variant.name(), || {
            stage.roundtrip_batch(&x, &mut out, n);
        });
        stage.roundtrip_batch(&x, &mut out, n);
        let e = mse(&x, &out);
        if variant == Variant::Rotor3D {
            rotor_us = r.median_us();
        }
        table.row(vec![
            variant.name().to_string(),
            format!("{e:.6}"),
            format!("{:.2}%", 100.0 * e / power),
            format!("{}", stage.encoded_len()),
            format!("{:.1}", r.median_us()),
            format!("{:.2}x", rotor_us / r.median_us()),
        ]);
    }
    table.print();

    // encode/decode roundtrip — what the KV cache actually stores
    let stage = Stage1::new(Stage1Config::new(Variant::IsoFull, d, bits));
    let one = &x[..d];
    let mut encoded = Vec::new();
    stage.encode(one, &mut encoded);
    let mut decoded = vec![0.0f32; d];
    stage.decode(&encoded, &mut decoded);
    println!(
        "\nsingle vector: {} B -> {} B ({}x compression), rel L2 err {:.3}",
        d * 4,
        encoded.len(),
        d * 4 / encoded.len(),
        isoquant::metrics::rel_l2(one, &decoded)
    );
}
