//! Minimal, offline-vendored subset of the `anyhow` API.
//!
//! The real crate is not vendorable in this container, so this shim
//! re-implements exactly the surface the workspace uses:
//!
//! * [`Error`] — a boxed message chain with `Display`/`Debug`,
//! * [`Result`] — `Result<T, Error>` with a defaulted error type,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion (what makes `?` work on io/parse errors) coherent with the
//! reflexive `From<Error> for Error` used by `?` on `Result<T, Error>`.

use std::fmt;

/// An error message plus the chain of contexts wrapped around it
/// (most-recent context first, original cause last).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = c.source.as_deref();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // flatten the std error chain into our message chain
        let mut chain: Vec<String> = Vec::new();
        chain.push(err.to_string());
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            out = Some(match out {
                None => Error { msg, source: None },
                Some(inner) => Error {
                    msg,
                    source: Some(Box::new(inner)),
                },
            });
        }
        out.expect("non-empty chain")
    }
}

/// Attach context to failure values (`Result` errors and `None`s).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`]-constructed error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("base failure {}", 42)
    }

    #[test]
    fn bail_and_context_chain() {
        let err = fails().context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer");
        assert_eq!(err.root_cause(), "base failure 42");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("base failure 42"));
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            let v: i32 = s.parse()?;
            Ok(v)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
        let some = Some(3u8).with_context(|| "unused").unwrap();
        assert_eq!(some, 3);
    }

    #[test]
    fn inline_format_capture() {
        let x = 5;
        let e = anyhow!("value {x}");
        assert_eq!(e.to_string(), "value 5");
    }
}
