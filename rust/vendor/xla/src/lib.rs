//! Offline stub of the `xla` (xla-rs / PJRT) binding surface this
//! workspace uses.
//!
//! The real crate links the XLA runtime, which is not available in this
//! container.  The stub keeps the crate compiling and the *host-side*
//! pieces fully functional:
//!
//! * [`Literal`] round-trips typed host data (used by unit tests and by
//!   `runtime::exec::HostTensor`),
//! * every PJRT entry point ([`PjRtClient::cpu`], compilation, execution,
//!   buffer staging) returns a clean [`Error`] explaining that the build
//!   has no device runtime.
//!
//! Integration tests skip when AOT artifacts are absent, so the serving
//! stack degrades exactly like a checkout without `make artifacts`.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime unavailable (built against the vendored stub in rust/vendor/xla)"
    ))
}

/// Element dtypes used by this workspace's artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host types a [`Literal`] can be read back into.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_ne(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne(bytes: [u8; 4]) -> Self {
        f32::from_ne_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne(bytes: [u8; 4]) -> Self {
        i32::from_ne_bytes(bytes)
    }
}

/// A host-resident typed array (or tuple of arrays).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = shape.iter().product();
        if numel * 4 != data.len() {
            return Err(Error(format!(
                "literal shape {shape:?} needs {} bytes, got {}",
                numel * 4,
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            shape: shape.to_vec(),
            data: data.to_vec(),
            tuple: None,
        })
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            ty: ElementType::F32,
            shape: Vec::new(),
            data: Vec::new(),
            tuple: Some(parts),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal dtype {:?} read as {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_ne([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        self.tuple
            .clone()
            .ok_or_else(|| Error("literal is not a tuple".into()))
    }
}

/// Parsed HLO module (stub: never constructible without the runtime).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parse HLO text {path}")))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_literal"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }

    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let xs = [1.0f32, -2.0, 3.5];
        let bytes: Vec<u8> = xs.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn pjrt_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
