//! Serving metrics: latency percentiles, throughput counters, and the
//! reconstruction-quality measures reported by the experiments.
//!
//! Counter structs here are *field-tabled*: the macro invocations below
//! generate `Clone`/`PartialEq`/[`ShareStats::fields`] from one list,
//! so stats JSON, the `/metrics` exposition, and the summary line all
//! iterate the same table — a newly added counter cannot be silently
//! dropped from any surface (and a test asserts exactly that).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

pub mod histogram;
pub mod prometheus;

pub use histogram::{Histogram, HistogramSnapshot};

/// Reservoir-free latency recorder: keeps every sample (serving runs here
/// are bounded) and reports percentiles.
#[derive(Default, Debug, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p / 100.0).round() as usize;
        s[idx]
    }

    /// Batch percentile lookup with a single sort (the per-call sort in
    /// [`LatencyRecorder::percentile`] is fine for one-shot summaries,
    /// not for a stats endpoint asking for p50/p95/p99 of the same
    /// recorder).  NaN per entry when empty, like `percentile`.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples_us.is_empty() {
            return vec![f64::NAN; ps.len()];
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter()
            .map(|&p| s[((s.len() - 1) as f64 * p / 100.0).round() as usize])
            .collect()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_us.is_empty() {
            return f64::NAN;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
        )
    }
}

/// Shared monotonically increasing counters (engine-wide).
#[derive(Default, Debug)]
pub struct Counters {
    pub requests: AtomicU64,
    pub tokens_prefilled: AtomicU64,
    pub tokens_decoded: AtomicU64,
    pub pages_allocated: AtomicU64,
    pub pages_freed: AtomicU64,
    pub bytes_compressed: AtomicU64,
    pub bytes_uncompressed: AtomicU64,
}

/// The single field table for [`Counters`].  Adding a field to the
/// struct without adding it here fails to compile (`counters_fields`
/// would not read it, but the completeness test in
/// `tests/observability.rs` compares against `std::mem::size_of`), and
/// every rendering surface iterates [`Counters::fields`] — so a new
/// counter automatically reaches stats JSON and `/metrics`.
macro_rules! for_each_counter {
    ($m:ident) => {
        $m! {
            requests,
            tokens_prefilled,
            tokens_decoded,
            pages_allocated,
            pages_freed,
            bytes_compressed,
            bytes_uncompressed,
        }
    };
}

macro_rules! counters_fields {
    ($($f:ident,)*) => {
        impl Counters {
            /// How many fields the table carries (compared against the
            /// struct size in tests, so the table cannot fall behind).
            pub const FIELD_COUNT: usize = [$(stringify!($f),)*].len();

            /// Every counter as a `(name, value)` pair — the one list
            /// stats JSON and the `/metrics` exposition render from.
            pub fn fields(&self) -> Vec<(&'static str, u64)> {
                vec![$((stringify!($f), self.$f.load(Ordering::Relaxed)),)*]
            }
        }
    };
}

for_each_counter!(counters_fields);

/// Prefix-sharing accounting kept by the cache manager (single-writer,
/// so plain integers): index hits, copy-on-write activity, and the bytes
/// sharing kept off the allocator.  The two gather-dedup counters are
/// atomics because gathers take `&self` and run on the worker pool; all
/// admission-path counters stay plain integers.
#[derive(Default, Debug)]
pub struct ShareStats {
    /// sealed pages adopted from the prefix index at admission
    pub prefix_hit_pages: u64,
    /// cached tokens those adoptions covered (prefill work avoided)
    pub prefix_hit_tokens: u64,
    /// shared tails copied before an append (CoW)
    pub cow_copies: u64,
    /// page bytes served from shared pages instead of fresh allocations
    pub bytes_deduped: u64,
    /// radix index only: prompt token slots copied out of indexed pages
    /// instead of re-encoded (sub-page slot-range reuse — two prompts
    /// sharing 15 of 16 tail tokens share those 15 slots' encode work)
    pub slots_copied: u64,
    /// radix index only: partial-page adoptions assembled by slot-range
    /// copy (each saved re-encoding `slots_copied / tail_copies` slots
    /// on average)
    pub tail_copies: u64,
    /// sealed prompt pages published to the index
    pub pages_published: u64,
    /// zero-ref index entries evicted under pool pressure (with a
    /// persistent store attached these are RAM→disk demotions: the
    /// content stays resolvable cold)
    pub pages_evicted: u64,
    /// pages handed to the persistent store's write-behind spill
    /// thread at zero-ref park time
    pub pages_spilled: u64,
    /// on-disk records adopted into the cold directory at boot
    pub pages_rehydrated: u64,
    /// cold pages promoted from disk into fresh resident pages on a
    /// prefix-index miss (re-encode avoided)
    pub pages_promoted: u64,
    /// cross-lane gather dedup: duplicate (page, slot-range) runs served
    /// by memcpy from an already-decoded leader instead of re-decoded
    pub strips_deduped: AtomicU64,
    /// decode output bytes those skipped runs would have produced
    /// (K and V both counted)
    pub bytes_saved: AtomicU64,
    /// requests dropped mid-flight because the client disconnected or
    /// explicitly cancelled (lane + pages freed the same engine step)
    pub requests_cancelled: u64,
    /// requests finished with `finish: "timeout"` (per-request
    /// `deadline_ms` or the `[server] request_timeout_ms` default)
    pub requests_timed_out: u64,
    /// requests shed at admission because the bounded queue
    /// (`[server] max_queue`) was full
    pub requests_shed: u64,
    /// 1 once the persistent store has tripped into degraded mode
    /// (persistence disabled after repeated I/O failures; serving
    /// continues without it)
    pub store_degraded: u64,
    /// store records the segment compactor rewrote into the active
    /// segment before their old segment retired (mirrored from
    /// `StoreStats::records_compacted`)
    pub records_compacted: u64,
    /// segments that had at least one live record rescued before
    /// retirement (mirrored from `StoreStats::segments_compacted`)
    pub segments_compacted: u64,
    /// promoted store records whose original node run began mid-page
    /// (a persisted radix split point) — coverage a v1 warm boot lost
    pub subrun_promotions: u64,
}

/// The single field table for [`ShareStats`]: `plain` fields are
/// single-writer `u64`, `atomic` fields are the gather-path
/// `AtomicU64`s.  `Clone`, `PartialEq`, [`ShareStats::fields`], and
/// (through `fields`) the summary line, stats JSON, and `/metrics`
/// exposition all expand from this one list — add a field to the struct
/// without adding it here and `clone()` fails to compile.
macro_rules! for_each_share_stat {
    ($m:ident) => {
        $m! {
            plain prefix_hit_pages,
            plain prefix_hit_tokens,
            plain cow_copies,
            plain bytes_deduped,
            plain slots_copied,
            plain tail_copies,
            plain pages_published,
            plain pages_evicted,
            plain pages_spilled,
            plain pages_rehydrated,
            plain pages_promoted,
            atomic strips_deduped,
            atomic bytes_saved,
            plain requests_cancelled,
            plain requests_timed_out,
            plain requests_shed,
            plain store_degraded,
            plain records_compacted,
            plain segments_compacted,
            plain subrun_promotions,
        }
    };
}

macro_rules! share_read {
    (plain $self:ident $f:ident) => {
        $self.$f
    };
    (atomic $self:ident $f:ident) => {
        $self.$f.load(Ordering::Relaxed)
    };
}

macro_rules! share_clone_field {
    (plain $self:ident $f:ident) => {
        $self.$f
    };
    (atomic $self:ident $f:ident) => {
        AtomicU64::new($self.$f.load(Ordering::Relaxed))
    };
}

macro_rules! share_impls {
    ($($kind:ident $f:ident,)*) => {
        impl ShareStats {
            /// How many fields the table carries.
            pub const FIELD_COUNT: usize = [$(stringify!($f),)*].len();

            /// Every counter as a `(name, value)` pair, in declaration
            /// order — the one list every rendering surface iterates.
            pub fn fields(&self) -> Vec<(&'static str, u64)> {
                vec![$((stringify!($f), share_read!($kind self $f)),)*]
            }
        }

        impl Clone for ShareStats {
            fn clone(&self) -> Self {
                ShareStats { $($f: share_clone_field!($kind self $f),)* }
            }
        }

        impl PartialEq for ShareStats {
            fn eq(&self, other: &Self) -> bool {
                $(share_read!($kind self $f) == share_read!($kind other $f))&&*
            }
        }
    };
}

for_each_share_stat!(share_impls);

impl Eq for ShareStats {}

impl ShareStats {
    /// One-line human summary, driven by the field table so a new
    /// counter shows up here without a second edit.  Byte counters
    /// render in MB; lifecycle counters are omitted while zero (the
    /// steady-state line stays short); a degraded store is shouted.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for (name, v) in self.fields() {
            match name {
                "store_degraded" => {
                    if v > 0 {
                        s.push_str(" STORE-DEGRADED");
                    }
                }
                "requests_cancelled" | "requests_timed_out" | "requests_shed"
                | "records_compacted" | "segments_compacted" | "subrun_promotions"
                    if v == 0 => {}
                _ => {
                    if !s.is_empty() {
                        s.push(' ');
                    }
                    if name.starts_with("bytes_") {
                        s.push_str(&format!("{name}={:.1}MB", v as f64 / 1e6));
                    } else {
                        s.push_str(&format!("{name}={v}"));
                    }
                }
            }
        }
        s
    }
}

impl Counters {
    pub fn bump(field: &AtomicU64, by: u64) {
        field.fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(field: &AtomicU64) -> u64 {
        field.load(Ordering::Relaxed)
    }

    pub fn compression_ratio(&self) -> f64 {
        let c = self.bytes_compressed.load(Ordering::Relaxed);
        let u = self.bytes_uncompressed.load(Ordering::Relaxed);
        if c == 0 {
            return f64::NAN;
        }
        u as f64 / c as f64
    }
}

// ---------------------------------------------------------------------
// reconstruction / fidelity measures
// ---------------------------------------------------------------------

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    crate::quant::pipeline::mse(a, b)
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += (x as f64) * (y as f64);
        na += (x as f64) * (x as f64);
        nb += (y as f64) * (y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 1.0 } else { 0.0 };
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Relative L2 error ‖a-b‖/‖a‖.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (x as f64).powi(2);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Fraction of positions where the arg-max of `a` equals that of `b`
/// over consecutive chunks of `width` (top-1 agreement of logits).
pub fn top1_agreement(a: &[f32], b: &[f32], width: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(width > 0 && a.len() % width == 0);
    let rows = a.len() / width;
    let mut agree = 0usize;
    for r in 0..rows {
        let am = argmax(&a[r * width..(r + 1) * width]);
        let bm = argmax(&b[r * width..(r + 1) * width]);
        if am == bm {
            agree += 1;
        }
    }
    agree as f64 / rows as f64
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        assert_eq!(r.len(), 100);
        assert!((r.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((r.percentile(99.0) - 98.0).abs() <= 2.0);
        assert!((r.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_nan() {
        let r = LatencyRecorder::new();
        assert!(r.percentile(50.0).is_nan());
        assert!(r.mean().is_nan());
        assert!(r.percentiles(&[50.0, 99.0]).iter().all(|v| v.is_nan()));
    }

    #[test]
    fn batch_percentiles_match_single() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        let batch = r.percentiles(&[50.0, 95.0, 99.0]);
        assert_eq!(batch[0], r.percentile(50.0));
        assert_eq!(batch[1], r.percentile(95.0));
        assert_eq!(batch[2], r.percentile(99.0));
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0; 2], &[0.0; 2]), 1.0);
    }

    #[test]
    fn rel_l2_basics() {
        assert_eq!(rel_l2(&[2.0, 0.0], &[2.0, 0.0]), 0.0);
        assert!((rel_l2(&[2.0, 0.0], &[0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn top1() {
        let a = [1.0, 2.0, 0.0, 5.0, 1.0, 0.0];
        let b = [0.5, 3.0, 0.0, 0.0, 9.0, 0.0];
        // rows of width 3: argmax a = [1, 0], argmax b = [1, 1] → 50%
        assert!((top1_agreement(&a, &b, 3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn counters() {
        let c = Counters::default();
        Counters::bump(&c.bytes_compressed, 100);
        Counters::bump(&c.bytes_uncompressed, 1600);
        assert!((c.compression_ratio() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn field_tables_cover_every_struct_field() {
        // every field is 8 bytes (u64 / AtomicU64): a field added to
        // either struct without a matching table entry changes the
        // struct size but not FIELD_COUNT, and this assert fires
        assert_eq!(std::mem::size_of::<ShareStats>(), 8 * ShareStats::FIELD_COUNT);
        assert_eq!(std::mem::size_of::<Counters>(), 8 * Counters::FIELD_COUNT);
        let s = ShareStats::default();
        assert_eq!(s.fields().len(), ShareStats::FIELD_COUNT);
        assert_eq!(Counters::default().fields().len(), Counters::FIELD_COUNT);
    }

    #[test]
    fn share_stats_clone_eq_via_table() {
        let mut s = ShareStats::default();
        s.prefix_hit_pages = 3;
        s.strips_deduped.store(7, Ordering::Relaxed);
        let c = s.clone();
        assert_eq!(s, c);
        assert_eq!(c.strips_deduped.load(Ordering::Relaxed), 7);
        let mut d = s.clone();
        d.requests_shed = 1;
        assert_ne!(s, d);
    }

    #[test]
    fn share_summary_covers_table_and_gates_lifecycle() {
        let mut s = ShareStats::default();
        let line = s.summary();
        assert!(line.contains("prefix_hit_pages=0"));
        assert!(line.contains("bytes_deduped=0.0MB"), "{line}");
        assert!(!line.contains("requests_shed"), "zero lifecycle hidden");
        assert!(!line.contains("STORE-DEGRADED"));
        s.requests_shed = 2;
        s.store_degraded = 1;
        let line = s.summary();
        assert!(line.contains("requests_shed=2"));
        assert!(line.contains("STORE-DEGRADED"));
    }
}
