//! Serving metrics: latency percentiles, throughput counters, and the
//! reconstruction-quality measures reported by the experiments.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Reservoir-free latency recorder: keeps every sample (serving runs here
/// are bounded) and reports percentiles.
#[derive(Default, Debug, Clone)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p / 100.0).round() as usize;
        s[idx]
    }

    /// Batch percentile lookup with a single sort (the per-call sort in
    /// [`LatencyRecorder::percentile`] is fine for one-shot summaries,
    /// not for a stats endpoint asking for p50/p95/p99 of the same
    /// recorder).  NaN per entry when empty, like `percentile`.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples_us.is_empty() {
            return vec![f64::NAN; ps.len()];
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter()
            .map(|&p| s[((s.len() - 1) as f64 * p / 100.0).round() as usize])
            .collect()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_us.is_empty() {
            return f64::NAN;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.1}us p50={:.1}us p90={:.1}us p99={:.1}us",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
        )
    }
}

/// Shared monotonically increasing counters (engine-wide).
#[derive(Default, Debug)]
pub struct Counters {
    pub requests: AtomicU64,
    pub tokens_prefilled: AtomicU64,
    pub tokens_decoded: AtomicU64,
    pub pages_allocated: AtomicU64,
    pub pages_freed: AtomicU64,
    pub bytes_compressed: AtomicU64,
    pub bytes_uncompressed: AtomicU64,
}

/// Prefix-sharing accounting kept by the cache manager (single-writer,
/// so plain integers): index hits, copy-on-write activity, and the bytes
/// sharing kept off the allocator.  The two gather-dedup counters are
/// atomics because gathers take `&self` and run on the worker pool; all
/// admission-path counters stay plain integers.
#[derive(Default, Debug)]
pub struct ShareStats {
    /// sealed pages adopted from the prefix index at admission
    pub prefix_hit_pages: u64,
    /// cached tokens those adoptions covered (prefill work avoided)
    pub prefix_hit_tokens: u64,
    /// shared tails copied before an append (CoW)
    pub cow_copies: u64,
    /// page bytes served from shared pages instead of fresh allocations
    pub bytes_deduped: u64,
    /// radix index only: prompt token slots copied out of indexed pages
    /// instead of re-encoded (sub-page slot-range reuse — two prompts
    /// sharing 15 of 16 tail tokens share those 15 slots' encode work)
    pub slots_copied: u64,
    /// radix index only: partial-page adoptions assembled by slot-range
    /// copy (each saved re-encoding `slots_copied / tail_copies` slots
    /// on average)
    pub tail_copies: u64,
    /// sealed prompt pages published to the index
    pub pages_published: u64,
    /// zero-ref index entries evicted under pool pressure (with a
    /// persistent store attached these are RAM→disk demotions: the
    /// content stays resolvable cold)
    pub pages_evicted: u64,
    /// pages handed to the persistent store's write-behind spill
    /// thread at zero-ref park time
    pub pages_spilled: u64,
    /// on-disk records adopted into the cold directory at boot
    pub pages_rehydrated: u64,
    /// cold pages promoted from disk into fresh resident pages on a
    /// prefix-index miss (re-encode avoided)
    pub pages_promoted: u64,
    /// cross-lane gather dedup: duplicate (page, slot-range) runs served
    /// by memcpy from an already-decoded leader instead of re-decoded
    pub strips_deduped: AtomicU64,
    /// decode output bytes those skipped runs would have produced
    /// (K and V both counted)
    pub bytes_saved: AtomicU64,
    /// requests dropped mid-flight because the client disconnected or
    /// explicitly cancelled (lane + pages freed the same engine step)
    pub requests_cancelled: u64,
    /// requests finished with `finish: "timeout"` (per-request
    /// `deadline_ms` or the `[server] request_timeout_ms` default)
    pub requests_timed_out: u64,
    /// requests shed at admission because the bounded queue
    /// (`[server] max_queue`) was full
    pub requests_shed: u64,
    /// 1 once the persistent store has tripped into degraded mode
    /// (persistence disabled after repeated I/O failures; serving
    /// continues without it)
    pub store_degraded: u64,
}

impl Clone for ShareStats {
    fn clone(&self) -> Self {
        ShareStats {
            prefix_hit_pages: self.prefix_hit_pages,
            prefix_hit_tokens: self.prefix_hit_tokens,
            cow_copies: self.cow_copies,
            bytes_deduped: self.bytes_deduped,
            slots_copied: self.slots_copied,
            tail_copies: self.tail_copies,
            pages_published: self.pages_published,
            pages_evicted: self.pages_evicted,
            pages_spilled: self.pages_spilled,
            pages_rehydrated: self.pages_rehydrated,
            pages_promoted: self.pages_promoted,
            strips_deduped: AtomicU64::new(self.strips_deduped.load(Ordering::Relaxed)),
            bytes_saved: AtomicU64::new(self.bytes_saved.load(Ordering::Relaxed)),
            requests_cancelled: self.requests_cancelled,
            requests_timed_out: self.requests_timed_out,
            requests_shed: self.requests_shed,
            store_degraded: self.store_degraded,
        }
    }
}

impl PartialEq for ShareStats {
    fn eq(&self, other: &Self) -> bool {
        self.prefix_hit_pages == other.prefix_hit_pages
            && self.prefix_hit_tokens == other.prefix_hit_tokens
            && self.cow_copies == other.cow_copies
            && self.bytes_deduped == other.bytes_deduped
            && self.slots_copied == other.slots_copied
            && self.tail_copies == other.tail_copies
            && self.pages_published == other.pages_published
            && self.pages_evicted == other.pages_evicted
            && self.pages_spilled == other.pages_spilled
            && self.pages_rehydrated == other.pages_rehydrated
            && self.pages_promoted == other.pages_promoted
            && self.strips_deduped.load(Ordering::Relaxed)
                == other.strips_deduped.load(Ordering::Relaxed)
            && self.bytes_saved.load(Ordering::Relaxed)
                == other.bytes_saved.load(Ordering::Relaxed)
            && self.requests_cancelled == other.requests_cancelled
            && self.requests_timed_out == other.requests_timed_out
            && self.requests_shed == other.requests_shed
            && self.store_degraded == other.store_degraded
    }
}

impl Eq for ShareStats {}

impl ShareStats {
    pub fn summary(&self) -> String {
        let mut s = format!(
            "prefix: hits={}p/{}t cow={} dedup={:.1}MB slotcopy={}s/{} published={} \
             evicted={} spill={} rehydrated={} promote={} \
             gather-dedup={}r/{:.1}MB",
            self.prefix_hit_pages,
            self.prefix_hit_tokens,
            self.cow_copies,
            self.bytes_deduped as f64 / 1e6,
            self.slots_copied,
            self.tail_copies,
            self.pages_published,
            self.pages_evicted,
            self.pages_spilled,
            self.pages_rehydrated,
            self.pages_promoted,
            self.strips_deduped.load(Ordering::Relaxed),
            self.bytes_saved.load(Ordering::Relaxed) as f64 / 1e6,
        );
        // lifecycle counters only clutter the line once they fire
        if self.requests_cancelled + self.requests_timed_out + self.requests_shed > 0 {
            s.push_str(&format!(
                " lifecycle: cancelled={} timeout={} shed={}",
                self.requests_cancelled, self.requests_timed_out, self.requests_shed,
            ));
        }
        if self.store_degraded > 0 {
            s.push_str(" STORE-DEGRADED");
        }
        s
    }
}

impl Counters {
    pub fn bump(field: &AtomicU64, by: u64) {
        field.fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(field: &AtomicU64) -> u64 {
        field.load(Ordering::Relaxed)
    }

    pub fn compression_ratio(&self) -> f64 {
        let c = self.bytes_compressed.load(Ordering::Relaxed);
        let u = self.bytes_uncompressed.load(Ordering::Relaxed);
        if c == 0 {
            return f64::NAN;
        }
        u as f64 / c as f64
    }
}

// ---------------------------------------------------------------------
// reconstruction / fidelity measures
// ---------------------------------------------------------------------

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    crate::quant::pipeline::mse(a, b)
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += (x as f64) * (y as f64);
        na += (x as f64) * (x as f64);
        nb += (y as f64) * (y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 1.0 } else { 0.0 };
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Relative L2 error ‖a-b‖/‖a‖.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (x as f64).powi(2);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Fraction of positions where the arg-max of `a` equals that of `b`
/// over consecutive chunks of `width` (top-1 agreement of logits).
pub fn top1_agreement(a: &[f32], b: &[f32], width: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(width > 0 && a.len() % width == 0);
    let rows = a.len() / width;
    let mut agree = 0usize;
    for r in 0..rows {
        let am = argmax(&a[r * width..(r + 1) * width]);
        let bm = argmax(&b[r * width..(r + 1) * width]);
        if am == bm {
            agree += 1;
        }
    }
    agree as f64 / rows as f64
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        assert_eq!(r.len(), 100);
        assert!((r.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((r.percentile(99.0) - 98.0).abs() <= 2.0);
        assert!((r.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_nan() {
        let r = LatencyRecorder::new();
        assert!(r.percentile(50.0).is_nan());
        assert!(r.mean().is_nan());
        assert!(r.percentiles(&[50.0, 99.0]).iter().all(|v| v.is_nan()));
    }

    #[test]
    fn batch_percentiles_match_single() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_us(i as f64);
        }
        let batch = r.percentiles(&[50.0, 95.0, 99.0]);
        assert_eq!(batch[0], r.percentile(50.0));
        assert_eq!(batch[1], r.percentile(95.0));
        assert_eq!(batch[2], r.percentile(99.0));
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0; 2], &[0.0; 2]), 1.0);
    }

    #[test]
    fn rel_l2_basics() {
        assert_eq!(rel_l2(&[2.0, 0.0], &[2.0, 0.0]), 0.0);
        assert!((rel_l2(&[2.0, 0.0], &[0.0, 0.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn top1() {
        let a = [1.0, 2.0, 0.0, 5.0, 1.0, 0.0];
        let b = [0.5, 3.0, 0.0, 0.0, 9.0, 0.0];
        // rows of width 3: argmax a = [1, 0], argmax b = [1, 1] → 50%
        assert!((top1_agreement(&a, &b, 3) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn counters() {
        let c = Counters::default();
        Counters::bump(&c.bytes_compressed, 100);
        Counters::bump(&c.bytes_uncompressed, 1600);
        assert!((c.compression_ratio() - 16.0).abs() < 1e-12);
    }
}
