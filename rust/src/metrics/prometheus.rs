//! Prometheus text-exposition 0.0.4 rendering and linting — hand-rolled
//! in the repo's no-new-deps idiom.
//!
//! The engine loop assembles a [`MetricsSnapshot`] (cloned counters,
//! plain-integer gauges, [`HistogramSnapshot`]s) roughly once a second
//! and renders it with [`render_prometheus`] into a shared string; the
//! reactor serves scrapes from that string, so a `GET /metrics` never
//! touches the engine queue.  [`lint_exposition`] is the validity
//! checker both `tests/observability.rs` and the CI scrape leg run
//! against real output: HELP/TYPE present for every family, sample
//! lines parse, histogram buckets are cumulative-monotone, the `+Inf`
//! bucket equals `_count`, and `_sum` exists.
//!
//! Naming scheme (all under the `isoquant_` prefix):
//!
//! | metric | source |
//! |---|---|
//! | `isoquant_share_<field>_total` | every [`ShareStats`] counter |
//! | `isoquant_store_degraded` | the one ShareStats gauge |
//! | `isoquant_<field>_total` | every [`super::Counters`] counter |
//! | `isoquant_compression_ratio` | append-path bytes ratio |
//! | `isoquant_pages_*` | page-pool occupancy gauges |
//! | `isoquant_store_*` | persistent-store health |
//! | `isoquant_*_seconds` | latency histograms (TTFT, inter-token, …) |
//! | `isoquant_engine_phase_seconds{phase=...}` | step profiler |

use std::collections::BTreeMap;

use super::histogram::{bucket_bounds_us, HistogramSnapshot, BUCKETS};
use super::ShareStats;

/// Page-pool and store occupancy gauges, read off the cache manager at
/// snapshot time.
#[derive(Debug, Clone, Default)]
pub struct PageGauges {
    /// pages owned by live (in-flight) sequences
    pub live: u64,
    /// zero-ref sealed pages parked in the prefix index (warm)
    pub cached: u64,
    /// pool capacity in pages
    pub capacity: u64,
    /// high-water mark of resident pages
    pub high_water: u64,
    /// resident pages referenced by more than one sequence
    pub shared: u64,
    /// resident pages referenced by exactly one sequence
    pub exclusive: u64,
    /// cold directory entries resolvable from the persistent store
    pub cold: u64,
    /// bytes the persistent store holds on disk
    pub store_disk_bytes: u64,
    /// 1 when a persistent store is attached
    pub store_attached: u64,
}

/// Everything a `/metrics` render needs, detached from the engine so
/// the render (and the scrape serving it) can happen on another thread.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub share: ShareStats,
    /// `Counters::fields()` at snapshot time
    pub counters: Vec<(&'static str, u64)>,
    /// `Counters::compression_ratio()` (NaN until data flows; rendered 0)
    pub compression_ratio: f64,
    pub pages: PageGauges,
    /// reactor-side disconnects due to per-connection buffer overflow
    pub conn_overflow_disconnects: u64,
    /// latency histograms: (full metric name, snapshot); values are
    /// recorded in µs and rendered in seconds
    pub hists: Vec<(&'static str, HistogramSnapshot)>,
    /// step-profiler phases: (phase label, snapshot); empty unless
    /// `[engine] profile = on`
    pub phases: Vec<(&'static str, HistogramSnapshot)>,
}

impl Default for MetricsSnapshot {
    fn default() -> Self {
        MetricsSnapshot {
            share: ShareStats::default(),
            counters: super::Counters::default().fields(),
            compression_ratio: f64::NAN,
            pages: PageGauges::default(),
            conn_overflow_disconnects: 0,
            hists: Vec::new(),
            phases: Vec::new(),
        }
    }
}

fn push_counter(out: &mut String, name: &str, help: &str, v: u64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
    ));
}

fn push_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
    ));
}

/// One histogram series body: cumulative `_bucket` lines (le in
/// seconds), `_sum`, `_count`.  `label` adds a fixed label pair (the
/// profiler's `phase="..."`) ahead of `le`.
fn push_hist_series(
    out: &mut String,
    name: &str,
    label: Option<(&str, &str)>,
    h: &HistogramSnapshot,
) {
    let bounds = bucket_bounds_us();
    let mut cum = 0u64;
    for (i, &c) in h.counts.iter().enumerate() {
        cum += c;
        let le = if i < BUCKETS - 1 {
            format!("{}", bounds[i] / 1e6)
        } else {
            "+Inf".to_string()
        };
        match label {
            Some((k, v)) => {
                out.push_str(&format!("{name}_bucket{{{k}=\"{v}\",le=\"{le}\"}} {cum}\n"))
            }
            None => out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n")),
        }
    }
    let plain = match label {
        Some((k, v)) => format!("{{{k}=\"{v}\"}}"),
        None => String::new(),
    };
    out.push_str(&format!("{name}_sum{plain} {}\n", h.sum_us as f64 / 1e6));
    out.push_str(&format!("{name}_count{plain} {cum}\n"));
}

fn push_hist(out: &mut String, name: &str, help: &str, h: &HistogramSnapshot) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} histogram\n"
    ));
    push_hist_series(out, name, None, h);
}

/// Render a snapshot as Prometheus text exposition 0.0.4.
pub fn render_prometheus(s: &MetricsSnapshot) -> String {
    let mut out = String::with_capacity(16 * 1024);

    for (name, v) in s.share.fields() {
        if name == "store_degraded" {
            push_gauge(
                &mut out,
                "isoquant_store_degraded",
                "1 once the persistent store tripped into degraded mode",
                v as f64,
            );
        } else {
            push_counter(
                &mut out,
                &format!("isoquant_share_{name}_total"),
                &format!("prefix-sharing counter {name}"),
                v,
            );
        }
    }

    for (name, v) in &s.counters {
        push_counter(
            &mut out,
            &format!("isoquant_{name}_total"),
            &format!("engine counter {name}"),
            *v,
        );
    }

    let ratio = if s.compression_ratio.is_finite() {
        s.compression_ratio
    } else {
        0.0
    };
    push_gauge(
        &mut out,
        "isoquant_compression_ratio",
        "uncompressed/compressed byte ratio on the append path (0 until data flows)",
        ratio,
    );

    let p = &s.pages;
    push_gauge(&mut out, "isoquant_pages_live", "pages owned by in-flight sequences", p.live as f64);
    push_gauge(&mut out, "isoquant_pages_cached", "zero-ref sealed pages parked in the prefix index", p.cached as f64);
    push_gauge(&mut out, "isoquant_pages_capacity", "page-pool capacity", p.capacity as f64);
    push_gauge(&mut out, "isoquant_pages_high_water", "high-water mark of resident pages", p.high_water as f64);
    push_gauge(&mut out, "isoquant_pages_shared", "resident pages referenced by more than one sequence", p.shared as f64);
    push_gauge(&mut out, "isoquant_pages_exclusive", "resident pages referenced by exactly one sequence", p.exclusive as f64);
    push_gauge(&mut out, "isoquant_pages_cold", "cold directory entries resolvable from the persistent store", p.cold as f64);
    push_gauge(&mut out, "isoquant_store_disk_bytes", "bytes the persistent store holds on disk", p.store_disk_bytes as f64);
    push_gauge(&mut out, "isoquant_store_attached", "1 when a persistent store is attached", p.store_attached as f64);

    push_counter(
        &mut out,
        "isoquant_conn_overflow_disconnects_total",
        "connections dropped for exceeding the per-connection buffer cap",
        s.conn_overflow_disconnects,
    );

    for (name, h) in &s.hists {
        push_hist(&mut out, name, "latency histogram (seconds)", h);
    }

    if !s.phases.is_empty() {
        out.push_str(
            "# HELP isoquant_engine_phase_seconds per-phase Engine::step timings (seconds)\n\
             # TYPE isoquant_engine_phase_seconds histogram\n",
        );
        for (phase, h) in &s.phases {
            push_hist_series(&mut out, "isoquant_engine_phase_seconds", Some(("phase", phase)), h);
        }
    }

    out
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split a sample line into (name, labels-without-le, le, value).
fn parse_sample(line: &str) -> Result<(String, String, Option<f64>, f64), String> {
    let (name_labels, value) = match line.find('}') {
        Some(close) => {
            let v = line[close + 1..].trim();
            (&line[..close + 1], v)
        }
        None => {
            let sp = line
                .find(' ')
                .ok_or_else(|| format!("no value separator in {line:?}"))?;
            (&line[..sp], line[sp + 1..].trim())
        }
    };
    let value: f64 = value
        .parse()
        .map_err(|_| format!("unparseable value {value:?} in {line:?}"))?;
    let (name, labels) = match name_labels.find('{') {
        Some(open) => {
            if !name_labels.ends_with('}') {
                return Err(format!("unterminated label set in {line:?}"));
            }
            (
                &name_labels[..open],
                &name_labels[open + 1..name_labels.len() - 1],
            )
        }
        None => (name_labels, ""),
    };
    if !valid_metric_name(name) {
        return Err(format!("invalid metric name {name:?}"));
    }
    // our exposition never puts ',' or '=' inside label values, so a
    // flat split is enough for the lint's purposes
    let mut le = None;
    let mut rest = Vec::new();
    for pair in labels.split(',').filter(|p| !p.is_empty()) {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| format!("malformed label {pair:?} in {line:?}"))?;
        let v = v
            .strip_prefix('"')
            .and_then(|v| v.strip_suffix('"'))
            .ok_or_else(|| format!("unquoted label value {pair:?} in {line:?}"))?;
        if k == "le" {
            le = Some(if v == "+Inf" {
                f64::INFINITY
            } else {
                v.parse()
                    .map_err(|_| format!("unparseable le {v:?} in {line:?}"))?
            });
        } else {
            rest.push(pair.to_string());
        }
    }
    Ok((name.to_string(), rest.join(","), le, value))
}

/// Validate Prometheus text exposition: every sample's family carries
/// HELP and TYPE, sample lines parse, histogram bucket series are
/// cumulative-monotone with a `+Inf` bucket equal to `_count`, and
/// `_sum` is present.  Returns the first violation found.
pub fn lint_exposition(text: &str) -> Result<(), String> {
    #[derive(Default)]
    struct Series {
        buckets: Vec<(f64, f64)>,
        count: Option<f64>,
        sum: Option<f64>,
    }
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut helps: Vec<String> = Vec::new();
    let mut hist: BTreeMap<(String, String), Series> = BTreeMap::new();

    if text.is_empty() {
        return Err("empty exposition".into());
    }

    for (i, line) in text.lines().enumerate() {
        let ln = i + 1;
        if line.trim().is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(format!("line {ln}: HELP for invalid name {name:?}"));
            }
            helps.push(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: unknown TYPE {kind:?} for {name}"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("line {ln}: duplicate TYPE for {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }

        let (name, labels, le, value) =
            parse_sample(line).map_err(|e| format!("line {ln}: {e}"))?;

        // resolve the family: histogram children hang off the base name
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                name.strip_suffix(suf)
                    .filter(|base| types.get(*base).map(String::as_str) == Some("histogram"))
                    .map(str::to_string)
            })
            .unwrap_or_else(|| name.clone());
        let kind = types
            .get(&family)
            .ok_or_else(|| format!("line {ln}: sample {name} has no TYPE"))?;
        if !helps.iter().any(|h| h == &family) {
            return Err(format!("line {ln}: sample {name} has no HELP"));
        }
        if kind == "counter" && value < 0.0 {
            return Err(format!("line {ln}: counter {name} is negative"));
        }

        if kind == "histogram" {
            let series = hist.entry((family.clone(), labels)).or_default();
            if name.ends_with("_bucket") {
                let le =
                    le.ok_or_else(|| format!("line {ln}: bucket without le label"))?;
                series.buckets.push((le, value));
            } else if name.ends_with("_count") {
                series.count = Some(value);
            } else if name.ends_with("_sum") {
                series.sum = Some(value);
            } else {
                return Err(format!(
                    "line {ln}: bare sample {name} for histogram family {family}"
                ));
            }
        }
    }

    for ((family, labels), s) in &hist {
        let what = if labels.is_empty() {
            family.clone()
        } else {
            format!("{family}{{{labels}}}")
        };
        if s.buckets.is_empty() {
            return Err(format!("{what}: histogram with no buckets"));
        }
        for w in s.buckets.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err(format!("{what}: le values not increasing"));
            }
            if w[1].1 < w[0].1 {
                return Err(format!(
                    "{what}: cumulative bucket counts decrease at le={}",
                    w[1].0
                ));
            }
        }
        let last = s.buckets.last().unwrap();
        if !last.0.is_infinite() {
            return Err(format!("{what}: missing +Inf bucket"));
        }
        let count = s
            .count
            .ok_or_else(|| format!("{what}: missing _count"))?;
        if (last.1 - count).abs() > 1e-9 {
            return Err(format!(
                "{what}: +Inf bucket {} != _count {count}",
                last.1
            ));
        }
        if s.sum.is_none() {
            return Err(format!("{what}: missing _sum"));
        }
    }

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{Counters, Histogram};

    fn sample_snapshot() -> MetricsSnapshot {
        let h = Histogram::new();
        h.record_us(120.0);
        h.record_us(4_000.0);
        h.record_us(90_000.0);
        let mut s = MetricsSnapshot::default();
        s.share.prefix_hit_pages = 5;
        s.share.requests_shed = 1;
        s.compression_ratio = 16.0;
        s.pages.live = 7;
        s.pages.capacity = 64;
        s.hists = vec![
            ("isoquant_ttft_seconds", h.snapshot()),
            ("isoquant_inter_token_seconds", h.snapshot()),
            ("isoquant_queue_wait_seconds", h.snapshot()),
            ("isoquant_request_total_seconds", h.snapshot()),
        ];
        s.phases = vec![("forward", h.snapshot()), ("gather", h.snapshot())];
        s
    }

    #[test]
    fn render_passes_lint_and_covers_field_tables() {
        let snap = sample_snapshot();
        let text = render_prometheus(&snap);
        lint_exposition(&text).expect("own exposition lints clean");
        // every field-table counter appears by name
        for (name, _) in snap.share.fields() {
            assert!(text.contains(name), "share counter {name} missing");
        }
        for (name, _) in Counters::default().fields() {
            assert!(
                text.contains(&format!("isoquant_{name}_total")),
                "counter {name} missing"
            );
        }
        for required in [
            "isoquant_compression_ratio",
            "isoquant_pages_live",
            "isoquant_pages_high_water",
            "isoquant_pages_cold",
            "isoquant_store_degraded",
            "isoquant_store_attached",
            "isoquant_conn_overflow_disconnects_total",
            "isoquant_ttft_seconds_bucket",
            "isoquant_engine_phase_seconds_bucket{phase=\"forward\"",
        ] {
            assert!(text.contains(required), "{required} missing:\n{text}");
        }
    }

    #[test]
    fn empty_histograms_still_lint() {
        let mut snap = MetricsSnapshot::default();
        snap.hists = vec![("isoquant_ttft_seconds", Histogram::new().snapshot())];
        let text = render_prometheus(&snap);
        lint_exposition(&text).expect("zero-count histograms are valid");
        assert!(text.contains("isoquant_ttft_seconds_count 0"));
    }

    #[test]
    fn lint_rejects_missing_type() {
        assert!(lint_exposition("foo 1\n").is_err());
        let ok = "# HELP foo x\n# TYPE foo counter\nfoo 1\n";
        assert!(lint_exposition(ok).is_ok());
        let no_help = "# TYPE foo counter\nfoo 1\n";
        assert!(lint_exposition(no_help).is_err());
    }

    #[test]
    fn lint_rejects_broken_histograms() {
        let head = "# HELP h x\n# TYPE h histogram\n";
        // cumulative counts decrease
        let bad = format!(
            "{head}h_bucket{{le=\"1\"}} 5\nh_bucket{{le=\"2\"}} 3\nh_bucket{{le=\"+Inf\"}} 5\nh_sum 9\nh_count 5\n"
        );
        assert!(lint_exposition(&bad).is_err());
        // +Inf != count
        let bad = format!(
            "{head}h_bucket{{le=\"1\"}} 2\nh_bucket{{le=\"+Inf\"}} 5\nh_sum 9\nh_count 4\n"
        );
        assert!(lint_exposition(&bad).is_err());
        // missing +Inf
        let bad = format!("{head}h_bucket{{le=\"1\"}} 2\nh_sum 9\nh_count 2\n");
        assert!(lint_exposition(&bad).is_err());
        // the well-formed version passes
        let ok = format!(
            "{head}h_bucket{{le=\"1\"}} 2\nh_bucket{{le=\"+Inf\"}} 5\nh_sum 9\nh_count 5\n"
        );
        assert!(lint_exposition(&ok).is_ok());
    }

    #[test]
    fn lint_rejects_garbage_samples() {
        let head = "# HELP foo x\n# TYPE foo counter\n";
        assert!(lint_exposition(&format!("{head}foo bar\n")).is_err());
        assert!(lint_exposition(&format!("{head}1foo 2\n")).is_err());
        assert!(lint_exposition(&format!("{head}foo -1\n")).is_err(), "negative counter");
    }
}
