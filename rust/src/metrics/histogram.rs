//! Bounded log-bucketed latency histograms for the serve path.
//!
//! [`super::LatencyRecorder`] keeps every sample, which is the right
//! trade for a one-shot bench (exact percentiles, bounded run) and the
//! wrong one for a server: memory grows with request count and every
//! `{"stats": true}` percentile query clones and sorts the whole
//! vector.  [`Histogram`] fixes both — a fixed array of 64
//! geometrically spaced buckets (ratio √2, covering 1 µs to ~35 min),
//! lock-free `AtomicU64` counts so recorders can be shared across
//! threads, O(buckets) percentile estimation, and O(buckets) merge.
//! A percentile estimate is off by at most one bucket width (~41%
//! relative), which is what a latency dashboard needs; exact-sample
//! analysis stays on `LatencyRecorder`.
//!
//! Bucket `i < 63` counts samples with `value_us <= 2^(i/2)`; bucket 63
//! is the +Inf overflow.  The bounds double every two buckets, so the
//! exposition's `le` labels line up with the powers of two a human can
//! read off a scrape.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

/// Total bucket count, including the +Inf overflow bucket.
pub const BUCKETS: usize = 64;

/// Finite upper bounds in microseconds: `bound[i] = 2^(i/2)`.  The last
/// bucket (index `BUCKETS - 1`) has no finite bound.
pub fn bucket_bounds_us() -> &'static [f64; BUCKETS - 1] {
    static BOUNDS: OnceLock<[f64; BUCKETS - 1]> = OnceLock::new();
    BOUNDS.get_or_init(|| {
        let mut b = [0.0; BUCKETS - 1];
        for (i, slot) in b.iter_mut().enumerate() {
            *slot = 2f64.powf(i as f64 / 2.0);
        }
        b
    })
}

/// Lock-free bounded histogram of microsecond latencies.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    /// sum of recorded values, rounded to whole microseconds
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [(); BUCKETS].map(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index for a value in microseconds (sub-µs values land in
    /// bucket 0, values beyond the last finite bound in the overflow).
    pub fn bucket_of(us: f64) -> usize {
        bucket_bounds_us().partition_point(|&b| b < us)
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&self, us: f64) {
        let us = if us.is_finite() { us.max(0.0) } else { 0.0 };
        self.counts[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us.round() as u64, Ordering::Relaxed);
    }

    /// Fold another histogram's counts into this one.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.counts.iter().zip(&other.counts) {
            dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum_us
            .fetch_add(other.sum_us.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Point-in-time copy (plain integers) for rendering off-thread.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (dst, src) in counts.iter_mut().zip(&self.counts) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            sum_us: self.sum_us.load(Ordering::Relaxed),
        }
    }

    pub fn count(&self) -> u64 {
        self.counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// O(buckets) percentile estimate: the upper bound of the bucket
    /// holding the rank, so the estimate is never below the true value
    /// by more than one bucket width.  NaN when empty (the same
    /// convention as [`super::LatencyRecorder::percentile`]).
    pub fn percentile(&self, p: f64) -> f64 {
        self.snapshot().percentile(p)
    }

    pub fn mean(&self) -> f64 {
        self.snapshot().mean()
    }
}

/// Plain-integer copy of a [`Histogram`], cheap to clone and hand to a
/// renderer on another thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub counts: [u64; BUCKETS],
    pub sum_us: u64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        // same rank convention as LatencyRecorder: round((n-1) * p/100)
        let rank = ((total - 1) as f64 * p / 100.0).round() as u64;
        let bounds = bucket_bounds_us();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                // overflow bucket: report the last finite bound — the
                // estimate saturates rather than inventing a value
                return bounds.get(i).copied().unwrap_or(bounds[BUCKETS - 2]);
            }
        }
        bounds[BUCKETS - 2]
    }

    pub fn mean(&self) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        self.sum_us as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_increasing_sqrt2() {
        let b = bucket_bounds_us();
        assert_eq!(b[0], 1.0);
        assert_eq!(b[2], 2.0);
        assert_eq!(b[4], 4.0);
        for w in b.windows(2) {
            assert!(w[1] > w[0]);
            assert!((w[1] / w[0] - 2f64.sqrt()).abs() < 1e-9);
        }
    }

    #[test]
    fn bucket_of_edges() {
        // values at a bound land in the bucket whose `le` is that bound
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(1.0), 0);
        assert_eq!(Histogram::bucket_of(1.0001), 1);
        assert_eq!(Histogram::bucket_of(2.0), 2);
        assert_eq!(Histogram::bucket_of(f64::MAX), BUCKETS - 1);
    }

    #[test]
    fn percentile_within_one_bucket_of_exact() {
        let h = Histogram::new();
        let mut r = crate::metrics::LatencyRecorder::new();
        // deterministic spread across several octaves
        for i in 0..10_000u64 {
            let v = 1.0 + (i as f64 * 37.0) % 90_000.0;
            h.record_us(v);
            r.record_us(v);
        }
        let bounds = bucket_bounds_us();
        for p in [50.0, 90.0, 95.0, 99.0] {
            let exact = r.percentile(p);
            let est = h.percentile(p);
            let b = Histogram::bucket_of(exact);
            let lo = if b == 0 { 0.0 } else { bounds[b - 1] };
            let hi = bounds.get(b).copied().unwrap_or(f64::INFINITY);
            assert!(
                est >= lo && est <= hi,
                "p{p}: estimate {est} outside exact value's bucket [{lo}, {hi}] (exact {exact})"
            );
        }
    }

    #[test]
    fn empty_is_nan() {
        let h = Histogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn merge_adds() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_us(10.0);
        b.record_us(10.0);
        b.record_us(1e6);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        let snap = a.snapshot();
        assert_eq!(snap.sum_us, 1_000_020);
    }

    #[test]
    fn memory_is_fixed_regardless_of_samples() {
        // the whole point: no per-sample storage anywhere
        let h = Histogram::new();
        for i in 0..100_000 {
            h.record_us(i as f64);
        }
        assert_eq!(
            std::mem::size_of::<Histogram>(),
            std::mem::size_of::<AtomicU64>() * (BUCKETS + 1)
        );
    }

    #[test]
    fn overflow_bucket_counts_and_saturates() {
        let h = Histogram::new();
        h.record_us(1e18); // way past the last finite bound
        assert_eq!(h.count(), 1);
        let last_finite = bucket_bounds_us()[BUCKETS - 2];
        assert_eq!(h.percentile(99.0), last_finite);
        let snap = h.snapshot();
        assert_eq!(snap.counts[BUCKETS - 1], 1);
    }
}
