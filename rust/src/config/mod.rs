//! Engine configuration: a minimal TOML-subset parser (sections,
//! `key = value` with string/int/float/bool values, `#` comments) and the
//! typed schema consumed by the CLI and the serving engine.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::kvcache::PrefixIndexKind;
use crate::quant::kernels::KernelBackend;
use crate::quant::params::Variant;
use crate::quant::scalar::QuantKind;
use crate::util::pool::ParallelPolicy;

/// Raw parsed config: section → key → value.
#[derive(Debug, Default, Clone)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<RawConfig> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            let val = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<RawConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        RawConfig::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    fn usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_int()
                .map(|i| i as usize)
                .with_context(|| format!("[{section}] {key} must be an integer")),
        }
    }

    fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str().map(|s| s.to_string()))
            .unwrap_or_else(|| default.to_string())
    }

    fn f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_float()
                .with_context(|| format!("[{section}] {key} must be a number")),
        }
    }
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(q) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(q.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare strings allowed (variant names etc.)
    if s.chars().all(|c| c.is_alphanumeric() || "-_.".contains(c)) {
        return Ok(Value::Str(s.to_string()));
    }
    bail!("cannot parse value {s:?}")
}

// ---------------------------------------------------------------------
// typed engine config
// ---------------------------------------------------------------------

/// Everything the serving engine needs to boot.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// directory with manifest.json / *.hlo.txt / weights.bin
    pub artifacts_dir: String,
    /// stage-1 variant used for KV compression
    pub variant: Variant,
    pub bits: u8,
    pub quant: QuantKind,
    /// tokens per KV page
    pub page_tokens: usize,
    /// max decode batch (must divide into the compiled artifact batch)
    pub max_batch: usize,
    /// batching window: how long the batcher waits to fill a batch
    pub batch_window_us: u64,
    pub max_seq_len: usize,
    pub max_new_tokens_default: usize,
    /// TCP bind address for `isoquant serve`
    pub bind: String,
    /// default per-request deadline in milliseconds
    /// (`[server] request_timeout_ms`); 0 (the default) disables
    /// deadlines.  A request's own `deadline_ms` field overrides this.
    /// Expired requests finish with `finish: "timeout"` carrying
    /// whatever tokens were generated
    pub request_timeout_ms: u64,
    /// bound on requests waiting for admission (`[server] max_queue`);
    /// 0 (the default) keeps the queue unbounded.  Overflow is shed
    /// immediately with `{"error":"overloaded","retry_after_ms":…}`
    pub max_queue: usize,
    /// how long a graceful shutdown lets in-flight lanes finish before
    /// dropping them (`[server] drain_timeout_ms`); queued-but-unadmitted
    /// requests are shed at drain start either way
    pub drain_timeout_ms: u64,
    /// per-connection buffer cap in KiB (`[server] max_conn_buffer_kb`),
    /// applied to both an unterminated request line and the queued
    /// output backlog of a slow reader; a connection exceeding it is
    /// disconnected (counted in `conn_overflow_disconnects`).  0 =
    /// unlimited
    pub max_conn_buffer_kb: usize,
    /// optional dedicated Prometheus scrape listener
    /// (`[server] metrics_addr`); empty (the default) binds no second
    /// socket — `GET /metrics` still works on the main port via
    /// byte-sniffing
    pub metrics_addr: String,
    /// log verbosity (`[server] log_level = error|warn|info|debug`)
    pub log_level: String,
    /// emit log lines as JSON objects instead of text
    /// (`[server] log_json = off|on`)
    pub log_json: bool,
    /// per-phase `Engine::step` profiling (`[engine] profile = off|on`):
    /// expire/admit/gather/forward/append/emit histograms, exported via
    /// `/metrics` and the stats line.  Off by default — the phase clocks
    /// cost a few `Instant::now()` calls per step
    pub profile: bool,
    /// write attempts per spilled page before the spill worker counts a
    /// failure (`[cache] persist_retries`), retried with capped
    /// exponential backoff
    pub persist_retries: u32,
    /// initial backoff between spill retries in milliseconds
    /// (`[cache] persist_retry_backoff_ms`), doubling per attempt and
    /// capped at 1s
    pub persist_retry_backoff_ms: u64,
    /// consecutive spill-job failures before the store degrades to
    /// disabled (`[cache] persist_degrade_after`): serving continues,
    /// persistence stops, the stats line carries a STORE-DEGRADED marker
    pub persist_degrade_after: u32,
    /// stage-2 residual correction (0 = off, else projection dim)
    pub residual_m: usize,
    /// threading of the batched KV gather: `off`, `auto`, or a thread
    /// count (`[engine] gather_parallel`)
    pub gather_parallel: ParallelPolicy,
    /// stage-1 kernel implementation: `scalar`, `auto`, `avx2`, `neon`,
    /// or `avx512` (`[engine] kernel_backend`); all backends are
    /// bit-exact, `scalar` is the reference.  Rejected at load time when
    /// the host cannot run an explicitly requested SIMD backend.
    pub kernel_backend: KernelBackend,
    /// decode each distinct (page, slot-range) strip once per gather and
    /// fan duplicate rows out by memcpy (`[engine] gather_dedup =
    /// off|on`); only observable through `ShareStats` — gather output is
    /// byte-identical either way
    pub gather_dedup: bool,
    /// share sealed prompt pages between same-prefix sequences
    /// (`[cache] prefix_sharing = off|on`); off reproduces the
    /// exclusive-ownership cache
    pub prefix_sharing: bool,
    /// prefix-index structure (`[cache] prefix_index = flat|radix`):
    /// `flat` (default) is the whole-page chain-hash index and
    /// preserves PR 3/4 behavior exactly; `radix` is the token-level
    /// radix tree with sub-page slot-range reuse and hierarchical
    /// eviction
    pub prefix_index: PrefixIndexKind,
    /// directory of the persistent page store (`[cache] persist_dir`);
    /// empty (the default) disables persistence — no file I/O at all.
    /// Requires `prefix_sharing = on` (the store rides on the
    /// content-addressed index)
    pub persist_dir: String,
    /// on-disk budget of the page store in MiB
    /// (`[cache] persist_budget_mb`); 0 = unlimited.  Enforced by
    /// retiring the oldest log segments
    pub persist_budget_mb: usize,
    /// serve cold reads from mmap'd store segments instead of buffered
    /// file reads (`[cache] persist_mmap = off|on`); records are still
    /// CRC- and fingerprint-verified on every read, and unsupported
    /// hosts fall back to buffered reads
    pub persist_mmap: bool,
    /// minimum `(reuse+1)/(depth+1)` retention score a store record
    /// must carry for the segment compactor to rescue it before its
    /// segment retires (`[cache] compact_threshold`, fractional; 0.0 —
    /// the default — disables compaction, keeping plain whole-segment
    /// FIFO retirement)
    pub compact_threshold: f64,
    /// upper bound on bytes the compactor may rewrite per spill-side
    /// pass (`[cache] compact_max_bytes_per_pass`); bounds an append's
    /// tail latency when a large segment retires
    pub compact_max_bytes_per_pass: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: "artifacts".to_string(),
            variant: Variant::IsoFull,
            bits: 4,
            quant: QuantKind::Lloyd,
            page_tokens: 16,
            max_batch: 4,
            batch_window_us: 2_000,
            max_seq_len: 256,
            max_new_tokens_default: 32,
            bind: "127.0.0.1:7439".to_string(),
            request_timeout_ms: 0,
            max_queue: 0,
            drain_timeout_ms: 5_000,
            max_conn_buffer_kb: 1024,
            metrics_addr: String::new(),
            log_level: "info".to_string(),
            log_json: false,
            profile: false,
            persist_retries: 3,
            persist_retry_backoff_ms: 50,
            persist_degrade_after: 5,
            residual_m: 0,
            gather_parallel: ParallelPolicy::Auto,
            // honor the ISOQUANT_KERNEL process override (the CI matrix
            // forces the backend through it), falling back to auto
            kernel_backend: KernelBackend::from_env_default(),
            gather_dedup: true,
            prefix_sharing: false,
            prefix_index: PrefixIndexKind::Flat,
            persist_dir: String::new(),
            persist_budget_mb: 256,
            persist_mmap: true,
            compact_threshold: 0.0,
            compact_max_bytes_per_pass: 4 << 20,
            seed: 0x150_0541,
        }
    }
}

/// Parse an `off|on` (or bare bool) config value.
fn parse_switch(v: &Value, what: &str) -> Result<bool> {
    match v {
        Value::Bool(b) => Ok(*b),
        Value::Str(s) if s == "on" => Ok(true),
        Value::Str(s) if s == "off" => Ok(false),
        other => bail!("{what} must be off/on, got {other:?}"),
    }
}

impl EngineConfig {
    pub fn from_raw(raw: &RawConfig) -> Result<EngineConfig> {
        let d = EngineConfig::default();
        let variant = Variant::from_name(&raw.str_or("engine", "variant", "iso-full"))?;
        let quant = match raw.str_or("engine", "quantizer", "lloyd").as_str() {
            "lloyd" => QuantKind::Lloyd,
            "uniform" => QuantKind::Uniform,
            other => bail!("unknown quantizer {other:?}"),
        };
        let bits = raw.usize_or("engine", "bits", d.bits as usize)? as u8;
        if !(2..=4).contains(&bits) {
            bail!("bits must be 2..=4, got {bits}");
        }
        Ok(EngineConfig {
            artifacts_dir: raw.str_or("engine", "artifacts_dir", &d.artifacts_dir),
            variant,
            bits,
            quant,
            page_tokens: raw.usize_or("engine", "page_tokens", d.page_tokens)?,
            max_batch: raw.usize_or("engine", "max_batch", d.max_batch)?,
            batch_window_us: raw.usize_or("engine", "batch_window_us", d.batch_window_us as usize)?
                as u64,
            max_seq_len: raw.usize_or("engine", "max_seq_len", d.max_seq_len)?,
            max_new_tokens_default: raw.usize_or(
                "engine",
                "max_new_tokens_default",
                d.max_new_tokens_default,
            )?,
            bind: raw.str_or("server", "bind", &d.bind),
            request_timeout_ms: raw.usize_or(
                "server",
                "request_timeout_ms",
                d.request_timeout_ms as usize,
            )? as u64,
            max_queue: raw.usize_or("server", "max_queue", d.max_queue)?,
            drain_timeout_ms: raw.usize_or(
                "server",
                "drain_timeout_ms",
                d.drain_timeout_ms as usize,
            )? as u64,
            max_conn_buffer_kb: raw.usize_or(
                "server",
                "max_conn_buffer_kb",
                d.max_conn_buffer_kb,
            )?,
            metrics_addr: match raw.get("server", "metrics_addr") {
                None => d.metrics_addr,
                Some(Value::Str(s)) => s.clone(),
                Some(v) => bail!("[server] metrics_addr must be a string address, got {v:?}"),
            },
            log_level: match raw.get("server", "log_level") {
                None => d.log_level,
                Some(Value::Str(s)) => {
                    if crate::util::log::Level::parse(s).is_none() {
                        bail!("[server] log_level must be error|warn|info|debug, got {s:?}");
                    }
                    s.clone()
                }
                Some(v) => bail!("[server] log_level must be error|warn|info|debug, got {v:?}"),
            },
            log_json: match raw.get("server", "log_json") {
                None => d.log_json,
                Some(v) => parse_switch(v, "[server] log_json")?,
            },
            profile: match raw.get("engine", "profile") {
                None => d.profile,
                Some(v) => parse_switch(v, "[engine] profile")?,
            },
            persist_retries: raw.usize_or("cache", "persist_retries", d.persist_retries as usize)?
                as u32,
            persist_retry_backoff_ms: raw.usize_or(
                "cache",
                "persist_retry_backoff_ms",
                d.persist_retry_backoff_ms as usize,
            )? as u64,
            persist_degrade_after: {
                let n = raw.usize_or(
                    "cache",
                    "persist_degrade_after",
                    d.persist_degrade_after as usize,
                )?;
                if n == 0 {
                    bail!("[cache] persist_degrade_after must be >= 1");
                }
                n as u32
            },
            residual_m: raw.usize_or("engine", "residual_m", d.residual_m)?,
            gather_parallel: match raw.get("engine", "gather_parallel") {
                None => d.gather_parallel,
                Some(Value::Int(0)) => ParallelPolicy::Off,
                Some(Value::Int(n)) if *n > 0 => ParallelPolicy::Fixed(*n as usize),
                Some(Value::Str(s)) => match ParallelPolicy::parse(s) {
                    Some(p) => p,
                    None => bail!("gather_parallel must be off/auto/<threads>, got {s:?}"),
                },
                Some(v) => bail!("gather_parallel must be off/auto/<threads>, got {v:?}"),
            },
            kernel_backend: match raw.get("engine", "kernel_backend") {
                None => d.kernel_backend,
                Some(Value::Str(s)) => match KernelBackend::parse(s) {
                    Some(b) => {
                        if let Err(e) = b.validate() {
                            bail!("{e}");
                        }
                        b
                    }
                    None => bail!("kernel_backend must be scalar/auto/avx2/neon/avx512, got {s:?}"),
                },
                Some(v) => bail!("kernel_backend must be scalar/auto/avx2/neon/avx512, got {v:?}"),
            },
            gather_dedup: match raw.get("engine", "gather_dedup") {
                None => d.gather_dedup,
                Some(v) => parse_switch(v, "[engine] gather_dedup")?,
            },
            prefix_sharing: match raw.get("cache", "prefix_sharing") {
                None => d.prefix_sharing,
                Some(v) => parse_switch(v, "[cache] prefix_sharing")?,
            },
            prefix_index: match raw.get("cache", "prefix_index") {
                None => d.prefix_index,
                Some(Value::Str(s)) => match PrefixIndexKind::parse(s) {
                    Some(k) => k,
                    None => bail!("[cache] prefix_index must be flat|radix, got {s:?}"),
                },
                Some(v) => bail!("[cache] prefix_index must be flat|radix, got {v:?}"),
            },
            persist_dir: match raw.get("cache", "persist_dir") {
                None => d.persist_dir,
                Some(Value::Str(s)) => s.clone(),
                Some(v) => bail!("[cache] persist_dir must be a string path, got {v:?}"),
            },
            persist_budget_mb: raw.usize_or("cache", "persist_budget_mb", d.persist_budget_mb)?,
            persist_mmap: match raw.get("cache", "persist_mmap") {
                None => d.persist_mmap,
                Some(v) => parse_switch(v, "[cache] persist_mmap")?,
            },
            compact_threshold: {
                let t = raw.f64_or("cache", "compact_threshold", d.compact_threshold)?;
                if !(0.0..=65_536.0).contains(&t) {
                    bail!("[cache] compact_threshold must be in [0, 65536], got {t}");
                }
                t
            },
            compact_max_bytes_per_pass: raw.usize_or(
                "cache",
                "compact_max_bytes_per_pass",
                d.compact_max_bytes_per_pass,
            )?,
            seed: raw.f64_or("engine", "seed", d.seed as f64)? as u64,
        })
    }

    pub fn load(path: &Path) -> Result<EngineConfig> {
        EngineConfig::from_raw(&RawConfig::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# engine settings
[engine]
variant = "iso-fast"
bits = 2
quantizer = lloyd
page_tokens = 32
max_batch = 4        # fixed by the compiled artifact

[server]
bind = "0.0.0.0:9000"
"#;

    #[test]
    fn parses_sample() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("engine", "bits").unwrap().as_int(), Some(2));
        assert_eq!(
            raw.get("engine", "variant").unwrap().as_str(),
            Some("iso-fast")
        );
        let cfg = EngineConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.variant, Variant::IsoFast);
        assert_eq!(cfg.bits, 2);
        assert_eq!(cfg.page_tokens, 32);
        assert_eq!(cfg.bind, "0.0.0.0:9000");
    }

    #[test]
    fn defaults_when_missing() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.variant, Variant::IsoFull);
        assert_eq!(cfg.bits, 4);
        assert_eq!(cfg.page_tokens, 16);
    }

    #[test]
    fn value_types() {
        let raw = RawConfig::parse("[a]\nx = 1\ny = 2.5\nz = true\ns = \"hi\"").unwrap();
        assert_eq!(raw.get("a", "x").unwrap().as_int(), Some(1));
        assert_eq!(raw.get("a", "y").unwrap().as_float(), Some(2.5));
        assert_eq!(raw.get("a", "z").unwrap().as_bool(), Some(true));
        assert_eq!(raw.get("a", "s").unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn gather_parallel_knob() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.gather_parallel, ParallelPolicy::Auto);
        for (text, want) in [
            ("[engine]\ngather_parallel = \"off\"", ParallelPolicy::Off),
            ("[engine]\ngather_parallel = off", ParallelPolicy::Off),
            ("[engine]\ngather_parallel = \"auto\"", ParallelPolicy::Auto),
            ("[engine]\ngather_parallel = 0", ParallelPolicy::Off),
            ("[engine]\ngather_parallel = 4", ParallelPolicy::Fixed(4)),
        ] {
            let cfg = EngineConfig::from_raw(&RawConfig::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.gather_parallel, want, "{text}");
        }
        for text in [
            "[engine]\ngather_parallel = \"sideways\"",
            "[engine]\ngather_parallel = -2",
            "[engine]\ngather_parallel = true",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn kernel_backend_knob() {
        // the default follows the process override (CI forces it via
        // ISOQUANT_KERNEL), so compare against that, not a literal
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.kernel_backend, KernelBackend::from_env_default());
        for (text, want) in [
            ("[engine]\nkernel_backend = \"scalar\"", KernelBackend::Scalar),
            ("[engine]\nkernel_backend = scalar", KernelBackend::Scalar),
            ("[engine]\nkernel_backend = \"auto\"", KernelBackend::Auto),
        ] {
            let cfg = EngineConfig::from_raw(&RawConfig::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.kernel_backend, want, "{text}");
        }
        for text in [
            "[engine]\nkernel_backend = \"sse9\"",
            "[engine]\nkernel_backend = 4",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
        // an explicitly requested SIMD backend the host supports parses;
        // one it cannot run is rejected at load time
        let avx = EngineConfig::from_raw(
            &RawConfig::parse("[engine]\nkernel_backend = \"avx2\"").unwrap(),
        );
        assert_eq!(avx.is_ok(), KernelBackend::Avx2.validate().is_ok());
        let neon = EngineConfig::from_raw(
            &RawConfig::parse("[engine]\nkernel_backend = \"neon\"").unwrap(),
        );
        assert_eq!(neon.is_ok(), KernelBackend::Neon.validate().is_ok());
        let avx512 = EngineConfig::from_raw(
            &RawConfig::parse("[engine]\nkernel_backend = \"avx512\"").unwrap(),
        );
        assert_eq!(avx512.is_ok(), KernelBackend::Avx512.validate().is_ok());
    }

    #[test]
    fn gather_dedup_knob() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert!(cfg.gather_dedup, "defaults on");
        for (text, want) in [
            ("[engine]\ngather_dedup = \"off\"", false),
            ("[engine]\ngather_dedup = off", false),
            ("[engine]\ngather_dedup = false", false),
            ("[engine]\ngather_dedup = \"on\"", true),
            ("[engine]\ngather_dedup = on", true),
            ("[engine]\ngather_dedup = true", true),
        ] {
            let cfg = EngineConfig::from_raw(&RawConfig::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.gather_dedup, want, "{text}");
        }
        for text in [
            "[engine]\ngather_dedup = 1",
            "[engine]\ngather_dedup = \"always\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn persist_mmap_knob() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert!(cfg.persist_mmap, "defaults on");
        for (text, want) in [
            ("[cache]\npersist_mmap = \"off\"", false),
            ("[cache]\npersist_mmap = off", false),
            ("[cache]\npersist_mmap = \"on\"", true),
            ("[cache]\npersist_mmap = true", true),
        ] {
            let cfg = EngineConfig::from_raw(&RawConfig::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.persist_mmap, want, "{text}");
        }
        for text in [
            "[cache]\npersist_mmap = 0",
            "[cache]\npersist_mmap = \"sometimes\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn prefix_sharing_knob() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert!(!cfg.prefix_sharing, "defaults off");
        for (text, want) in [
            ("[cache]\nprefix_sharing = \"on\"", true),
            ("[cache]\nprefix_sharing = on", true),
            ("[cache]\nprefix_sharing = true", true),
            ("[cache]\nprefix_sharing = \"off\"", false),
            ("[cache]\nprefix_sharing = off", false),
            ("[cache]\nprefix_sharing = false", false),
        ] {
            let cfg = EngineConfig::from_raw(&RawConfig::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.prefix_sharing, want, "{text}");
        }
        for text in [
            "[cache]\nprefix_sharing = 1",
            "[cache]\nprefix_sharing = \"maybe\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn prefix_index_knob() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.prefix_index, PrefixIndexKind::Flat, "defaults flat");
        for (text, want) in [
            ("[cache]\nprefix_index = \"flat\"", PrefixIndexKind::Flat),
            ("[cache]\nprefix_index = flat", PrefixIndexKind::Flat),
            ("[cache]\nprefix_index = \"radix\"", PrefixIndexKind::Radix),
            ("[cache]\nprefix_index = radix", PrefixIndexKind::Radix),
        ] {
            let cfg = EngineConfig::from_raw(&RawConfig::parse(text).unwrap()).unwrap();
            assert_eq!(cfg.prefix_index, want, "{text}");
        }
        for text in [
            "[cache]\nprefix_index = \"hash\"",
            "[cache]\nprefix_index = 2",
            "[cache]\nprefix_index = true",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn persist_knobs() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.persist_dir, "", "persistence defaults off");
        assert_eq!(cfg.persist_budget_mb, 256);
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse(
                "[cache]\nprefix_sharing = on\npersist_dir = \"/tmp/kv\"\npersist_budget_mb = 64",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.persist_dir, "/tmp/kv");
        assert_eq!(cfg.persist_budget_mb, 64);
        assert!(cfg.prefix_sharing);
        // bare (unquoted) paths parse too
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse("[cache]\npersist_dir = kvstore").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.persist_dir, "kvstore");
        for text in [
            "[cache]\npersist_dir = 5",
            "[cache]\npersist_dir = true",
            "[cache]\npersist_budget_mb = \"lots\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn lifecycle_knobs() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.request_timeout_ms, 0, "deadlines default off");
        assert_eq!(cfg.max_queue, 0, "queue defaults unbounded");
        assert_eq!(cfg.drain_timeout_ms, 5_000);
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse(
                "[server]\nrequest_timeout_ms = 250\nmax_queue = 32\ndrain_timeout_ms = 100",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.request_timeout_ms, 250);
        assert_eq!(cfg.max_queue, 32);
        assert_eq!(cfg.drain_timeout_ms, 100);
        for text in [
            "[server]\nrequest_timeout_ms = \"fast\"",
            "[server]\nmax_queue = true",
            "[server]\ndrain_timeout_ms = \"long\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn conn_buffer_knob() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.max_conn_buffer_kb, 1024, "defaults to 1 MiB");
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse("[server]\nmax_conn_buffer_kb = 64").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.max_conn_buffer_kb, 64);
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse("[server]\nmax_conn_buffer_kb = 0").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.max_conn_buffer_kb, 0, "0 disables the cap");
        let raw = RawConfig::parse("[server]\nmax_conn_buffer_kb = \"big\"").unwrap();
        assert!(EngineConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn persist_fault_knobs() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.persist_retries, 3);
        assert_eq!(cfg.persist_retry_backoff_ms, 50);
        assert_eq!(cfg.persist_degrade_after, 5);
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse(
                "[cache]\npersist_retries = 0\npersist_retry_backoff_ms = 1\n\
                 persist_degrade_after = 2",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.persist_retries, 0, "retries can be disabled");
        assert_eq!(cfg.persist_retry_backoff_ms, 1);
        assert_eq!(cfg.persist_degrade_after, 2);
        for text in [
            "[cache]\npersist_degrade_after = 0",
            "[cache]\npersist_retries = \"many\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn compaction_knobs() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.compact_threshold, 0.0, "compaction defaults off");
        assert_eq!(cfg.compact_max_bytes_per_pass, 4 << 20);
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse(
                "[cache]\ncompact_threshold = 0.5\n\
                 compact_max_bytes_per_pass = 1048576",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.compact_threshold, 0.5);
        assert_eq!(cfg.compact_max_bytes_per_pass, 1 << 20);
        for text in [
            "[cache]\ncompact_threshold = -0.25",
            "[cache]\ncompact_threshold = 70000",
            "[cache]\ncompact_threshold = \"hot\"",
            "[cache]\ncompact_max_bytes_per_pass = \"lots\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn observability_knobs() {
        let cfg = EngineConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.metrics_addr, "", "no dedicated scrape port by default");
        assert_eq!(cfg.log_level, "info");
        assert!(!cfg.log_json);
        assert!(!cfg.profile, "profiler defaults off");
        let cfg = EngineConfig::from_raw(
            &RawConfig::parse(
                "[server]\nmetrics_addr = \"127.0.0.1:9100\"\nlog_level = \"debug\"\n\
                 log_json = on\n[engine]\nprofile = on",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.metrics_addr, "127.0.0.1:9100");
        assert_eq!(cfg.log_level, "debug");
        assert!(cfg.log_json);
        assert!(cfg.profile);
        for text in [
            "[server]\nmetrics_addr = 9100",
            "[server]\nlog_level = \"chatty\"",
            "[server]\nlog_level = 2",
            "[server]\nlog_json = 1",
            "[engine]\nprofile = \"sometimes\"",
        ] {
            let raw = RawConfig::parse(text).unwrap();
            assert!(EngineConfig::from_raw(&raw).is_err(), "{text}");
        }
    }

    #[test]
    fn rejects_bad_bits() {
        let raw = RawConfig::parse("[engine]\nbits = 9").unwrap();
        assert!(EngineConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn rejects_garbage_line() {
        assert!(RawConfig::parse("[a]\nnot a kv line").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let raw = RawConfig::parse("\n# c\n[s]\n# c2\nk = 1 # inline\n\n").unwrap();
        assert_eq!(raw.get("s", "k").unwrap().as_int(), Some(1));
    }
}
