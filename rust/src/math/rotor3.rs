//! Cl(3,0) geometric algebra: even-subalgebra rotors and the full
//! 8-component multivector product — the substrate for the RotorQuant
//! baseline (paper [2]).
//!
//! Two implementations of the rotor sandwich are provided:
//!
//! * [`Rotor::apply`] — the *efficient* odd-intermediate form (two
//!   quaternion-shaped products), which is what our fair fused baseline
//!   uses;
//! * [`Multivector`]-based [`sandwich_multivector`] — the general
//!   8-component expansion the paper says RotorQuant's implementation
//!   pays for ("IsoQuant avoids the expansion to an 8-component
//!   multivector representation", §9.3).  This form appears in the
//!   module-level (unfused) benchmark path and in tests that pin the two
//!   forms to each other.
//!
//! Multivector component order: [1, e1, e2, e3, e12, e13, e23, e123].

/// Even-subalgebra rotor R = s + b12·e12 + b13·e13 + b23·e23 with
/// s² + b12² + b13² + b23² = 1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rotor {
    pub s: f32,
    pub b12: f32,
    pub b13: f32,
    pub b23: f32,
}

impl Rotor {
    /// Rotor from a unit quaternion (w, x, y, z): the standard Cl(3,0) ≅ ℍ
    /// even-subalgebra isomorphism (e23 ↦ -i, e13 ↦ j, e12 ↦ -k up to
    /// sign convention; we pick the one that makes `apply` match
    /// `quaternion::rotate3`).
    pub fn from_quaternion(q: [f32; 4]) -> Rotor {
        Rotor {
            s: q[0],
            b23: -q[1],
            b13: q[2],
            b12: -q[3],
        }
    }

    pub fn to_quaternion(self) -> [f32; 4] {
        [self.s, -self.b23, self.b13, -self.b12]
    }

    /// Rotor norm (should be 1 for a proper rotor).
    pub fn norm(self) -> f32 {
        (self.s * self.s + self.b12 * self.b12 + self.b13 * self.b13 + self.b23 * self.b23)
            .sqrt()
    }

    pub fn normalize(self) -> Rotor {
        let n = self.norm();
        Rotor {
            s: self.s / n,
            b12: self.b12 / n,
            b13: self.b13 / n,
            b23: self.b23 / n,
        }
    }

    /// Reverse R~ (grade involution of the bivector part).
    pub fn reverse(self) -> Rotor {
        Rotor {
            s: self.s,
            b12: -self.b12,
            b13: -self.b13,
            b23: -self.b23,
        }
    }

    /// Rotor sandwich R v R~ on a 3-vector in the efficient
    /// odd-intermediate form.  Cost: the intermediate R·v is an odd
    /// multivector (vector + trivector = 4 components, 12 mul + 8 add),
    /// the second product back to a vector is 12 mul + 9 add — ~28 FMAs
    /// per 3 coordinates, vs 32 FMAs per 4 coordinates for the
    /// IsoQuant-Full sandwich (paper Table 1 counts the full fused
    /// rotor pipeline at ≈56 FMA/block, i.e. forward + inverse).
    #[inline(always)]
    pub fn apply(self, v: [f32; 3]) -> [f32; 3] {
        // odd intermediate o = R v: vector part (o1,o2,o3), trivector o123
        let Rotor { s, b12, b13, b23 } = self;
        let [v1, v2, v3] = v;
        let o1 = s * v1 + b12 * v2 + b13 * v3;
        let o2 = s * v2 - b12 * v1 + b23 * v3;
        let o3 = s * v3 - b13 * v1 - b23 * v2;
        let o123 = b23 * v1 - b13 * v2 + b12 * v3;
        // r = o · R~ — vector part only (trivector part cancels)
        let r1 = o1 * s + o2 * b12 + o3 * b13 + o123 * b23;
        let r2 = o2 * s - o1 * b12 - o123 * b13 + o3 * b23;
        let r3 = o3 * s + o123 * b12 - o1 * b13 - o2 * b23;
        [r1, r2, r3]
    }

    #[inline(always)]
    pub fn apply_inv(self, v: [f32; 3]) -> [f32; 3] {
        self.reverse().apply(v)
    }
}

/// General Cl(3,0) multivector: [scalar, e1, e2, e3, e12, e13, e23, e123].
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Multivector(pub [f32; 8]);

impl Multivector {
    pub fn scalar(s: f32) -> Multivector {
        let mut m = [0.0; 8];
        m[0] = s;
        Multivector(m)
    }

    pub fn vector(v: [f32; 3]) -> Multivector {
        let mut m = [0.0; 8];
        m[1] = v[0];
        m[2] = v[1];
        m[3] = v[2];
        Multivector(m)
    }

    pub fn from_rotor(r: Rotor) -> Multivector {
        let mut m = [0.0; 8];
        m[0] = r.s;
        m[4] = r.b12;
        m[5] = r.b13;
        m[6] = r.b23;
        Multivector(m)
    }

    pub fn vector_part(self) -> [f32; 3] {
        [self.0[1], self.0[2], self.0[3]]
    }

    /// Full geometric product — 64 multiplies (the 8-component expansion
    /// RotorQuant's unfused path pays; see module docs).
    #[inline(always)]
    pub fn geometric_product(self, rhs: Multivector) -> Multivector {
        let a = self.0;
        let b = rhs.0;
        // basis: 0:1, 1:e1, 2:e2, 3:e3, 4:e12, 5:e13, 6:e23, 7:e123
        let mut c = [0.0f32; 8];
        c[0] = a[0] * b[0] + a[1] * b[1] + a[2] * b[2] + a[3] * b[3]
            - a[4] * b[4] - a[5] * b[5] - a[6] * b[6] - a[7] * b[7];
        c[1] = a[0] * b[1] + a[1] * b[0] - a[2] * b[4] - a[3] * b[5]
            + a[4] * b[2] + a[5] * b[3] - a[6] * b[7] - a[7] * b[6];
        c[2] = a[0] * b[2] + a[2] * b[0] + a[1] * b[4] - a[3] * b[6]
            - a[4] * b[1] + a[5] * b[7] + a[6] * b[3] + a[7] * b[5];
        c[3] = a[0] * b[3] + a[3] * b[0] + a[1] * b[5] + a[2] * b[6]
            - a[4] * b[7] - a[5] * b[1] - a[6] * b[2] - a[7] * b[4];
        c[4] = a[0] * b[4] + a[4] * b[0] + a[1] * b[2] - a[2] * b[1]
            + a[3] * b[7] + a[7] * b[3] - a[5] * b[6] + a[6] * b[5];
        c[5] = a[0] * b[5] + a[5] * b[0] + a[1] * b[3] - a[3] * b[1]
            - a[2] * b[7] - a[7] * b[2] + a[4] * b[6] - a[6] * b[4];
        c[6] = a[0] * b[6] + a[6] * b[0] + a[2] * b[3] - a[3] * b[2]
            + a[1] * b[7] + a[7] * b[1] - a[4] * b[5] + a[5] * b[4];
        c[7] = a[0] * b[7] + a[7] * b[0] + a[1] * b[6] - a[2] * b[5]
            + a[3] * b[4] + a[4] * b[3] - a[5] * b[2] + a[6] * b[1];
        Multivector(c)
    }

    #[inline(always)]
    pub fn reverse(self) -> Multivector {
        let a = self.0;
        // grades 0,1 keep sign; grades 2,3 flip
        Multivector([a[0], a[1], a[2], a[3], -a[4], -a[5], -a[6], -a[7]])
    }
}

/// Rotor sandwich via the full multivector expansion (the unfused
/// RotorQuant module path): R v R~ with two 64-multiply products.
#[inline(always)]
pub fn sandwich_multivector(r: Rotor, v: [f32; 3]) -> [f32; 3] {
    let rm = Multivector::from_rotor(r);
    let vm = Multivector::vector(v);
    rm.geometric_product(vm)
        .geometric_product(rm.reverse())
        .vector_part()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::quaternion;
    use crate::util::prng::Rng;

    fn n3(v: [f32; 3]) -> f32 {
        (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt()
    }

    #[test]
    fn rotor_apply_matches_quaternion_rotate3() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let q = rng.haar_quaternion();
            let v = [
                rng.gaussian() as f32,
                rng.gaussian() as f32,
                rng.gaussian() as f32,
            ];
            let a = Rotor::from_quaternion(q).apply(v);
            let b = quaternion::rotate3(q, v);
            for i in 0..3 {
                assert!((a[i] - b[i]).abs() < 1e-5, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn rotor_apply_matches_multivector_sandwich() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let r = Rotor::from_quaternion(rng.haar_quaternion());
            let v = [
                rng.gaussian() as f32,
                rng.gaussian() as f32,
                rng.gaussian() as f32,
            ];
            let a = r.apply(v);
            let b = sandwich_multivector(r, v);
            for i in 0..3 {
                assert!((a[i] - b[i]).abs() < 1e-5, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn apply_preserves_norm_and_inverts() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let r = Rotor::from_quaternion(rng.haar_quaternion());
            let v = [
                rng.gaussian() as f32,
                rng.gaussian() as f32,
                rng.gaussian() as f32,
            ];
            let y = r.apply(v);
            assert!((n3(y) - n3(v)).abs() < 1e-5 * n3(v).max(1.0));
            let back = r.apply_inv(y);
            for i in 0..3 {
                assert!((back[i] - v[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn quaternion_roundtrip() {
        let mut rng = Rng::new(4);
        let q = rng.haar_quaternion();
        let q2 = Rotor::from_quaternion(q).to_quaternion();
        for i in 0..4 {
            assert!((q[i] - q2[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn geometric_product_basis_identities() {
        // e1·e1 = 1
        let e1 = Multivector::vector([1.0, 0.0, 0.0]);
        let p = e1.geometric_product(e1);
        assert_eq!(p.0[0], 1.0);
        assert!(p.0[1..].iter().all(|&x| x == 0.0));
        // e1·e2 = e12
        let e2 = Multivector::vector([0.0, 1.0, 0.0]);
        let p = e1.geometric_product(e2);
        assert_eq!(p.0[4], 1.0);
        // e123·e123 = -1
        let mut e123 = Multivector::default();
        e123.0[7] = 1.0;
        let p = e123.geometric_product(e123);
        assert_eq!(p.0[0], -1.0);
    }

    #[test]
    fn geometric_product_associative() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let a = Multivector(std::array::from_fn(|_| rng.gaussian() as f32));
            let b = Multivector(std::array::from_fn(|_| rng.gaussian() as f32));
            let c = Multivector(std::array::from_fn(|_| rng.gaussian() as f32));
            let lhs = a.geometric_product(b).geometric_product(c);
            let rhs = a.geometric_product(b.geometric_product(c));
            for i in 0..8 {
                assert!(
                    (lhs.0[i] - rhs.0[i]).abs() < 2e-4 * lhs.0[i].abs().max(1.0),
                    "component {i}: {} vs {}",
                    lhs.0[i],
                    rhs.0[i]
                );
            }
        }
    }

    #[test]
    fn rotor_normalize() {
        let r = Rotor {
            s: 2.0,
            b12: 0.0,
            b13: 0.0,
            b23: 0.0,
        };
        assert!((r.normalize().norm() - 1.0).abs() < 1e-7);
    }
}
