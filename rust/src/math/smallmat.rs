//! Dense row-major matrix helpers for the TurboQuant baseline and the
//! attention substrate: matmul, matvec, transpose, Gram–Schmidt
//! orthogonality checks.  Sizes here are small (≤ d×d with d ≤ 512 and
//! attention projections), so a simple cache-blocked loop suffices; the
//! heavy model math runs inside the XLA executable, not here.

/// C(m×n) = A(m×k) · B(k×n), row-major, accumulating in f32.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// y(m) = A(m×n) · x(n).
pub fn matvec(a: &[f32], x: &[f32], y: &mut [f32], m: usize, n: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        y[i] = s;
    }
}

/// y(n) = Aᵀ(n×m) · x(m) for row-major A(m×n) — i.e. x · A.
pub fn matvec_t(a: &[f32], x: &[f32], y: &mut [f32], m: usize, n: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    for i in 0..m {
        let xv = x[i];
        if xv == 0.0 {
            continue;
        }
        let row = &a[i * n..(i + 1) * n];
        for j in 0..n {
            y[j] += xv * row[j];
        }
    }
}

pub fn transpose(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    let mut t = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            t[j * m + i] = a[i * n + j];
        }
    }
    t
}

/// Max |MᵀM - I| entry for a d×d row-major matrix.
pub fn orthogonality_defect(m: &[f32], d: usize) -> f32 {
    let mut worst = 0.0f32;
    for i in 0..d {
        for j in 0..d {
            let mut dot = 0.0f32;
            for k in 0..d {
                dot += m[k * d + i] * m[k * d + j];
            }
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((dot - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 1.0, 1.0, 1.0];
        let mut c = [0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (17, 33, 9);
        let a = rng.gaussian_vec_f32(m * k);
        let b = rng.gaussian_vec_f32(k * n);
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                assert!((c[i * n + j] - s).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn matvec_and_transpose_consistent() {
        let mut rng = Rng::new(2);
        let (m, n) = (12, 7);
        let a = rng.gaussian_vec_f32(m * n);
        let x = rng.gaussian_vec_f32(n);
        let mut y1 = vec![0.0; m];
        matvec(&a, &x, &mut y1, m, n);
        let at = transpose(&a, m, n);
        let mut y2 = vec![0.0; m];
        matvec_t(&at, &x, &mut y2, n, m);
        for i in 0..m {
            assert!((y1[i] - y2[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn haar_matrix_orthogonal() {
        let mut rng = Rng::new(3);
        let d = 32;
        let m = rng.haar_orthogonal(d);
        assert!(orthogonality_defect(&m, d) < 1e-4);
    }
}
