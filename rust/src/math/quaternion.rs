//! Quaternion algebra on `[f32; 4]` = (w, x, y, z) — the closed-form
//! parameterization of SO(4) at the heart of IsoQuant (paper §4).
//!
//! Scalar building blocks live here; the batched hot-path versions (which
//! keep blocks in registers across rotate→quantize→unrotate) are in
//! `quant::pipeline`.

pub type Quat = [f32; 4];

pub const IDENTITY: Quat = [1.0, 0.0, 0.0, 0.0];

/// Hamilton product a·b: 16 multiplies / 12 adds (the paper's ~16 FMA
/// costing unit, §6).
#[inline(always)]
pub fn hamilton(a: Quat, b: Quat) -> Quat {
    let [aw, ax, ay, az] = a;
    let [bw, bx, by, bz] = b;
    [
        aw * bw - ax * bx - ay * by - az * bz,
        aw * bx + ax * bw + ay * bz - az * by,
        aw * by - ax * bz + ay * bw + az * bx,
        aw * bz + ax * by - ay * bx + az * bw,
    ]
}

#[inline(always)]
pub fn conjugate(q: Quat) -> Quat {
    [q[0], -q[1], -q[2], -q[3]]
}

#[inline(always)]
pub fn norm(q: Quat) -> f32 {
    (q[0] * q[0] + q[1] * q[1] + q[2] * q[2] + q[3] * q[3]).sqrt()
}

/// Normalize onto S³ (paper eq. 33); identity for near-zero input.
#[inline]
pub fn normalize(q: Quat) -> Quat {
    let n = norm(q);
    if n < 1e-12 {
        return IDENTITY;
    }
    [q[0] / n, q[1] / n, q[2] / n, q[3] / n]
}

/// Double-sided isoclinic action T(v) = qL · v · conj(qR) (paper eq. 11).
#[inline(always)]
pub fn sandwich(q_l: Quat, v: Quat, q_r: Quat) -> Quat {
    hamilton(hamilton(q_l, v), conjugate(q_r))
}

/// Inverse action conj(qL) · v · qR (paper eq. 12).
#[inline(always)]
pub fn sandwich_inv(q_l: Quat, v: Quat, q_r: Quat) -> Quat {
    hamilton(hamilton(conjugate(q_l), v), q_r)
}

/// Rotate a 3-vector by the rotation encoded in unit quaternion q
/// (v ↦ q v q̄ on pure quaternions) — the Cl(3,0) rotor action used by
/// the RotorQuant baseline.
#[inline(always)]
pub fn rotate3(q: Quat, v: [f32; 3]) -> [f32; 3] {
    let p = [0.0, v[0], v[1], v[2]];
    let out = hamilton(hamilton(q, p), conjugate(q));
    [out[1], out[2], out[3]]
}

#[inline(always)]
pub fn rotate3_inv(q: Quat, v: [f32; 3]) -> [f32; 3] {
    let p = [0.0, v[0], v[1], v[2]];
    let out = hamilton(hamilton(conjugate(q), p), q);
    [out[1], out[2], out[3]]
}

/// Spherical linear interpolation on S³ — supports the paper's closing
/// observation that quaternion pairs admit smooth interpolation on the
/// rotation manifold (§11), used by the shared/adaptive-rotation
/// extension in `quant::params`.
pub fn slerp(a: Quat, b: Quat, t: f32) -> Quat {
    let mut dot = a[0] * b[0] + a[1] * b[1] + a[2] * b[2] + a[3] * b[3];
    // take the short arc (double cover: q and -q are the same rotation)
    let mut b = b;
    if dot < 0.0 {
        b = [-b[0], -b[1], -b[2], -b[3]];
        dot = -dot;
    }
    if dot > 0.9995 {
        // nearly parallel: lerp + renormalize
        return normalize([
            a[0] + t * (b[0] - a[0]),
            a[1] + t * (b[1] - a[1]),
            a[2] + t * (b[2] - a[2]),
            a[3] + t * (b[3] - a[3]),
        ]);
    }
    let theta = dot.clamp(-1.0, 1.0).acos();
    let s = theta.sin();
    let wa = ((1.0 - t) * theta).sin() / s;
    let wb = (t * theta).sin() / s;
    [
        wa * a[0] + wb * b[0],
        wa * a[1] + wb * b[1],
        wa * a[2] + wb * b[2],
        wa * a[3] + wb * b[3],
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn close(a: Quat, b: Quat, tol: f32) -> bool {
        a.iter().zip(&b).all(|(x, y)| (x - y).abs() < tol)
    }

    #[test]
    fn identity_element() {
        let q = [0.3, -0.5, 0.7, 0.1];
        assert!(close(hamilton(IDENTITY, q), q, 1e-7));
        assert!(close(hamilton(q, IDENTITY), q, 1e-7));
    }

    #[test]
    fn ijk_relations() {
        let i = [0.0, 1.0, 0.0, 0.0];
        let j = [0.0, 0.0, 1.0, 0.0];
        let k = [0.0, 0.0, 0.0, 1.0];
        let m1 = [-1.0, 0.0, 0.0, 0.0];
        assert!(close(hamilton(i, i), m1, 1e-7));
        assert!(close(hamilton(j, j), m1, 1e-7));
        assert!(close(hamilton(k, k), m1, 1e-7));
        assert!(close(hamilton(hamilton(i, j), k), m1, 1e-7));
        assert!(close(hamilton(i, j), k, 1e-7)); // ij = k
    }

    #[test]
    fn norm_multiplicative() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let a: Quat = std::array::from_fn(|_| rng.gaussian() as f32);
            let b: Quat = std::array::from_fn(|_| rng.gaussian() as f32);
            let n = norm(hamilton(a, b));
            assert!((n - norm(a) * norm(b)).abs() < 1e-3 * n.max(1.0));
        }
    }

    #[test]
    fn sandwich_preserves_norm_and_inverts() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let ql = rng.haar_quaternion();
            let qr = rng.haar_quaternion();
            let v: Quat = std::array::from_fn(|_| rng.gaussian() as f32);
            let y = sandwich(ql, v, qr);
            assert!((norm(y) - norm(v)).abs() < 1e-5 * norm(v).max(1.0));
            let back = sandwich_inv(ql, y, qr);
            assert!(close(back, v, 1e-5));
        }
    }

    #[test]
    fn double_cover() {
        // (qL, qR) and (-qL, -qR) give the same transform (paper eq. 13)
        let mut rng = Rng::new(3);
        let ql = rng.haar_quaternion();
        let qr = rng.haar_quaternion();
        let nl = [-ql[0], -ql[1], -ql[2], -ql[3]];
        let nr = [-qr[0], -qr[1], -qr[2], -qr[3]];
        let v: Quat = std::array::from_fn(|_| rng.gaussian() as f32);
        assert!(close(sandwich(ql, v, qr), sandwich(nl, v, nr), 1e-7));
    }

    #[test]
    fn rotate3_preserves_norm_and_inverts() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let q = rng.haar_quaternion();
            let v = [
                rng.gaussian() as f32,
                rng.gaussian() as f32,
                rng.gaussian() as f32,
            ];
            let y = rotate3(q, v);
            let nv = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            let ny = (y[0] * y[0] + y[1] * y[1] + y[2] * y[2]).sqrt();
            assert!((nv - ny).abs() < 1e-5 * nv.max(1.0));
            let back = rotate3_inv(q, y);
            for i in 0..3 {
                assert!((back[i] - v[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn rotate3_scalar_part_stays_zero() {
        // q (0,v) q̄ must remain a pure quaternion
        let mut rng = Rng::new(5);
        let q = rng.haar_quaternion();
        let v = [1.0, -2.0, 0.5];
        let p = [0.0, v[0], v[1], v[2]];
        let out = hamilton(hamilton(q, p), conjugate(q));
        assert!(out[0].abs() < 1e-6);
    }

    #[test]
    fn slerp_endpoints_and_midpoint_norm() {
        let mut rng = Rng::new(6);
        let a = rng.haar_quaternion();
        let b = rng.haar_quaternion();
        assert!(close(slerp(a, b, 0.0), a, 1e-6));
        let end = slerp(a, b, 1.0);
        // endpoint may be -b (short arc), which is the same rotation
        assert!(close(end, b, 1e-5) || close(end, [-b[0], -b[1], -b[2], -b[3]], 1e-5));
        let mid = slerp(a, b, 0.5);
        assert!((norm(mid) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn normalize_handles_zero() {
        assert_eq!(normalize([0.0; 4]), IDENTITY);
    }
}
