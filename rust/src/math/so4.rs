//! SO(4) via the isoclinic decomposition (paper §4): conversions between
//! quaternion pairs and dense 4×4 matrices, plus diagnostics used by
//! tests and the complexity model.  The hot path never materializes these
//! matrices — that is the paper's point — but tests, the learned-rotation
//! trainer, and the dense baseline need them.

use crate::math::quaternion::{self as quat, Quat};

/// Materialize the matrix M with M·v = qL · v · conj(qR), row-major.
pub fn isoclinic_matrix(q_l: Quat, q_r: Quat) -> [f32; 16] {
    let mut m = [0.0f32; 16];
    for i in 0..4 {
        let mut e = [0.0f32; 4];
        e[i] = 1.0;
        let col = quat::sandwich(q_l, e, q_r);
        for j in 0..4 {
            m[j * 4 + i] = col[j];
        }
    }
    m
}

/// Left-isoclinic matrix (IsoQuant-Fast): M·v = qL · v.
pub fn left_isoclinic_matrix(q_l: Quat) -> [f32; 16] {
    let [w, x, y, z] = q_l;
    // columns are qL·e_i under Hamilton product
    [
        w, -x, -y, -z, //
        x, w, -z, y, //
        y, z, w, -x, //
        z, -y, x, w,
    ]
}

/// Right-isoclinic matrix: M·v = v · conj(qR).
pub fn right_isoclinic_matrix(q_r: Quat) -> [f32; 16] {
    isoclinic_matrix(quat::IDENTITY, q_r)
}

/// Frobenius distance of MᵀM from I — orthogonality defect.
pub fn orthogonality_defect(m: &[f32; 16]) -> f32 {
    let mut sum = 0.0f32;
    for i in 0..4 {
        for j in 0..4 {
            let mut dot = 0.0f32;
            for k in 0..4 {
                dot += m[k * 4 + i] * m[k * 4 + j];
            }
            let want = if i == j { 1.0 } else { 0.0 };
            sum += (dot - want) * (dot - want);
        }
    }
    sum.sqrt()
}

/// Determinant of a 4×4 (row-major) by cofactor expansion.
pub fn det4(m: &[f32; 16]) -> f32 {
    let a = |r: usize, c: usize| m[r * 4 + c] as f64;
    let det3 = |r: [usize; 3], c: [usize; 3]| -> f64 {
        a(r[0], c[0]) * (a(r[1], c[1]) * a(r[2], c[2]) - a(r[1], c[2]) * a(r[2], c[1]))
            - a(r[0], c[1]) * (a(r[1], c[0]) * a(r[2], c[2]) - a(r[1], c[2]) * a(r[2], c[0]))
            + a(r[0], c[2]) * (a(r[1], c[0]) * a(r[2], c[1]) - a(r[1], c[1]) * a(r[2], c[0]))
    };
    let rows = [1, 2, 3];
    let mut det = 0.0f64;
    let cols = [0usize, 1, 2, 3];
    for (i, &c) in cols.iter().enumerate() {
        let rest: Vec<usize> = cols.iter().copied().filter(|&x| x != c).collect();
        let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
        det += sign * a(0, c) * det3(rows, [rest[0], rest[1], rest[2]]);
    }
    det as f32
}

/// An isoclinic rotation satisfies: all four column (or row) "rotation
/// angles" are equal.  Left-isoclinic matrices commute with right-
/// isoclinic ones — the su(2)⊕su(2) splitting (paper eq. 7–9).  Used by
/// tests to verify the decomposition numerically.
pub fn matmul4(a: &[f32; 16], b: &[f32; 16]) -> [f32; 16] {
    let mut c = [0.0f32; 16];
    for i in 0..4 {
        for j in 0..4 {
            let mut s = 0.0f32;
            for k in 0..4 {
                s += a[i * 4 + k] * b[k * 4 + j];
            }
            c[i * 4 + j] = s;
        }
    }
    c
}

pub fn matvec4(m: &[f32; 16], v: Quat) -> Quat {
    std::array::from_fn(|i| {
        m[i * 4] * v[0] + m[i * 4 + 1] * v[1] + m[i * 4 + 2] * v[2] + m[i * 4 + 3] * v[3]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn isoclinic_matrix_is_so4() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let m = isoclinic_matrix(rng.haar_quaternion(), rng.haar_quaternion());
            assert!(orthogonality_defect(&m) < 1e-5);
            assert!((det4(&m) - 1.0).abs() < 1e-4, "det {}", det4(&m));
        }
    }

    #[test]
    fn matrix_matches_sandwich() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let ql = rng.haar_quaternion();
            let qr = rng.haar_quaternion();
            let m = isoclinic_matrix(ql, qr);
            let v: Quat = std::array::from_fn(|_| rng.gaussian() as f32);
            let a = matvec4(&m, v);
            let b = quat::sandwich(ql, v, qr);
            for i in 0..4 {
                assert!((a[i] - b[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn left_and_right_factors_commute() {
        // the su(2)_L ⊕ su(2)_R splitting: L(qL)·R(qR) = R(qR)·L(qL)
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let l = left_isoclinic_matrix(rng.haar_quaternion());
            let r = right_isoclinic_matrix(rng.haar_quaternion());
            let lr = matmul4(&l, &r);
            let rl = matmul4(&r, &l);
            for i in 0..16 {
                assert!((lr[i] - rl[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn full_factors_into_left_times_right() {
        // M(qL, qR) = L(qL)·R(qR) (paper eq. 9 at the group level)
        let mut rng = Rng::new(4);
        let ql = rng.haar_quaternion();
        let qr = rng.haar_quaternion();
        let m = isoclinic_matrix(ql, qr);
        let prod = matmul4(&left_isoclinic_matrix(ql), &right_isoclinic_matrix(qr));
        for i in 0..16 {
            assert!((m[i] - prod[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn left_isoclinic_matrix_matches_hamilton() {
        let mut rng = Rng::new(5);
        let ql = rng.haar_quaternion();
        let v: Quat = std::array::from_fn(|_| rng.gaussian() as f32);
        let a = matvec4(&left_isoclinic_matrix(ql), v);
        let b = quat::hamilton(ql, v);
        for i in 0..4 {
            assert!((a[i] - b[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn identity_matrices() {
        let m = isoclinic_matrix(quat::IDENTITY, quat::IDENTITY);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((m[i * 4 + j] - want).abs() < 1e-7);
            }
        }
    }
}
