//! Mathematical substrates: quaternion algebra (ℍ), Cl(3,0) rotors, the
//! SO(4) isoclinic decomposition, and small dense linear algebra.

pub mod quaternion;
pub mod rotor3;
pub mod smallmat;
pub mod so4;
