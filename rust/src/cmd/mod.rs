//! CLI subcommand implementations for the `isoquant` binary.

use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::EngineConfig;
use crate::coordinator::Engine;
use crate::quant::{
    cost, mse, BatchScratch, KernelBackend, PackedSink, QuantKind, Stage1, Stage1Config, Variant,
};
use crate::runtime::{self, HostTensor, Runtime, ServingModel};
use crate::util::bench::Table;
use crate::util::cli::Parser;
use crate::util::prng::Rng;

fn parse_or_usage(p: &Parser, args: &[String]) -> Result<Option<crate::util::cli::Args>> {
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", p.usage());
        return Ok(None);
    }
    Ok(Some(p.parse(args)?))
}

/// Parse the `--kernel` option (empty = not given → `None`); rejects
/// backends this host cannot run.
fn parse_kernel(a: &crate::util::cli::Args) -> Result<Option<KernelBackend>> {
    match a.get("kernel") {
        None | Some("") => Ok(None),
        Some(s) => {
            let b = KernelBackend::parse(s)
                .with_context(|| format!("unknown kernel backend {s:?} (scalar|auto|avx2|neon|avx512)"))?;
            if let Err(e) = b.validate() {
                bail!("{e}");
            }
            Ok(Some(b))
        }
    }
}

/// `isoquant compress` — one-shot stage-1 compression demo.
pub fn compress(args: &[String]) -> Result<()> {
    let p = Parser::new("isoquant compress", "stage-1 compression demo on synthetic vectors")
        .opt("variant", "iso-full", "iso-full | iso-fast | iso-2d | rotor | dense | iso-8d")
        .opt("dim", "128", "vector dimension d")
        .opt("bits", "4", "bit width (2-4)")
        .opt("batch", "8192", "number of vectors")
        .opt("seed", "0", "data seed")
        .opt("kernel", "", "kernel backend: scalar | auto | avx2 | neon | avx512")
        .flag("uniform", "use the uniform quantizer instead of Lloyd-Max");
    let Some(a) = parse_or_usage(&p, args)? else {
        return Ok(());
    };
    let variant = Variant::from_name(a.get("variant").unwrap())?;
    let d = a.get_usize("dim")?;
    let bits = a.get_usize("bits")? as u8;
    let n = a.get_usize("batch")?;
    let mut cfg = Stage1Config::new(variant, d, bits);
    if a.has_flag("uniform") {
        cfg.quant = QuantKind::Uniform;
    }
    if let Some(b) = parse_kernel(&a)? {
        cfg.backend = b;
    }
    let stage = Stage1::new(cfg);
    let mut rng = Rng::new(a.get_u64("seed")?);
    let x = rng.gaussian_vec_f32(n * d);
    let mut out = vec![0.0f32; n * d];
    let t0 = std::time::Instant::now();
    stage.roundtrip_batch(&x, &mut out, n);
    let dt = t0.elapsed();
    let power = x.iter().map(|&v| (v * v) as f64).sum::<f64>() / x.len() as f64;
    let e = mse(&x, &out);
    println!("variant         : {}", variant.name());
    println!("kernel backend  : {}", stage.kernel_backend().name());
    println!("d x batch       : {d} x {n}");
    println!("bits            : {bits}");
    println!("mse             : {e:.6}");
    println!("relative mse    : {:.4}%", 100.0 * e / power);
    println!("compressed      : {} B/vector (from {} B)", stage.encoded_len(), d * 4);
    println!(
        "fused roundtrip : {:.1} us/batch ({:.1} ns/vector, scalar math)",
        dt.as_secs_f64() * 1e6,
        dt.as_secs_f64() * 1e9 / n as f64
    );
    // the packed encode→decode path is what the KV cache runs and what
    // the --kernel backend accelerates; warm the persistent buffers
    // first so the timed pass is steady-state
    let mut sink = PackedSink::new();
    let mut scratch = BatchScratch::new();
    let mut dec = vec![0.0f32; n * d];
    stage.encode_batch(&x, n, &mut sink);
    stage.decode_batch(sink.as_bytes(), n, &mut dec, &mut scratch);
    let t0 = std::time::Instant::now();
    stage.encode_batch(&x, n, &mut sink);
    let enc_dt = t0.elapsed();
    let t1 = std::time::Instant::now();
    stage.decode_batch(sink.as_bytes(), n, &mut dec, &mut scratch);
    let dec_dt = t1.elapsed();
    println!(
        "packed encode   : {:.1} us/batch ({:.1} ns/vector, {} kernels)",
        enc_dt.as_secs_f64() * 1e6,
        enc_dt.as_secs_f64() * 1e9 / n as f64,
        stage.kernel_backend().name()
    );
    println!(
        "packed decode   : {:.1} us/batch ({:.1} ns/vector, {} kernels)",
        dec_dt.as_secs_f64() * 1e6,
        dec_dt.as_secs_f64() * 1e9 / n as f64,
        stage.kernel_backend().name()
    );
    Ok(())
}

/// `isoquant table1` — the paper's complexity model.
pub fn table1(args: &[String]) -> Result<()> {
    let p = Parser::new("isoquant table1", "print the paper's Table 1 complexity model")
        .opt("dim", "128", "head dimension d");
    let Some(a) = parse_or_usage(&p, args)? else {
        return Ok(());
    };
    let d = a.get_usize("dim")?;
    println!("Forward rotation complexity at d = {d} (paper Table 1):\n");
    let mut t = Table::new(&["Method", "Block Structure", "Params", "FMAs"]);
    for row in cost::table1(d) {
        t.row(vec![
            row.method.to_string(),
            row.block_structure,
            row.params.to_string(),
            row.fmas.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// `isoquant sweep` — quick latency/MSE sweep over the packed
/// encode→decode path, the serving representation the `--kernel`
/// backend accelerates (the full 18-setting Table 2 regeneration lives
/// in `cargo bench --bench table2_sweep`).
pub fn sweep(args: &[String]) -> Result<()> {
    let p = Parser::new("isoquant sweep", "quick packed encode+decode latency/MSE sweep")
        .opt("dim", "128", "vector dimension")
        .opt("bits", "4", "bit width")
        .opt("batch", "8192", "batch size")
        .opt("kernel", "", "kernel backend: scalar | auto | avx2 | neon | avx512");
    let Some(a) = parse_or_usage(&p, args)? else {
        return Ok(());
    };
    let d = a.get_usize("dim")?;
    let bits = a.get_usize("bits")? as u8;
    let n = a.get_usize("batch")?;
    let kernel = parse_kernel(&a)?;
    let mut rng = Rng::new(1);
    let x = rng.gaussian_vec_f32(n * d);
    let mut out = vec![0.0f32; n * d];
    let bench = crate::util::bench::Bencher::quick();
    let mut t = Table::new(&["variant", "packed us/batch", "MSE", "speedup vs rotor"]);
    let mut rotor_us = 0.0;
    let configs = [
        ("rotorquant", Stage1Config::new(Variant::Rotor3D, d, bits)),
        (
            "rotor-opt",
            Stage1Config::new(Variant::Rotor3D, d, bits)
                .with_rotor_impl(crate::quant::pipeline::RotorImpl::OddIntermediate),
        ),
        ("iso-full", Stage1Config::new(Variant::IsoFull, d, bits)),
        ("iso-fast", Stage1Config::new(Variant::IsoFast, d, bits)),
        ("iso-2d", Stage1Config::new(Variant::Planar2D, d, bits)),
    ];
    let mut kname = "scalar";
    for (name, mut cfg) in configs {
        if let Some(b) = kernel {
            cfg.backend = b;
        }
        let s = Stage1::new(cfg);
        kname = s.kernel_backend().name();
        // the packed encode→decode pair is the KV-cache serving path —
        // the one the kernel backend dispatches
        let mut sink = PackedSink::new();
        let mut scratch = BatchScratch::new();
        let r = bench.run(name, || {
            s.encode_batch(&x, n, &mut sink);
            s.decode_batch(sink.as_bytes(), n, &mut out, &mut scratch);
        });
        s.encode_batch(&x, n, &mut sink);
        s.decode_batch(sink.as_bytes(), n, &mut out, &mut scratch);
        let e = mse(&x, &out);
        if name == "rotorquant" {
            rotor_us = r.median_us();
        }
        t.row(vec![
            name.to_string(),
            format!("{:.1}", r.median_us()),
            format!("{e:.6}"),
            format!("{:.2}x", rotor_us / r.median_us()),
        ]);
    }
    println!("d={d} bits={bits} batch={n} (f32, Lloyd-Max, {kname} kernels):\n");
    t.print();
    Ok(())
}

/// `isoquant inspect-artifacts` — print the AOT manifest.
pub fn inspect_artifacts(args: &[String]) -> Result<()> {
    let p = Parser::new("isoquant inspect-artifacts", "print the artifact manifest")
        .opt("artifacts", "artifacts", "artifacts directory");
    let Some(a) = parse_or_usage(&p, args)? else {
        return Ok(());
    };
    let dir = Path::new(a.get("artifacts").unwrap());
    let m = runtime::Manifest::load(dir)?;
    println!(
        "model: {} params, {} layers, {} heads x d_head {}, vocab {}, max_seq {}, serve batch {}",
        m.model.n_params,
        m.model.n_layers,
        m.model.n_heads,
        m.model.d_head,
        m.model.vocab,
        m.model.max_seq,
        m.model.serve_batch
    );
    let mut t = Table::new(&["artifact", "file", "inputs", "kind"]);
    for a in &m.artifacts {
        t.row(vec![
            a.name.clone(),
            a.file.clone(),
            a.inputs.len().to_string(),
            a.meta.get("kind").cloned().unwrap_or_default(),
        ]);
    }
    t.print();
    Ok(())
}

/// `isoquant selfcheck` — cross-language parity: the native Rust stage-1
/// pipeline must match the AOT-lowered Pallas/HLO graphs run under PJRT.
pub fn selfcheck(args: &[String]) -> Result<()> {
    let p = Parser::new(
        "isoquant selfcheck",
        "native stage-1 vs AOT Pallas/HLO parity via PJRT",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .opt("tol", "2e-5", "max |Δ| tolerance");
    let Some(a) = parse_or_usage(&p, args)? else {
        return Ok(());
    };
    let dir = Path::new(a.get("artifacts").unwrap());
    let tol: f64 = a.get_f64("tol")?;
    let mut rt = Runtime::load(dir)?;
    println!("platform: {}", rt.platform());
    let specs: Vec<_> = rt.manifest.stage1_artifacts().into_iter().cloned().collect();
    if specs.is_empty() {
        bail!("no stage1 artifacts in manifest — run `make artifacts`");
    }
    let mut failures = 0;
    for spec in specs {
        let variant = Variant::from_name(
            spec.meta.get("variant").context("artifact missing variant")?,
        )?;
        let d = spec.meta_usize("d").context("missing d")?;
        let bits = spec.meta_usize("bits").context("missing bits")? as u8;
        let batch = spec.meta_usize("batch").context("missing batch")?;
        let cfg = Stage1Config::new(variant, d, bits);
        let stage = Stage1::new(cfg);
        // same inputs to both paths
        let mut rng = Rng::new(0xA0A0 + d as u64 + bits as u64);
        let x = rng.gaussian_vec_f32(batch * d);
        let mut native = vec![0.0f32; batch * d];
        stage.roundtrip_batch(&x, &mut native, batch);

        let mut inputs = vec![HostTensor::F32(x.clone(), vec![batch, d])];
        for t in stage.bank.to_hlo_inputs() {
            inputs.push(HostTensor::F32(t.as_f32()?, t.shape.clone()));
        }
        let outs = rt.run_f32(&spec.name, &inputs)?;
        let hlo = &outs[0];
        let mut worst = 0.0f64;
        for (i, (&n, &h)) in native.iter().zip(hlo).enumerate() {
            let delta = ((n - h) as f64).abs();
            if delta > worst {
                worst = delta;
            }
            if delta > tol {
                failures += 1;
                if failures <= 3 {
                    eprintln!("  {}: idx {i}: native {n} vs hlo {h}", spec.name);
                }
            }
        }
        println!(
            "{:28} native-vs-HLO max|Δ| = {worst:.2e} {}",
            spec.name,
            if worst <= tol { "OK" } else { "FAIL" }
        );
    }
    if failures > 0 {
        bail!("{failures} elements exceeded tolerance {tol}");
    }
    println!("selfcheck OK");
    Ok(())
}

/// `isoquant serve` — boot the serving engine on TCP.
pub fn serve(args: &[String]) -> Result<()> {
    let p = Parser::new("isoquant serve", "serve the AOT model with compressed KV cache")
        .opt("config", "", "optional TOML config path")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("bind", "", "bind address (overrides config)")
        .opt("variant", "", "stage-1 variant (overrides config)")
        .opt("bits", "", "bit width (overrides config)")
        .opt("kernel", "", "kernel backend (overrides config): scalar | auto | avx2 | neon | avx512")
        .opt(
            "prefix-sharing",
            "",
            "share prompt-prefix KV pages between requests (overrides config): on | off",
        )
        .opt(
            "prefix-index",
            "",
            "prefix-index structure (overrides config): flat | radix \
             (radix adds token-granular sub-page matching)",
        )
        .opt(
            "persist-dir",
            "",
            "persist prompt pages to this directory across restarts (overrides config; \
             requires prefix sharing)",
        )
        .opt(
            "persist-budget-mb",
            "",
            "on-disk budget of the page store in MiB (overrides config; 0 = unlimited)",
        )
        .flag(
            "no-persist",
            "disable the persistent page store even when the config enables it",
        )
        .opt(
            "metrics-addr",
            "",
            "dedicated Prometheus scrape listener (overrides config; \
             GET /metrics also works on the main port)",
        )
        .opt(
            "log-level",
            "",
            "log verbosity (overrides config): error | warn | info | debug",
        )
        .flag(
            "profile",
            "per-phase engine-step profiling (histograms in /metrics and stats)",
        );
    let Some(a) = parse_or_usage(&p, args)? else {
        return Ok(());
    };
    let mut cfg = match a.get("config") {
        Some("") | None => EngineConfig::default(),
        Some(path) => EngineConfig::load(Path::new(path))?,
    };
    cfg.artifacts_dir = a.get("artifacts").unwrap_or("artifacts").to_string();
    if let Some(b) = a.get("bind") {
        if !b.is_empty() {
            cfg.bind = b.to_string();
        }
    }
    if let Some(v) = a.get("variant") {
        if !v.is_empty() {
            cfg.variant = Variant::from_name(v)?;
        }
    }
    if let Some(b) = a.get("bits") {
        if !b.is_empty() {
            cfg.bits = b.parse()?;
        }
    }
    if let Some(b) = parse_kernel(&a)? {
        cfg.kernel_backend = b;
    }
    match a.get("prefix-sharing") {
        None | Some("") => {}
        Some("on") => cfg.prefix_sharing = true,
        Some("off") => cfg.prefix_sharing = false,
        Some(other) => bail!("--prefix-sharing must be on|off, got {other:?}"),
    }
    match a.get("prefix-index") {
        None | Some("") => {}
        Some(s) => {
            cfg.prefix_index = crate::kvcache::PrefixIndexKind::parse(s)
                .with_context(|| format!("--prefix-index must be flat|radix, got {s:?}"))?;
        }
    }
    if let Some(dir) = a.get("persist-dir") {
        if !dir.is_empty() {
            cfg.persist_dir = dir.to_string();
        }
    }
    if let Some(mb) = a.get("persist-budget-mb") {
        if !mb.is_empty() {
            cfg.persist_budget_mb = mb
                .parse()
                .with_context(|| format!("--persist-budget-mb must be an integer, got {mb:?}"))?;
        }
    }
    if a.has_flag("no-persist") {
        cfg.persist_dir.clear();
    }
    if let Some(addr) = a.get("metrics-addr") {
        if !addr.is_empty() {
            cfg.metrics_addr = addr.to_string();
        }
    }
    if let Some(l) = a.get("log-level") {
        if !l.is_empty() {
            cfg.log_level = l.to_string();
        }
    }
    if a.has_flag("profile") {
        cfg.profile = true;
    }
    crate::util::log::configure(&cfg.log_level, cfg.log_json)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let model = ServingModel::load(Path::new(&cfg.artifacts_dir))?;
    let engine = Engine::new(model, cfg.clone())?;
    let stop = Arc::new(AtomicBool::new(false));
    // ctrl-C → graceful drain: lanes finish, queue is shed, store flushes
    crate::server::install_sigint_handler();
    crate::server::serve(engine, &cfg.bind, stop).map(|_| ())
}
