//! `isoquant` CLI — leader entrypoint.
//!
//! Subcommands:
//!   compress            one-shot stage-1 compression demo on synthetic data
//!   sweep               Table-2 style latency/MSE sweep (see benches for
//!                       the full 18-setting regeneration)
//!   serve               boot the serving engine on a TCP port
//!   selfcheck           cross-language parity: native pipeline vs the
//!                       AOT-lowered Pallas/HLO graphs via PJRT
//!   inspect-artifacts   print the artifact manifest
//!   table1              print the paper's Table 1 complexity model

use anyhow::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "compress" => isoquant::cmd::compress(rest),
        "sweep" => isoquant::cmd::sweep(rest),
        "serve" => isoquant::cmd::serve(rest),
        "selfcheck" => isoquant::cmd::selfcheck(rest),
        "inspect-artifacts" => isoquant::cmd::inspect_artifacts(rest),
        "table1" => isoquant::cmd::table1(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "isoquant — SO(4) isoclinic rotations for KV cache compression\n\
         \n\
         usage: isoquant <subcommand> [options]\n\
         \n\
         subcommands:\n\
         \x20 compress            stage-1 compression demo (synthetic batch)\n\
         \x20 sweep               latency/MSE sweep across variants\n\
         \x20 serve               run the serving engine (TCP, JSON lines)\n\
         \x20 selfcheck           native-vs-HLO parity via PJRT\n\
         \x20 inspect-artifacts   print the AOT artifact manifest\n\
         \x20 table1              print the complexity model (paper Table 1)\n\
         \n\
         run `isoquant <subcommand> --help` for per-command options"
    );
}
