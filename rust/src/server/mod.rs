//! TCP serving front-end: JSON-lines protocol over `std::net`.
//!
//! Request:  `{"id": 1, "prompt": [3, 17, 5], "max_new_tokens": 16}`
//! Response: `{"id": 1, "tokens": [...], "prompt_len": 3,
//!             "ttft_us": 1234.5, "total_us": 5678.9, "finish": "max_tokens"}`
//!
//! The listener thread parses requests into the engine's queue; the
//! engine thread runs `step()` continuously and pushes completions back
//! to the matching connection.  One in-flight request per connection
//! line keeps the protocol trivial while still exercising batched
//! multi-client serving (clients connect concurrently).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::{Batcher, Completion, Engine, FinishReason, Request};
use crate::util::json::Json;

/// Parse one request line.
pub fn parse_request(line: &str, fallback_id: u64, default_max_new: usize) -> Result<Request> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    let id = v
        .get("id")
        .and_then(|x| x.as_f64())
        .map(|f| f as u64)
        .unwrap_or(fallback_id);
    let prompt = v
        .get("prompt")
        .and_then(|x| x.as_arr())
        .context("request missing 'prompt' array")?
        .iter()
        .map(|t| t.as_f64().map(|f| f as i32).context("bad token"))
        .collect::<Result<Vec<i32>>>()?;
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(default_max_new);
    Ok(Request {
        id,
        prompt,
        max_new_tokens,
    })
}

/// Render one completion line.
pub fn render_completion(c: &Completion) -> String {
    let finish = match c.finish {
        FinishReason::MaxTokens => "max_tokens",
        FinishReason::ContextFull => "context_full",
        FinishReason::Rejected => "rejected",
    };
    Json::obj(vec![
        ("id", Json::num(c.id as f64)),
        (
            "tokens",
            Json::Arr(c.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("prompt_len", Json::num(c.prompt_len as f64)),
        ("prefix_hit_pages", Json::num(c.prefix_hit_pages as f64)),
        ("ttft_us", Json::num(c.timing.ttft_us().unwrap_or(-1.0))),
        ("total_us", Json::num(c.timing.total_us().unwrap_or(-1.0))),
        ("finish", Json::str(finish)),
    ])
    .to_string()
}

/// Run the server until `stop` is set.
///
/// The PJRT client is `!Send`, so the *engine loop runs on the calling
/// thread*; the TCP acceptor and per-connection readers run on spawned
/// threads and feed requests through a channel.
pub fn serve(engine: Engine, bind: &str, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
    serve_on(engine, listener, stop)
}

/// [`serve`] on an already-bound listener (lets tests bind port 0 and
/// read the assigned address before starting the engine loop).
pub fn serve_on(mut engine: Engine, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
    listener.set_nonblocking(true)?;
    eprintln!(
        "isoquant: serving on {} (variant={}, bits={}, prefix_sharing={}, prefix_index={})",
        listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into()),
        engine.cfg.variant.name(),
        engine.cfg.bits,
        if engine.cfg.prefix_sharing { "on" } else { "off" },
        engine.cfg.prefix_index.name(),
    );

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    type Sinks = Arc<Mutex<HashMap<u64, TcpStream>>>;
    let sinks: Sinks = Arc::new(Mutex::new(HashMap::new()));
    let default_max_new = engine.cfg.max_new_tokens_default;

    // acceptor thread (TcpListener is Send; the engine is not)
    let stop_a = stop.clone();
    let sinks_a = sinks.clone();
    let acceptor = std::thread::Builder::new()
        .name("isoquant-acceptor".into())
        .spawn(move || {
            let next_id = Arc::new(AtomicU64::new(1));
            while !stop_a.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        let req_tx = req_tx.clone();
                        let sinks = sinks_a.clone();
                        let next_id = next_id.clone();
                        std::thread::spawn(move || {
                            let reader =
                                BufReader::new(stream.try_clone().expect("clone stream"));
                            for line in reader.lines() {
                                let Ok(line) = line else { break };
                                if line.trim().is_empty() {
                                    continue;
                                }
                                let fallback =
                                    next_id.fetch_add(1, Ordering::SeqCst) | (1 << 62);
                                match parse_request(&line, fallback, default_max_new) {
                                    Ok(req) => {
                                        sinks
                                            .lock()
                                            .unwrap()
                                            .insert(req.id, stream.try_clone().expect("clone"));
                                        if req_tx.send(req).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        let mut s = stream.try_clone().expect("clone");
                                        let _ = writeln!(
                                            s,
                                            "{}",
                                            Json::obj(vec![(
                                                "error",
                                                Json::str(format!("{e:#}"))
                                            )])
                                        );
                                    }
                                }
                            }
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })?;

    // engine loop on this thread.  Incoming requests pass through the
    // dynamic batcher, which holds them up to `batch_window_us` to form
    // fuller admission waves and stable-sorts each drained batch by
    // prompt — so same-prefix requests reach the engine adjacently and
    // adopt each other's pages before pool pressure can evict them.
    // The window is a *lanes-full* trade: while free lanes exist,
    // waiting buys nothing (the engine admits continuously), so the
    // idle-lane fast path below drains immediately and a lone request
    // on an idle server no longer eats the full window (~2 ms) of
    // time-to-first-token for nothing.
    let mut batcher = Batcher::new(
        std::time::Duration::from_micros(engine.cfg.batch_window_us),
        engine.cfg.max_batch.max(1),
    );
    let mut last_stats = std::time::Instant::now();
    let mut last_finished: u64 = 0;
    while !stop.load(Ordering::SeqCst) {
        while let Ok(r) = req_rx.try_recv() {
            batcher.submit(r);
        }
        // idle-lane fast path: lanes nothing is using can start
        // immediately; requests beyond the free-lane count keep
        // queueing so the window can still group them into one wave
        let idle = engine.free_lanes().saturating_sub(engine.pending());
        if idle > 0 && batcher.pending() > 0 {
            for r in batcher.take_up_to(idle) {
                engine.submit(r);
            }
        }
        if let Some(batch) = batcher.poll(std::time::Instant::now()) {
            for r in batch {
                engine.submit(r);
            }
        }
        let worked = engine.step()?;
        for c in engine.take_completions() {
            last_finished += 1;
            let line = render_completion(&c);
            if let Some(mut s) = sinks.lock().unwrap().remove(&c.id) {
                let _ = writeln!(s, "{line}");
            }
        }
        // periodic serve stats line (page residency, prefix sharing,
        // throughput) — only when something completed since last print
        if last_stats.elapsed() >= std::time::Duration::from_secs(5) {
            if last_finished > 0 {
                eprintln!("isoquant: {}", engine.stats_line());
                last_finished = 0;
            }
            last_stats = std::time::Instant::now();
        }
        if !worked {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    acceptor.join().expect("acceptor thread");
    Ok(())
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and block for its completion line.
    pub fn generate(&mut self, id: u64, prompt: &[i32], max_new: usize) -> Result<Json> {
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::num(max_new as f64)),
        ]);
        writeln!(self.stream, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).context("parse completion")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Timing;

    #[test]
    fn parse_request_full() {
        let r = parse_request(r#"{"id": 7, "prompt": [1,2,3], "max_new_tokens": 5}"#, 0, 32)
            .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"prompt": [4]}"#, 99, 32).unwrap();
        assert_eq!(r.id, 99);
        assert_eq!(r.max_new_tokens, 32);
    }

    #[test]
    fn parse_request_rejects_bad() {
        assert!(parse_request("not json", 0, 32).is_err());
        assert!(parse_request(r#"{"id": 1}"#, 0, 32).is_err());
    }

    #[test]
    fn completion_roundtrips_through_json() {
        let c = Completion {
            id: 3,
            tokens: vec![9, 8],
            prompt_len: 2,
            prefix_hit_pages: 5,
            timing: Timing::new(),
            finish: FinishReason::MaxTokens,
        };
        let line = render_completion(&c);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("prefix_hit_pages").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("max_tokens"));
    }
}
