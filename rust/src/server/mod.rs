//! TCP serving front-end: JSON-lines protocol over `std::net`.
//!
//! Request:  `{"id": 1, "prompt": [3, 17, 5], "max_new_tokens": 16}`
//!           (optional `"deadline_ms": 250` per-request deadline)
//! Response: `{"id": 1, "tokens": [...], "prompt_len": 3,
//!             "ttft_us": 1234.5, "total_us": 5678.9, "finish": "max_tokens"}`
//!
//! The listener thread parses requests into the engine's queue; the
//! engine thread runs `step()` continuously and pushes completions back
//! to the matching connection.  One in-flight request per connection
//! line keeps the protocol trivial while still exercising batched
//! multi-client serving (clients connect concurrently).
//!
//! # Request lifecycle
//!
//! Each connection's reader detects EOF/disconnect and routes
//! [`ServerMsg::Cancel`] for every request it submitted — a dead socket
//! frees its lane and pages within one engine step instead of decoding
//! to `max_new_tokens` for nobody.  With `[server] max_queue` set, the
//! admission queue is bounded and overflow is shed immediately with
//! `{"error":"overloaded","retry_after_ms":…}`.  With
//! `[server] request_timeout_ms` (or per-request `deadline_ms`) set,
//! expired requests finish with `finish: "timeout"`.  On stop/SIGINT
//! the listener closes, queued requests are shed, in-flight lanes
//! finish up to `[server] drain_timeout_ms`, and the page store is
//! flushed before the loop returns.  All knobs default off: the
//! default-config serve path behaves exactly as it did without them.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::{Batcher, Completion, Engine, FinishReason, Request};
use crate::metrics::ShareStats;
use crate::util::json::Json;

/// Control messages from connection readers to the engine loop.
pub enum ServerMsg {
    Submit(Request),
    /// the connection that submitted this request id is gone — free
    /// its queue slot / lane / pages; no response will be written
    Cancel(u64),
}

/// Extract a non-negative integer field (JSON numbers are f64: a
/// fractional or negative value is a malformed request, not something
/// to silently truncate).
fn json_u64(v: &Json, what: &str) -> Result<u64> {
    let f = v.as_f64().with_context(|| format!("{what} must be a number"))?;
    if !f.is_finite() || f.fract() != 0.0 || f < 0.0 || f > (1u64 << 53) as f64 {
        bail!("{what} must be a non-negative integer, got {f}");
    }
    Ok(f as u64)
}

/// Parse one request line.  `max_new_cap` bounds `max_new_tokens`
/// (requests asking for more than the engine could ever produce are
/// rejected here with a structured error instead of tying up a lane).
pub fn parse_request(
    line: &str,
    fallback_id: u64,
    default_max_new: usize,
    max_new_cap: usize,
) -> Result<Request> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    let id = match v.get("id") {
        None => fallback_id,
        Some(x) => json_u64(x, "'id'")?,
    };
    let prompt = v
        .get("prompt")
        .and_then(|x| x.as_arr())
        .context("request missing 'prompt' array")?
        .iter()
        .map(|t| {
            let t = json_u64(t, "prompt token")?;
            if t > i32::MAX as u64 {
                bail!("prompt token {t} out of range");
            }
            Ok(t as i32)
        })
        .collect::<Result<Vec<i32>>>()?;
    let max_new_tokens = match v.get("max_new_tokens") {
        None => default_max_new,
        Some(x) => {
            let n = json_u64(x, "'max_new_tokens'")? as usize;
            if n == 0 {
                bail!("'max_new_tokens' must be >= 1");
            }
            if max_new_cap > 0 && n > max_new_cap {
                bail!("'max_new_tokens' {n} exceeds the server cap {max_new_cap}");
            }
            n
        }
    };
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(x) => Some(json_u64(x, "'deadline_ms'")?),
    };
    Ok(Request {
        id,
        prompt,
        max_new_tokens,
        deadline_ms,
    })
}

/// Render one completion line.
pub fn render_completion(c: &Completion) -> String {
    let finish = match c.finish {
        FinishReason::MaxTokens => "max_tokens",
        FinishReason::ContextFull => "context_full",
        FinishReason::Rejected => "rejected",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Timeout => "timeout",
    };
    Json::obj(vec![
        ("id", Json::num(c.id as f64)),
        (
            "tokens",
            Json::Arr(c.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("prompt_len", Json::num(c.prompt_len as f64)),
        ("prefix_hit_pages", Json::num(c.prefix_hit_pages as f64)),
        ("ttft_us", Json::num(c.timing.ttft_us().unwrap_or(-1.0))),
        ("total_us", Json::num(c.timing.total_us().unwrap_or(-1.0))),
        ("finish", Json::str(finish)),
    ])
    .to_string()
}

/// The structured overload-shed response (`[server] max_queue`).
fn render_overloaded(retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("error", Json::str("overloaded")),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
    ])
    .to_string()
}

// ---------------------------------------------------------------------
// SIGINT → graceful drain
// ---------------------------------------------------------------------

static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigint(_sig: std::os::raw::c_int) {
    // async-signal-safe: a single atomic store
    SIGINT_FLAG.store(true, Ordering::SeqCst);
}

/// Route SIGINT (ctrl-C) into the serve loop's stop path so an
/// interactive shutdown drains gracefully (lanes finish, store
/// flushes) instead of killing the process mid-write.  No-op off unix.
pub fn install_sigint_handler() {
    #[cfg(unix)]
    {
        // the symbol lives in the platform libc std already links —
        // same idiom as the store's flock/mmap externs
        extern "C" {
            fn signal(signum: std::os::raw::c_int, handler: usize) -> usize;
        }
        const SIGINT: std::os::raw::c_int = 2;
        unsafe {
            signal(SIGINT, on_sigint as usize);
        }
    }
}

/// Has a SIGINT arrived since [`install_sigint_handler`]?
pub fn sigint_requested() -> bool {
    SIGINT_FLAG.load(Ordering::SeqCst)
}

/// Send-able end-of-serve snapshot (the engine itself is `!Send`):
/// lifecycle/sharing counters for smoke tests and benches to assert on.
#[derive(Debug)]
pub struct ServeReport {
    pub share: ShareStats,
    /// total requests submitted to the engine
    pub requests: u64,
    /// lanes still active when the drain window closed (0 on a clean
    /// drain)
    pub undrained_lanes: usize,
}

/// Run the server until `stop` is set (or SIGINT, when the handler is
/// installed).
///
/// The PJRT client is `!Send`, so the *engine loop runs on the calling
/// thread*; the TCP acceptor and per-connection readers run on spawned
/// threads and feed requests through a channel.
pub fn serve(engine: Engine, bind: &str, stop: Arc<AtomicBool>) -> Result<ServeReport> {
    let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
    serve_on(engine, listener, stop)
}

type Sinks = Arc<Mutex<HashMap<u64, TcpStream>>>;

/// Write `line` to the sink registered for `id` (if any) and drop the
/// sink entry — each request gets exactly one response line.
fn respond(sinks: &Sinks, id: u64, line: &str) {
    let sink = sinks
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .remove(&id);
    if let Some(mut s) = sink {
        let _ = writeln!(s, "{line}");
    }
}

/// [`serve`] on an already-bound listener (lets tests bind port 0 and
/// read the assigned address before starting the engine loop).
pub fn serve_on(
    mut engine: Engine,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<ServeReport> {
    listener.set_nonblocking(true)?;
    eprintln!(
        "isoquant: serving on {} (variant={}, bits={}, prefix_sharing={}, prefix_index={})",
        listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into()),
        engine.cfg.variant.name(),
        engine.cfg.bits,
        if engine.cfg.prefix_sharing { "on" } else { "off" },
        engine.cfg.prefix_index.name(),
    );

    let (req_tx, req_rx) = mpsc::channel::<ServerMsg>();
    let sinks: Sinks = Arc::new(Mutex::new(HashMap::new()));
    let default_max_new = engine.cfg.max_new_tokens_default;
    // a request can never produce more than max_seq tokens; asking for
    // more is a malformed request, answered at parse time
    let max_new_cap = engine.model.meta.max_seq;

    // acceptor thread (TcpListener is Send; the engine is not)
    let stop_a = stop.clone();
    let sinks_a = sinks.clone();
    let acceptor = std::thread::Builder::new()
        .name("isoquant-acceptor".into())
        .spawn(move || {
            let next_id = Arc::new(AtomicU64::new(1));
            while !stop_a.load(Ordering::SeqCst) && !sigint_requested() {
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        let req_tx = req_tx.clone();
                        let sinks = sinks_a.clone();
                        let next_id = next_id.clone();
                        // one bad socket must not take the acceptor
                        // down: a failed clone drops this connection
                        // and moves on
                        let Ok(read_half) = stream.try_clone() else {
                            continue;
                        };
                        std::thread::spawn(move || {
                            connection_reader(
                                stream,
                                read_half,
                                req_tx,
                                sinks,
                                next_id,
                                default_max_new,
                                max_new_cap,
                            );
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            // dropping the listener here closes the accept socket —
            // the first step of a graceful drain
        })?;

    // engine loop on this thread.  Incoming requests pass through the
    // dynamic batcher, which holds them up to `batch_window_us` to form
    // fuller admission waves and stable-sorts each drained batch by
    // prompt — so same-prefix requests reach the engine adjacently and
    // adopt each other's pages before pool pressure can evict them.
    // The window is a *lanes-full* trade: while free lanes exist,
    // waiting buys nothing (the engine admits continuously), so the
    // idle-lane fast path below drains immediately and a lone request
    // on an idle server no longer eats the full window (~2 ms) of
    // time-to-first-token for nothing.
    let mut batcher = Batcher::new(
        std::time::Duration::from_micros(engine.cfg.batch_window_us),
        engine.cfg.max_batch.max(1),
    );
    let max_queue = engine.cfg.max_queue;
    let mut last_stats = std::time::Instant::now();
    let mut last_finished: u64 = 0;
    while !stop.load(Ordering::SeqCst) && !sigint_requested() {
        while let Ok(msg) = req_rx.try_recv() {
            match msg {
                ServerMsg::Submit(r) => {
                    // bounded admission queue: overflow is shed with a
                    // structured error instead of growing without bound.
                    // Free lanes count as headroom — a burst on an idle
                    // server lands on lanes, not on the bound
                    let queued = batcher.pending() + engine.pending();
                    if max_queue > 0 && queued >= max_queue + engine.free_lanes() {
                        // a rough time-to-free-slot: one batching
                        // window per queued wave, floor 25ms
                        let retry = (engine.cfg.batch_window_us / 1_000).max(25);
                        respond(&sinks, r.id, &render_overloaded(retry));
                        engine.cache.share.requests_shed += 1;
                    } else {
                        batcher.submit(r);
                    }
                }
                ServerMsg::Cancel(id) => {
                    // still queued → drop; mid-flight → free the lane
                    // and its pages.  Unknown (already finished) → no-op
                    let dropped = batcher.cancel(id);
                    if dropped {
                        engine.cache.share.requests_cancelled += 1;
                    } else {
                        engine.cancel(id);
                    }
                    sinks
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .remove(&id);
                }
            }
        }
        // idle-lane fast path: lanes nothing is using can start
        // immediately; requests beyond the free-lane count keep
        // queueing so the window can still group them into one wave
        let idle = engine.free_lanes().saturating_sub(engine.pending());
        if idle > 0 && batcher.pending() > 0 {
            for r in batcher.take_up_to(idle) {
                engine.submit(r);
            }
        }
        if let Some(batch) = batcher.poll(std::time::Instant::now()) {
            for r in batch {
                engine.submit(r);
            }
        }
        let worked = engine.step()?;
        for c in engine.take_completions() {
            last_finished += 1;
            respond(&sinks, c.id, &render_completion(&c));
        }
        // periodic serve stats line (page residency, prefix sharing,
        // throughput) — only when something completed since last print
        if last_stats.elapsed() >= std::time::Duration::from_secs(5) {
            if last_finished > 0 {
                eprintln!("isoquant: {}", engine.stats_line());
                last_finished = 0;
            }
            last_stats = std::time::Instant::now();
        }
        if !worked {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    // ------------------------------------------------------------------
    // graceful drain: listener closed (acceptor exits on the stop
    // flag), queued requests shed, in-flight lanes finish up to
    // drain_timeout_ms, spill queue flushed — then return
    // ------------------------------------------------------------------
    let drain_deadline = std::time::Instant::now()
        + std::time::Duration::from_millis(engine.cfg.drain_timeout_ms);
    // shed everything not yet on a lane: these will never run
    for r in batcher.take_up_to(usize::MAX) {
        engine.submit(r);
    }
    while let Ok(msg) = req_rx.try_recv() {
        if let ServerMsg::Submit(r) = msg {
            engine.submit(r);
        }
    }
    // move just-arrived requests into the engine queue, then shed the
    // whole queue with definitive rejections (clients get an answer,
    // not a hang)
    let shed = engine.shed_waiting();
    let mut drained = true;
    while engine.active() > 0 {
        if std::time::Instant::now() >= drain_deadline {
            drained = false;
            break;
        }
        engine.step()?;
        for c in engine.take_completions() {
            respond(&sinks, c.id, &render_completion(&c));
        }
    }
    for c in engine.take_completions() {
        respond(&sinks, c.id, &render_completion(&c));
    }
    // everything spilled so far becomes durable before the process can
    // exit; a degraded store makes this a no-op
    engine.cache.flush_store();
    let undrained_lanes = engine.active();
    eprintln!(
        "isoquant: drained (shed={shed} undrained_lanes={undrained_lanes}) — {}",
        engine.stats_line()
    );
    acceptor.join().map_err(|_| {
        anyhow::anyhow!("acceptor thread panicked")
    })?;
    Ok(ServeReport {
        share: engine.cache.share.clone(),
        requests: crate::metrics::Counters::get(&engine.stats.counters.requests),
        undrained_lanes: if drained { 0 } else { undrained_lanes },
    })
}

/// Per-connection reader: parse request lines into the engine queue,
/// and on EOF/disconnect route a [`ServerMsg::Cancel`] for every id
/// this connection submitted — whatever is still queued or mid-decode
/// is freed, and no sink entry outlives its socket.
#[allow(clippy::too_many_arguments)]
fn connection_reader(
    stream: TcpStream,
    read_half: TcpStream,
    req_tx: mpsc::Sender<ServerMsg>,
    sinks: Sinks,
    next_id: Arc<AtomicU64>,
    default_max_new: usize,
    max_new_cap: usize,
) {
    let reader = BufReader::new(read_half);
    let mut submitted: Vec<u64> = Vec::new();
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let fallback = next_id.fetch_add(1, Ordering::SeqCst) | (1 << 62);
        match parse_request(&line, fallback, default_max_new, max_new_cap) {
            Ok(req) => {
                let Ok(sink) = stream.try_clone() else { break };
                sinks
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(req.id, sink);
                let id = req.id;
                if req_tx.send(ServerMsg::Submit(req)).is_err() {
                    break;
                }
                submitted.push(id);
            }
            Err(e) => {
                let Ok(mut s) = stream.try_clone() else { break };
                let _ = writeln!(
                    s,
                    "{}",
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))])
                );
            }
        }
    }
    // EOF / read error: the client is gone.  Cancel everything this
    // connection submitted (finished ids are no-ops) so no lane decodes
    // for a dead socket and no sink-map entry leaks
    for id in submitted {
        if req_tx.send(ServerMsg::Cancel(id)).is_err() {
            break;
        }
    }
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and block for its completion line.
    pub fn generate(&mut self, id: u64, prompt: &[i32], max_new: usize) -> Result<Json> {
        self.send(id, prompt, max_new, None)?;
        self.recv()
    }

    /// Fire a request without waiting for the response (disconnect /
    /// overload tests pipeline these).
    pub fn send(
        &mut self,
        id: u64,
        prompt: &[i32],
        max_new: usize,
        deadline_ms: Option<u64>,
    ) -> Result<()> {
        let mut fields = vec![
            ("id", Json::num(id as f64)),
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::num(max_new as f64)),
        ];
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        writeln!(self.stream, "{}", Json::obj(fields).to_string())?;
        Ok(())
    }

    /// Block for the next response line.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).context("parse completion")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Timing;

    #[test]
    fn parse_request_full() {
        let r = parse_request(
            r#"{"id": 7, "prompt": [1,2,3], "max_new_tokens": 5}"#,
            0,
            32,
            256,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"prompt": [4]}"#, 99, 32, 256).unwrap();
        assert_eq!(r.id, 99);
        assert_eq!(r.max_new_tokens, 32);
    }

    #[test]
    fn parse_request_deadline() {
        let r = parse_request(r#"{"prompt": [4], "deadline_ms": 250}"#, 1, 32, 256).unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        assert!(parse_request(r#"{"prompt": [4], "deadline_ms": -5}"#, 1, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [4], "deadline_ms": 0.5}"#, 1, 32, 256).is_err());
    }

    #[test]
    fn parse_request_rejects_bad() {
        assert!(parse_request("not json", 0, 32, 256).is_err());
        assert!(parse_request(r#"{"id": 1}"#, 0, 32, 256).is_err());
    }

    #[test]
    fn parse_request_rejects_bad_tokens() {
        // negative, fractional, and out-of-range token ids are
        // malformed requests, not values to silently cast
        assert!(parse_request(r#"{"prompt": [1, -2, 3]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [1.5]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [3000000000]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": ["a"]}"#, 0, 32, 256).is_err());
        // negative / fractional ids too
        assert!(parse_request(r#"{"id": -1, "prompt": [1]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"id": 1.5, "prompt": [1]}"#, 0, 32, 256).is_err());
    }

    #[test]
    fn parse_request_caps_max_new_tokens() {
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": 0}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": 257}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": -4}"#, 0, 32, 256).is_err());
        let r = parse_request(r#"{"prompt": [1], "max_new_tokens": 256}"#, 0, 32, 256).unwrap();
        assert_eq!(r.max_new_tokens, 256);
        // cap 0 = uncapped
        let r = parse_request(r#"{"prompt": [1], "max_new_tokens": 9999}"#, 0, 32, 0).unwrap();
        assert_eq!(r.max_new_tokens, 9999);
    }

    #[test]
    fn completion_roundtrips_through_json() {
        let c = Completion {
            id: 3,
            tokens: vec![9, 8],
            prompt_len: 2,
            prefix_hit_pages: 5,
            timing: Timing::new(),
            finish: FinishReason::MaxTokens,
        };
        let line = render_completion(&c);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("prefix_hit_pages").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("max_tokens"));
    }

    #[test]
    fn timeout_and_cancelled_render() {
        let mut c = Completion {
            id: 1,
            tokens: vec![],
            prompt_len: 1,
            prefix_hit_pages: 0,
            timing: Timing::new(),
            finish: FinishReason::Timeout,
        };
        assert!(render_completion(&c).contains(r#""finish": "timeout""#));
        c.finish = FinishReason::Cancelled;
        assert!(render_completion(&c).contains(r#""finish": "cancelled""#));
        let v = Json::parse(&render_overloaded(25)).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("retry_after_ms").unwrap().as_usize(), Some(25));
    }
}
