//! TCP serving front-end: JSON-lines protocol over `std::net`, served
//! by a readiness-driven reactor (epoll on Linux, `poll(2)` elsewhere
//! on unix — see [`poller`]).
//!
//! Request:  `{"id": 1, "prompt": [3, 17, 5], "max_new_tokens": 16}`
//!           (optional `"deadline_ms": 250` per-request deadline,
//!           optional `"stream": true` for token-by-token responses,
//!           optional `"trace": true` for a lifecycle timeline on the
//!           completion line)
//! Response: `{"id": 1, "tokens": [...], "prompt_len": 3,
//!             "ttft_us": 1234.5, "total_us": 5678.9, "finish": "max_tokens"}`
//!
//! With `"stream": true` the terminal line above is preceded by one
//! line per generated token: `{"id": 1, "index": 0, "token": 42}`.
//! A `{"stats": true}` line is answered with the counter / latency
//! snapshot ([`render_stats`]) without touching a lane; add
//! `"traces": K` to include the flight recorder's last K request
//! timelines.  Non-streaming clients see byte-identical behavior to
//! the pre-reactor server.
//!
//! # Observability
//!
//! The same listener also answers HTTP: a line starting with `"GET "`
//! flips the connection into HTTP mode and `GET /metrics` returns
//! Prometheus text exposition rendered from the engine's last metrics
//! snapshot (refreshed about once a second by the engine loop) — a
//! scrape never touches the engine queue.  `[server] metrics_addr`
//! optionally opens a second, metrics-only listener on the same
//! reactor.  `[server] log_level` / `log_json` control the leveled
//! logger ([`crate::util::log`]) that all server output goes through.
//!
//! One reactor thread owns the listener and all client sockets
//! (non-blocking, one event loop — no thread per connection, no accept
//! or idle sleeps); the engine loop runs on the calling thread (the
//! PJRT client is `!Send`) and blocks on its request channel when
//! fully idle.  The two meet over mpsc channels plus a
//! [`poller::Waker`] that interrupts the reactor's wait when responses
//! are ready.
//!
//! # Request lifecycle
//!
//! EOF/disconnect on a connection routes [`ServerMsg::Cancel`] for
//! every request it submitted — a dead socket frees its lane and pages
//! within one engine step instead of decoding to `max_new_tokens` for
//! nobody (mid-stream disconnects included).  With `[server]
//! max_queue` set, the admission queue is bounded and overflow is shed
//! immediately with `{"error":"overloaded","retry_after_ms":…}`.  With
//! `[server] request_timeout_ms` (or per-request `deadline_ms`) set,
//! expired requests finish with `finish: "timeout"` — mid-stream, the
//! partial token lines precede it.  `[server] max_conn_buffer_kb`
//! bounds per-connection buffering; slow readers are disconnected
//! rather than buffered without limit.  On stop/SIGINT the listener
//! closes, queued requests are shed, in-flight lanes finish up to
//! `[server] drain_timeout_ms`, and the page store is flushed before
//! the loop returns.  All knobs default off or safe: the
//! default-config serve path behaves exactly as it did without them.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{
    Batcher, Completion, Engine, FinishReason, Request, Timing, TokenEvent, TraceRecord,
};
use crate::log_info;
use crate::metrics::prometheus::render_prometheus;
use crate::metrics::{Histogram, ShareStats};
use crate::util::json::Json;

pub mod poller;
mod reactor;

use reactor::{Outbound, Reactor, ReactorOpts};

/// Control messages from the reactor to the engine loop.
pub enum ServerMsg {
    Submit(Request),
    /// the connection that submitted this request id is gone — free
    /// its queue slot / lane / pages; no response will be written
    Cancel(u64),
    /// a `{"stats": true}` request: answer `id` with [`render_stats`];
    /// `traces` > 0 appends the flight recorder's last K timelines
    Stats { id: u64, traces: usize },
}

/// Extract a non-negative integer field (JSON numbers are f64: a
/// fractional or negative value is a malformed request, not something
/// to silently truncate).
fn json_u64(v: &Json, what: &str) -> Result<u64> {
    let f = v.as_f64().with_context(|| format!("{what} must be a number"))?;
    if !f.is_finite() || f.fract() != 0.0 || f < 0.0 || f > (1u64 << 53) as f64 {
        bail!("{what} must be a non-negative integer, got {f}");
    }
    Ok(f as u64)
}

/// Parse one request line.  `max_new_cap` bounds `max_new_tokens`
/// (requests asking for more than the engine could ever produce are
/// rejected here with a structured error instead of tying up a lane).
pub fn parse_request(
    line: &str,
    fallback_id: u64,
    default_max_new: usize,
    max_new_cap: usize,
) -> Result<Request> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    let id = match v.get("id") {
        None => fallback_id,
        Some(x) => json_u64(x, "'id'")?,
    };
    let prompt = v
        .get("prompt")
        .and_then(|x| x.as_arr())
        .context("request missing 'prompt' array")?
        .iter()
        .map(|t| {
            let t = json_u64(t, "prompt token")?;
            if t > i32::MAX as u64 {
                bail!("prompt token {t} out of range");
            }
            Ok(t as i32)
        })
        .collect::<Result<Vec<i32>>>()?;
    let max_new_tokens = match v.get("max_new_tokens") {
        None => default_max_new,
        Some(x) => {
            let n = json_u64(x, "'max_new_tokens'")? as usize;
            if n == 0 {
                bail!("'max_new_tokens' must be >= 1");
            }
            if max_new_cap > 0 && n > max_new_cap {
                bail!("'max_new_tokens' {n} exceeds the server cap {max_new_cap}");
            }
            n
        }
    };
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(x) => Some(json_u64(x, "'deadline_ms'")?),
    };
    let stream = match v.get("stream") {
        None => false,
        Some(x) => x.as_bool().context("'stream' must be a boolean")?,
    };
    let trace = match v.get("trace") {
        None => false,
        Some(x) => x.as_bool().context("'trace' must be a boolean")?,
    };
    Ok(Request {
        id,
        prompt,
        max_new_tokens,
        deadline_ms,
        stream,
        trace,
        received_at: None,
        parsed_at: None,
    })
}

/// The per-request timeline as a JSON object: every lifecycle stamp as
/// a µs offset from the trace origin (`received` when the reactor
/// stamped it, else `queued`), `-1` for stamps the request never
/// reached, plus the outcome and page accounting.  Shared by the
/// completion line's `"trace"` field and the stats `"traces"` dump.
fn trace_json(
    timing: &Timing,
    outcome: &str,
    pages_reused: usize,
    pages_allocated: usize,
) -> Json {
    let origin = timing.origin();
    let stamp = |t: Option<Instant>| {
        Json::num(t.map_or(-1.0, |t| (t - origin).as_secs_f64() * 1e6))
    };
    Json::obj(vec![
        ("received", stamp(timing.received)),
        ("parsed", stamp(timing.parsed)),
        ("queued", stamp(Some(timing.submitted))),
        ("admitted", stamp(timing.admitted)),
        ("prefix_walk", stamp(timing.prefix_walk)),
        ("prefill_done", stamp(timing.prefill_done)),
        ("first_token", stamp(timing.first_token)),
        ("finished", stamp(timing.finished)),
        ("outcome", Json::str(outcome)),
        ("pages_reused", Json::num(pages_reused as f64)),
        ("pages_allocated", Json::num(pages_allocated as f64)),
    ])
}

/// Render one completion line.  The `"trace"` field appears only when
/// the request opted in — without it the line is byte-identical to the
/// pre-observability protocol.
pub fn render_completion(c: &Completion) -> String {
    let mut fields = vec![
        ("id", Json::num(c.id as f64)),
        (
            "tokens",
            Json::Arr(c.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("prompt_len", Json::num(c.prompt_len as f64)),
        ("prefix_hit_pages", Json::num(c.prefix_hit_pages as f64)),
        ("ttft_us", Json::num(c.timing.ttft_us().unwrap_or(-1.0))),
        ("total_us", Json::num(c.timing.total_us().unwrap_or(-1.0))),
        ("finish", Json::str(c.finish.as_str())),
    ];
    if c.trace {
        fields.push((
            "trace",
            trace_json(
                &c.timing,
                c.finish.as_str(),
                c.prefix_hit_pages,
                c.pages_allocated,
            ),
        ));
    }
    Json::obj(fields).to_string()
}

/// Render one streamed-token line (`"stream": true` requests get one
/// of these per generated token, ahead of the terminal
/// [`render_completion`] line).
pub fn render_token(t: &TokenEvent) -> String {
    Json::obj(vec![
        ("id", Json::num(t.id as f64)),
        ("index", Json::num(t.index as f64)),
        ("token", Json::num(t.token as f64)),
    ])
    .to_string()
}

/// The structured overload-shed response (`[server] max_queue`).
fn render_overloaded(retry_after_ms: u64) -> String {
    Json::obj(vec![
        ("error", Json::str("overloaded")),
        ("retry_after_ms", Json::num(retry_after_ms as f64)),
    ])
    .to_string()
}

fn latency_json(h: &Histogram) -> Json {
    // percentile() is NaN on an empty histogram; -1 is the protocol's
    // "not measured" marker (same convention as ttft_us).  One snapshot
    // serves all three percentile walks — the query is O(buckets), not
    // O(samples), no matter how long the server has been up.
    let s = h.snapshot();
    let pct = |p: f64| {
        let v = s.percentile(p);
        Json::num(if v.is_nan() { -1.0 } else { v })
    };
    Json::obj(vec![
        ("n", Json::num(s.count() as f64)),
        ("p50_us", pct(50.0)),
        ("p95_us", pct(95.0)),
        ("p99_us", pct(99.0)),
    ])
}

/// The `{"stats": true}` response: the full [`ShareStats`] counter set
/// and engine throughput counters (both iterated from their field
/// tables, so a newly added counter appears here without a second
/// edit), page residency, the per-request latency distributions, the
/// step profiler (when `[engine] profile = on`), and — with
/// `"traces": K` — the flight recorder's last K request timelines.
pub fn render_stats(engine: &Engine, conn_overflow_disconnects: u64, traces: usize) -> String {
    let share_obj = Json::obj(
        engine
            .cache
            .share
            .fields()
            .into_iter()
            .map(|(n, v)| (n, Json::num(v as f64)))
            .collect(),
    );
    let counters_obj = Json::obj(
        engine
            .stats
            .counters
            .fields()
            .into_iter()
            .map(|(n, v)| (n, Json::num(v as f64)))
            .collect(),
    );
    let mut latency = vec![
        ("ttft_us", latency_json(&engine.stats.ttft)),
        ("inter_token_us", latency_json(&engine.stats.inter_token)),
        ("queue_wait_us", latency_json(&engine.stats.queue_wait)),
        ("request_total_us", latency_json(&engine.stats.request_total)),
    ];
    if let Some(p) = &engine.stats.profile {
        latency.push((
            "engine_phases_us",
            Json::obj(
                p.named()
                    .into_iter()
                    .map(|(n, h)| (n, latency_json(h)))
                    .collect(),
            ),
        ));
    }
    let mut fields = vec![
        ("stats", Json::Bool(true)),
        ("share", share_obj),
        ("counters", counters_obj),
        (
            "pages",
            Json::obj(vec![
                ("live", Json::num(engine.cache.live_pages() as f64)),
                ("cached", Json::num(engine.cache.cached_pages() as f64)),
                ("capacity", Json::num(engine.cache.page_capacity() as f64)),
                ("high_water", Json::num(engine.cache.high_water_pages() as f64)),
                ("shared", Json::num(engine.cache.shared_pages() as f64)),
                ("exclusive", Json::num(engine.cache.exclusive_pages() as f64)),
            ]),
        ),
        ("latency", Json::obj(latency)),
        (
            "server",
            Json::obj(vec![(
                "conn_overflow_disconnects",
                Json::num(conn_overflow_disconnects as f64),
            )]),
        ),
    ];
    if traces > 0 {
        fields.push((
            "traces",
            Json::Arr(
                engine
                    .recent_traces(traces)
                    .iter()
                    .map(|t: &TraceRecord| {
                        let mut o = trace_json(&t.timing, t.outcome, t.pages_reused, t.pages_allocated);
                        if let Json::Obj(m) = &mut o {
                            m.insert("id".into(), Json::num(t.id as f64));
                            m.insert("prompt_len".into(), Json::num(t.prompt_len as f64));
                            m.insert(
                                "tokens_generated".into(),
                                Json::num(t.tokens_generated as f64),
                            );
                        }
                        o
                    })
                    .collect(),
            ),
        ));
    }
    Json::obj(fields).to_string()
}

// ---------------------------------------------------------------------
// SIGINT → graceful drain
// ---------------------------------------------------------------------

static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigint(_sig: std::os::raw::c_int) {
    // async-signal-safe: a single atomic store
    SIGINT_FLAG.store(true, Ordering::SeqCst);
}

/// Route SIGINT (ctrl-C) into the serve loop's stop path so an
/// interactive shutdown drains gracefully (lanes finish, store
/// flushes) instead of killing the process mid-write.  No-op off unix.
pub fn install_sigint_handler() {
    #[cfg(unix)]
    {
        // the symbol lives in the platform libc std already links —
        // same idiom as the store's flock/mmap externs
        extern "C" {
            fn signal(signum: std::os::raw::c_int, handler: usize) -> usize;
        }
        const SIGINT: std::os::raw::c_int = 2;
        unsafe {
            signal(SIGINT, on_sigint as usize);
        }
    }
}

/// Has a SIGINT arrived since [`install_sigint_handler`]?
pub fn sigint_requested() -> bool {
    SIGINT_FLAG.load(Ordering::SeqCst)
}

/// Send-able end-of-serve snapshot (the engine itself is `!Send`):
/// lifecycle/sharing counters for smoke tests and benches to assert on.
#[derive(Debug)]
pub struct ServeReport {
    pub share: ShareStats,
    /// total requests submitted to the engine
    pub requests: u64,
    /// lanes still active when the drain window closed (0 on a clean
    /// drain)
    pub undrained_lanes: usize,
    /// connections dropped by the `[server] max_conn_buffer_kb` policy
    /// (slow readers with an over-cap output backlog, or oversized
    /// unterminated request lines)
    pub conn_overflow_disconnects: u64,
}

/// Run the server until `stop` is set (or SIGINT, when the handler is
/// installed).
///
/// The PJRT client is `!Send`, so the *engine loop runs on the calling
/// thread*; the reactor (listener + all client sockets, one event
/// loop) runs on a spawned thread and exchanges requests/responses
/// through channels.
pub fn serve(engine: Engine, bind: &str, stop: Arc<AtomicBool>) -> Result<ServeReport> {
    let listener = TcpListener::bind(bind).with_context(|| format!("bind {bind}"))?;
    serve_on(engine, listener, stop)
}

/// One engine-loop pass over a control message.  Shed/stats replies go
/// straight to the reactor (with a wake) — they never touch a lane.
fn handle_msg(
    msg: ServerMsg,
    engine: &mut Engine,
    batcher: &mut Batcher,
    out_tx: &mpsc::Sender<Outbound>,
    wake: &poller::WakeHandle,
    max_queue: usize,
    overflow: &AtomicU64,
) {
    match msg {
        ServerMsg::Submit(r) => {
            // bounded admission queue: overflow is shed with a
            // structured error instead of growing without bound.
            // Free lanes count as headroom — a burst on an idle
            // server lands on lanes, not on the bound
            let queued = batcher.pending() + engine.pending();
            if max_queue > 0 && queued >= max_queue + engine.free_lanes() {
                // a rough time-to-free-slot: one batching
                // window per queued wave, floor 25ms
                let retry = (engine.cfg.batch_window_us / 1_000).max(25);
                let _ = out_tx.send(Outbound::Line {
                    id: r.id,
                    text: render_overloaded(retry),
                    last: true,
                });
                wake.wake();
                engine.record_shed(&r);
                engine.cache.share.requests_shed += 1;
            } else {
                batcher.submit(r);
            }
        }
        ServerMsg::Cancel(id) => {
            // still queued → drop; mid-flight → free the lane
            // and its pages.  Unknown (already finished) → no-op
            let dropped = batcher.cancel(id);
            if dropped {
                engine.cache.share.requests_cancelled += 1;
            } else {
                engine.cancel(id);
            }
        }
        ServerMsg::Stats { id, traces } => {
            let _ = out_tx.send(Outbound::Line {
                id,
                text: render_stats(engine, overflow.load(Ordering::Relaxed), traces),
                last: true,
            });
            wake.wake();
        }
    }
}

/// [`serve`] on an already-bound listener (lets tests bind port 0 and
/// read the assigned address before starting the engine loop).
pub fn serve_on(
    mut engine: Engine,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<ServeReport> {
    log_info!(
        "serving on {} (variant={}, bits={}, prefix_sharing={}, prefix_index={})",
        listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into()),
        engine.cfg.variant.name(),
        engine.cfg.bits,
        if engine.cfg.prefix_sharing { "on" } else { "off" },
        engine.cfg.prefix_index.name(),
    );

    let (req_tx, req_rx) = mpsc::channel::<ServerMsg>();
    let (out_tx, out_rx) = mpsc::channel::<Outbound>();
    let overflow = Arc::new(AtomicU64::new(0));
    // `/metrics` text, rendered by this loop, served by the reactor —
    // populated before the reactor can accept its first scrape
    let render_metrics = |engine: &Engine, overflow: &AtomicU64| {
        let mut snap = engine.metrics_snapshot();
        snap.conn_overflow_disconnects = overflow.load(Ordering::Relaxed);
        render_prometheus(&snap)
    };
    let metrics_text = Arc::new(Mutex::new(render_metrics(&engine, &overflow)));
    let metrics_listener = if engine.cfg.metrics_addr.is_empty() {
        None
    } else {
        let l = TcpListener::bind(&engine.cfg.metrics_addr)
            .with_context(|| format!("bind metrics_addr {}", engine.cfg.metrics_addr))?;
        log_info!(
            "metrics on http://{}/metrics",
            l.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into())
        );
        Some(l)
    };
    let opts = ReactorOpts {
        default_max_new: engine.cfg.max_new_tokens_default,
        // a request can never produce more than max_seq tokens; asking
        // for more is a malformed request, answered at parse time
        max_new_cap: engine.model.meta.max_seq,
        max_conn_buffer: engine.cfg.max_conn_buffer_kb.saturating_mul(1024),
        metrics: metrics_text.clone(),
        metrics_listener,
    };
    let (reactor, wake) =
        Reactor::new(listener, req_tx, out_rx, stop.clone(), opts, overflow.clone())?;
    let reactor_thread = std::thread::Builder::new()
        .name("isoquant-reactor".into())
        .spawn(move || reactor.run())?;

    // engine loop on this thread.  Incoming requests pass through the
    // dynamic batcher, which holds them up to `batch_window_us` to form
    // fuller admission waves and stable-sorts each drained batch by
    // prompt — so same-prefix requests reach the engine adjacently and
    // adopt each other's pages before pool pressure can evict them.
    // The window is a *lanes-full* trade: while free lanes exist,
    // waiting buys nothing (the engine admits continuously), so the
    // idle-lane fast path below drains immediately and a lone request
    // on an idle server no longer eats the full window (~2 ms) of
    // time-to-first-token for nothing.
    let mut batcher = Batcher::new(
        Duration::from_micros(engine.cfg.batch_window_us),
        engine.cfg.max_batch.max(1),
    );
    let max_queue = engine.cfg.max_queue;
    let mut last_stats = Instant::now();
    let mut last_metrics = Instant::now();
    let mut last_finished: u64 = 0;
    // set after any step that left nothing active, waiting, or batched:
    // the next pass may block on the channel instead of spinning
    let mut quiescent = true;
    while !stop.load(Ordering::SeqCst) && !sigint_requested() {
        // event-driven idle: a fully idle engine blocks here (bounded,
        // to re-check the stop flag) instead of the old 200 µs poll
        if quiescent {
            match req_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(msg) => handle_msg(
                    msg,
                    &mut engine,
                    &mut batcher,
                    &out_tx,
                    &wake,
                    max_queue,
                    &overflow,
                ),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break, // reactor died
            }
        }
        while let Ok(msg) = req_rx.try_recv() {
            handle_msg(
                msg,
                &mut engine,
                &mut batcher,
                &out_tx,
                &wake,
                max_queue,
                &overflow,
            );
        }
        // idle-lane fast path: lanes nothing is using can start
        // immediately; requests beyond the free-lane count keep
        // queueing so the window can still group them into one wave
        let idle = engine.free_lanes().saturating_sub(engine.pending());
        if idle > 0 && batcher.pending() > 0 {
            // under pool pressure, admit the requests that re-use the
            // deepest cached prefixes first — they cost the fewest
            // fresh pages and keep hot stems from being evicted for
            // cold prompts; otherwise plain FIFO
            let batch = if engine.cache_pressure() {
                batcher.take_up_to_by_lcp(idle, |p| engine.cached_lcp(p))
            } else {
                batcher.take_up_to(idle)
            };
            for r in batch {
                engine.submit(r);
            }
        }
        if let Some(batch) = batcher.poll(Instant::now()) {
            for r in batch {
                engine.submit(r);
            }
        }
        let worked = engine.step()?;
        let mut emitted = false;
        // token lines first, so a stream's terminal completion is
        // always its connection's last line for that id
        for t in engine.take_token_events() {
            let _ = out_tx.send(Outbound::Line {
                id: t.id,
                text: render_token(&t),
                last: false,
            });
            emitted = true;
        }
        for c in engine.take_completions() {
            last_finished += 1;
            let _ = out_tx.send(Outbound::Line {
                id: c.id,
                text: render_completion(&c),
                last: true,
            });
            emitted = true;
        }
        if emitted {
            wake.wake();
        }
        // periodic serve stats line (page residency, prefix sharing,
        // throughput) — only when something completed since last print
        if last_stats.elapsed() >= Duration::from_secs(5) {
            if last_finished > 0 {
                log_info!("{}", engine.stats_line());
                last_finished = 0;
            }
            last_stats = Instant::now();
        }
        // refresh the `/metrics` text about once a second: scrapes are
        // served from this string by the reactor, so a slow or hostile
        // scraper can never stall the engine
        if last_metrics.elapsed() >= Duration::from_secs(1) {
            let text = render_metrics(&engine, &overflow);
            *metrics_text.lock().unwrap() = text;
            last_metrics = Instant::now();
        }
        quiescent = !worked && batcher.pending() == 0;
    }

    // ------------------------------------------------------------------
    // graceful drain: the reactor closes the listener on the stop flag,
    // queued requests are shed, in-flight lanes finish up to
    // drain_timeout_ms (event-driven: the loop below *steps the
    // engine*, it never sleeps), spill queue flushed — then return
    // ------------------------------------------------------------------
    wake.wake(); // nudge the reactor to notice the stop flag promptly
    let drain_deadline =
        Instant::now() + Duration::from_millis(engine.cfg.drain_timeout_ms);
    // shed everything not yet on a lane: these will never run
    for r in batcher.take_up_to(usize::MAX) {
        engine.submit(r);
    }
    while let Ok(msg) = req_rx.try_recv() {
        match msg {
            ServerMsg::Submit(r) => engine.submit(r),
            ServerMsg::Cancel(id) => {
                engine.cancel(id);
            }
            ServerMsg::Stats { id, traces } => {
                let _ = out_tx.send(Outbound::Line {
                    id,
                    text: render_stats(&engine, overflow.load(Ordering::Relaxed), traces),
                    last: true,
                });
            }
        }
    }
    // move just-arrived requests into the engine queue, then shed the
    // whole queue with definitive rejections (clients get an answer,
    // not a hang)
    let shed = engine.shed_waiting();
    let mut drained = true;
    while engine.active() > 0 {
        if Instant::now() >= drain_deadline {
            drained = false;
            break;
        }
        engine.step()?;
        // late traffic still gets definitive answers mid-drain: the
        // listener is closed, so a submit that raced it is rejected
        // immediately instead of left hanging; cancels free lanes
        while let Ok(msg) = req_rx.try_recv() {
            match msg {
                ServerMsg::Submit(r) => {
                    let mut timing = Timing::new();
                    timing.received = r.received_at;
                    timing.parsed = r.parsed_at;
                    timing.finished = Some(Instant::now());
                    engine.record_shed(&r);
                    let c = Completion {
                        id: r.id,
                        tokens: Vec::new(),
                        prompt_len: r.prompt.len(),
                        prefix_hit_pages: 0,
                        pages_allocated: 0,
                        timing,
                        finish: FinishReason::Rejected,
                        trace: r.trace,
                    };
                    let _ = out_tx.send(Outbound::Line {
                        id: c.id,
                        text: render_completion(&c),
                        last: true,
                    });
                    engine.cache.share.requests_shed += 1;
                }
                ServerMsg::Cancel(id) => {
                    engine.cancel(id);
                }
                ServerMsg::Stats { id, traces } => {
                    let _ = out_tx.send(Outbound::Line {
                        id,
                        text: render_stats(&engine, overflow.load(Ordering::Relaxed), traces),
                        last: true,
                    });
                }
            }
        }
        let mut emitted = false;
        for t in engine.take_token_events() {
            let _ = out_tx.send(Outbound::Line {
                id: t.id,
                text: render_token(&t),
                last: false,
            });
            emitted = true;
        }
        for c in engine.take_completions() {
            let _ = out_tx.send(Outbound::Line {
                id: c.id,
                text: render_completion(&c),
                last: true,
            });
            emitted = true;
        }
        if emitted {
            wake.wake();
        }
    }
    for t in engine.take_token_events() {
        let _ = out_tx.send(Outbound::Line {
            id: t.id,
            text: render_token(&t),
            last: false,
        });
    }
    for c in engine.take_completions() {
        let _ = out_tx.send(Outbound::Line {
            id: c.id,
            text: render_completion(&c),
            last: true,
        });
    }
    // everything spilled so far becomes durable before the process can
    // exit; a degraded store makes this a no-op
    engine.cache.flush_store();
    let undrained_lanes = engine.active();
    log_info!(
        "drained (shed={shed} undrained_lanes={undrained_lanes}) — {}",
        engine.stats_line()
    );
    let _ = out_tx.send(Outbound::Shutdown);
    wake.wake();
    reactor_thread
        .join()
        .map_err(|_| anyhow::anyhow!("reactor thread panicked"))?;
    Ok(ServeReport {
        share: engine.cache.share.clone(),
        requests: crate::metrics::Counters::get(&engine.stats.counters.requests),
        undrained_lanes: if drained { 0 } else { undrained_lanes },
        conn_overflow_disconnects: overflow.load(Ordering::Relaxed),
    })
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request and block for its completion line.
    pub fn generate(&mut self, id: u64, prompt: &[i32], max_new: usize) -> Result<Json> {
        self.send(id, prompt, max_new, None)?;
        self.recv()
    }

    /// Fire a request without waiting for the response (disconnect /
    /// overload tests pipeline these).
    pub fn send(
        &mut self,
        id: u64,
        prompt: &[i32],
        max_new: usize,
        deadline_ms: Option<u64>,
    ) -> Result<()> {
        let mut fields = vec![
            ("id", Json::num(id as f64)),
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::num(max_new as f64)),
        ];
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        writeln!(self.stream, "{}", Json::obj(fields).to_string())?;
        Ok(())
    }

    /// Send a raw request line as-is (streaming / stats tests build
    /// their own JSON).
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.stream, "{line}")?;
        Ok(())
    }

    /// Block for the next response line.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).context("parse completion")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Timing;

    #[test]
    fn parse_request_full() {
        let r = parse_request(
            r#"{"id": 7, "prompt": [1,2,3], "max_new_tokens": 5}"#,
            0,
            32,
            256,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.deadline_ms, None);
        assert!(!r.stream);
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"prompt": [4]}"#, 99, 32, 256).unwrap();
        assert_eq!(r.id, 99);
        assert_eq!(r.max_new_tokens, 32);
    }

    #[test]
    fn parse_request_deadline() {
        let r = parse_request(r#"{"prompt": [4], "deadline_ms": 250}"#, 1, 32, 256).unwrap();
        assert_eq!(r.deadline_ms, Some(250));
        assert!(parse_request(r#"{"prompt": [4], "deadline_ms": -5}"#, 1, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [4], "deadline_ms": 0.5}"#, 1, 32, 256).is_err());
    }

    #[test]
    fn parse_request_stream_flag() {
        let r = parse_request(r#"{"prompt": [4], "stream": true}"#, 1, 32, 256).unwrap();
        assert!(r.stream);
        let r = parse_request(r#"{"prompt": [4], "stream": false}"#, 1, 32, 256).unwrap();
        assert!(!r.stream);
        // strict: only a boolean is a streaming opt-in
        assert!(parse_request(r#"{"prompt": [4], "stream": 1}"#, 1, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [4], "stream": "yes"}"#, 1, 32, 256).is_err());
    }

    #[test]
    fn parse_request_trace_flag() {
        let r = parse_request(r#"{"prompt": [4], "trace": true}"#, 1, 32, 256).unwrap();
        assert!(r.trace);
        let r = parse_request(r#"{"prompt": [4]}"#, 1, 32, 256).unwrap();
        assert!(!r.trace);
        // strict: only a boolean opts in, same as "stream"
        assert!(parse_request(r#"{"prompt": [4], "trace": 1}"#, 1, 32, 256).is_err());
    }

    #[test]
    fn parse_request_rejects_bad() {
        assert!(parse_request("not json", 0, 32, 256).is_err());
        assert!(parse_request(r#"{"id": 1}"#, 0, 32, 256).is_err());
    }

    #[test]
    fn parse_request_rejects_bad_tokens() {
        // negative, fractional, and out-of-range token ids are
        // malformed requests, not values to silently cast
        assert!(parse_request(r#"{"prompt": [1, -2, 3]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [1.5]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [3000000000]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": ["a"]}"#, 0, 32, 256).is_err());
        // negative / fractional ids too
        assert!(parse_request(r#"{"id": -1, "prompt": [1]}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"id": 1.5, "prompt": [1]}"#, 0, 32, 256).is_err());
    }

    #[test]
    fn parse_request_caps_max_new_tokens() {
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": 0}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": 257}"#, 0, 32, 256).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": -4}"#, 0, 32, 256).is_err());
        let r = parse_request(r#"{"prompt": [1], "max_new_tokens": 256}"#, 0, 32, 256).unwrap();
        assert_eq!(r.max_new_tokens, 256);
        // cap 0 = uncapped
        let r = parse_request(r#"{"prompt": [1], "max_new_tokens": 9999}"#, 0, 32, 0).unwrap();
        assert_eq!(r.max_new_tokens, 9999);
    }

    #[test]
    fn completion_roundtrips_through_json() {
        let c = Completion {
            id: 3,
            tokens: vec![9, 8],
            prompt_len: 2,
            prefix_hit_pages: 5,
            pages_allocated: 2,
            timing: Timing::new(),
            finish: FinishReason::MaxTokens,
            trace: false,
        };
        let line = render_completion(&c);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("prefix_hit_pages").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("max_tokens"));
        // no trace opt-in → no trace field, and no other new keys
        assert!(v.get("trace").is_none());
        assert!(v.get("pages_allocated").is_none());
    }

    #[test]
    fn completion_trace_field_renders_timeline() {
        let mut timing = Timing::new();
        let base = timing.submitted;
        timing.received = Some(base - Duration::from_micros(40));
        timing.parsed = Some(base - Duration::from_micros(20));
        timing.admitted = Some(base + Duration::from_micros(100));
        timing.prefix_walk = Some(base + Duration::from_micros(130));
        timing.prefill_done = Some(base + Duration::from_micros(800));
        timing.first_token = Some(base + Duration::from_micros(800));
        timing.finished = Some(base + Duration::from_micros(2000));
        let c = Completion {
            id: 9,
            tokens: vec![1],
            prompt_len: 4,
            prefix_hit_pages: 1,
            pages_allocated: 2,
            timing,
            finish: FinishReason::MaxTokens,
            trace: true,
        };
        let v = Json::parse(&render_completion(&c)).unwrap();
        let tr = v.get("trace").expect("trace object present");
        // every lifecycle stamp is present; offsets are relative to
        // `received` and monotone through the pipeline
        let mut prev = -1.0;
        for key in [
            "received",
            "parsed",
            "queued",
            "admitted",
            "prefix_walk",
            "prefill_done",
            "first_token",
            "finished",
        ] {
            let us = tr.get(key).unwrap_or_else(|| panic!("{key} missing")).as_f64().unwrap();
            assert!(us >= prev, "{key} offset {us} < previous {prev}");
            prev = us;
        }
        assert_eq!(tr.get("outcome").unwrap().as_str(), Some("max_tokens"));
        assert_eq!(tr.get("pages_reused").unwrap().as_usize(), Some(1));
        assert_eq!(tr.get("pages_allocated").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn trace_marks_unreached_stamps() {
        // a shed request never got admitted: those stamps render -1
        let mut timing = Timing::new();
        timing.finished = Some(timing.submitted + Duration::from_micros(10));
        let c = Completion {
            id: 2,
            tokens: vec![],
            prompt_len: 1,
            prefix_hit_pages: 0,
            pages_allocated: 0,
            timing,
            finish: FinishReason::Rejected,
            trace: true,
        };
        let v = Json::parse(&render_completion(&c)).unwrap();
        let tr = v.get("trace").unwrap();
        assert_eq!(tr.get("received").unwrap().as_f64(), Some(-1.0));
        assert_eq!(tr.get("admitted").unwrap().as_f64(), Some(-1.0));
        assert_eq!(tr.get("first_token").unwrap().as_f64(), Some(-1.0));
        assert_eq!(tr.get("queued").unwrap().as_f64(), Some(0.0));
        assert!(tr.get("finished").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn timeout_and_cancelled_render() {
        let mut c = Completion {
            id: 1,
            tokens: vec![],
            prompt_len: 1,
            prefix_hit_pages: 0,
            pages_allocated: 0,
            timing: Timing::new(),
            finish: FinishReason::Timeout,
            trace: false,
        };
        assert!(render_completion(&c).contains(r#""finish": "timeout""#));
        c.finish = FinishReason::Cancelled;
        assert!(render_completion(&c).contains(r#""finish": "cancelled""#));
        let v = Json::parse(&render_overloaded(25)).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(v.get("retry_after_ms").unwrap().as_usize(), Some(25));
    }

    #[test]
    fn token_line_roundtrips() {
        let line = render_token(&TokenEvent {
            id: 12,
            index: 3,
            token: 1234,
        });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize(), Some(12));
        assert_eq!(v.get("index").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("token").unwrap().as_usize(), Some(1234));
        // exactly the three streaming fields: no finish marker, so a
        // client tells token lines from the terminal line by shape
        assert!(v.get("finish").is_none());
        assert!(v.get("tokens").is_none());
    }
}
