//! Readiness polling for the serve reactor: epoll on Linux, `poll(2)`
//! on other unix, a no-socket stub elsewhere — raw externs into the
//! platform libc std already links, the same no-new-deps idiom as the
//! store's `flock`/`mmap` and the server's `signal(2)`.
//!
//! The surface is deliberately tiny: register/modify/remove an fd under
//! a caller-chosen `usize` token, then [`Poller::wait`] for readiness
//! events with an optional timeout.  Level-triggered everywhere (the
//! `poll(2)` fallback cannot do edge-triggered, so the Linux path does
//! not either — one behavior on every host).  A [`Waker`] built on a
//! `UnixStream` pair lets another thread interrupt a blocked `wait`
//! (the engine thread wakes the reactor when completions are ready).

use std::io;

/// One readiness event out of [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// the token the fd was registered under
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    /// error or hangup: the connection is dead either way — read until
    /// EOF and close
    pub hangup: bool,
}

/// What to watch an fd for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const READ_WRITE: Interest = Interest {
        readable: true,
        writable: true,
    };
}

// ---------------------------------------------------------------------
// Linux: epoll
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use super::{Event, Interest};
    use std::io;
    use std::os::raw::c_int;

    // x86_64 is the one ABI where the kernel struct is packed; other
    // architectures use natural alignment
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLL_CLOEXEC: c_int = 0x80000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;
    const EPOLLRDHUP: u32 = 0x2000;

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    pub struct Poller {
        epfd: c_int,
        buf: Vec<EpollEvent>,
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(&self, op: c_int, fd: i32, ev: Option<EpollEvent>) -> io::Result<()> {
            let mut ev = ev.unwrap_or(EpollEvent { events: 0, data: 0 });
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&mut self, fd: i32, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(
                EPOLL_CTL_ADD,
                fd,
                Some(EpollEvent {
                    events: mask(interest),
                    data: token as u64,
                }),
            )
        }

        pub fn modify(&mut self, fd: i32, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(
                EPOLL_CTL_MOD,
                fd,
                Some(EpollEvent {
                    events: mask(interest),
                    data: token as u64,
                }),
            )
        }

        pub fn remove(&mut self, fd: i32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, None)
        }

        pub fn wait(
            &mut self,
            timeout_ms: Option<u64>,
            out: &mut Vec<Event>,
        ) -> io::Result<()> {
            let timeout = match timeout_ms {
                None => -1,
                Some(ms) => ms.min(c_int::MAX as u64) as c_int,
            };
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as c_int,
                    timeout,
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(()); // EINTR: treat as a timeout tick
                }
                return Err(e);
            }
            for i in 0..n as usize {
                let ev = self.buf[i];
                let bits = ev.events;
                out.push(Event {
                    token: ev.data as usize,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------
// other unix: poll(2)
// ---------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::{Event, Interest};
    use std::io;
    use std::os::raw::{c_int, c_short, c_uint};

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    const POLLIN: c_short = 0x1;
    const POLLOUT: c_short = 0x4;
    const POLLERR: c_short = 0x8;
    const POLLHUP: c_short = 0x10;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    /// `poll(2)`-backed poller: the interest set lives in userspace and
    /// is rebuilt into a `pollfd` array per wait.  O(n) per call, which
    /// is fine for the fallback host (CI smoke, macOS dev) — Linux
    /// serving uses the epoll implementation above.
    pub struct Poller {
        entries: Vec<(i32, usize, Interest)>,
        fds: Vec<PollFd>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                entries: Vec::new(),
                fds: Vec::new(),
            })
        }

        pub fn add(&mut self, fd: i32, token: usize, interest: Interest) -> io::Result<()> {
            if self.entries.iter().any(|&(f, _, _)| f == fd) {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    "fd already registered",
                ));
            }
            self.entries.push((fd, token, interest));
            Ok(())
        }

        pub fn modify(&mut self, fd: i32, token: usize, interest: Interest) -> io::Result<()> {
            for e in &mut self.entries {
                if e.0 == fd {
                    e.1 = token;
                    e.2 = interest;
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        pub fn remove(&mut self, fd: i32) -> io::Result<()> {
            let before = self.entries.len();
            self.entries.retain(|&(f, _, _)| f != fd);
            if self.entries.len() == before {
                return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
            }
            Ok(())
        }

        pub fn wait(
            &mut self,
            timeout_ms: Option<u64>,
            out: &mut Vec<Event>,
        ) -> io::Result<()> {
            self.fds.clear();
            for &(fd, _, interest) in &self.entries {
                let mut events = 0;
                if interest.readable {
                    events |= POLLIN;
                }
                if interest.writable {
                    events |= POLLOUT;
                }
                self.fds.push(PollFd {
                    fd,
                    events,
                    revents: 0,
                });
            }
            let timeout = match timeout_ms {
                None => -1,
                Some(ms) => ms.min(c_int::MAX as u64) as c_int,
            };
            let n = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as c_uint, timeout) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (i, pfd) in self.fds.iter().enumerate() {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token: self.entries[i].1,
                    readable: r & (POLLIN | POLLHUP) != 0,
                    writable: r & POLLOUT != 0,
                    hangup: r & (POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------
// non-unix stub: no readiness API; wait() just sleeps out its timeout.
// The reactor never runs here (TcpStream fds are unix-only), but the
// crate still compiles.
// ---------------------------------------------------------------------

#[cfg(not(unix))]
mod sys {
    use super::{Event, Interest};
    use std::io;

    pub struct Poller;

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller)
        }

        pub fn add(&mut self, _fd: i32, _token: usize, _interest: Interest) -> io::Result<()> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "poller requires unix",
            ))
        }

        pub fn modify(&mut self, _fd: i32, _token: usize, _interest: Interest) -> io::Result<()> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "poller requires unix",
            ))
        }

        pub fn remove(&mut self, _fd: i32) -> io::Result<()> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "poller requires unix",
            ))
        }

        pub fn wait(
            &mut self,
            timeout_ms: Option<u64>,
            _out: &mut Vec<Event>,
        ) -> io::Result<()> {
            if let Some(ms) = timeout_ms {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
            Ok(())
        }
    }
}

/// Readiness poller: epoll (Linux), `poll(2)` (other unix), or a stub
/// (elsewhere).  Register fds under caller tokens, then [`wait`].
///
/// [`wait`]: Poller::wait
pub struct Poller {
    inner: sys::Poller,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller {
            inner: sys::Poller::new()?,
        })
    }

    /// Start watching `fd` with `interest`; events carry `token`.
    pub fn add(&mut self, fd: i32, token: usize, interest: Interest) -> io::Result<()> {
        self.inner.add(fd, token, interest)
    }

    /// Change the interest (and token) of an already-registered fd.
    pub fn modify(&mut self, fd: i32, token: usize, interest: Interest) -> io::Result<()> {
        self.inner.modify(fd, token, interest)
    }

    /// Stop watching `fd`.  Must be called before the fd is closed.
    pub fn remove(&mut self, fd: i32) -> io::Result<()> {
        self.inner.remove(fd)
    }

    /// Block until at least one event or the timeout (`None` = forever),
    /// appending events to `out` (which is *not* cleared here).  A
    /// timeout or EINTR returns `Ok` with nothing appended.
    pub fn wait(&mut self, timeout_ms: Option<u64>, out: &mut Vec<Event>) -> io::Result<()> {
        self.inner.wait(timeout_ms, out)
    }
}

// ---------------------------------------------------------------------
// waker
// ---------------------------------------------------------------------

/// Cross-thread wakeup for a blocked [`Poller::wait`]: a nonblocking
/// `UnixStream` pair — the engine thread writes a byte, the reactor
/// (which registered the read end) wakes and drains it.  On non-unix
/// hosts this degrades to a flag the stubbed poller never observes
/// mid-sleep (the reactor does not run there).
pub struct Waker {
    #[cfg(unix)]
    tx: std::os::unix::net::UnixStream,
    #[cfg(unix)]
    rx: std::os::unix::net::UnixStream,
    #[cfg(not(unix))]
    flag: std::sync::atomic::AtomicBool,
}

/// The sending half of a [`Waker`], cloneable across threads.
#[derive(Clone)]
pub struct WakeHandle {
    #[cfg(unix)]
    tx: std::sync::Arc<std::os::unix::net::UnixStream>,
    #[cfg(not(unix))]
    _unused: (),
}

impl WakeHandle {
    /// Wake the poller this handle's [`Waker`] is registered with.
    /// Best-effort: a full pipe already guarantees a pending wakeup.
    pub fn wake(&self) {
        #[cfg(unix)]
        {
            use std::io::Write;
            let _ = (&*self.tx).write(&[1u8]);
        }
    }
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        #[cfg(unix)]
        {
            let (tx, rx) = std::os::unix::net::UnixStream::pair()?;
            tx.set_nonblocking(true)?;
            rx.set_nonblocking(true)?;
            Ok(Waker { tx, rx })
        }
        #[cfg(not(unix))]
        {
            Ok(Waker {
                flag: std::sync::atomic::AtomicBool::new(false),
            })
        }
    }

    /// The fd to register with the poller (readable on wake).
    #[cfg(unix)]
    pub fn fd(&self) -> i32 {
        use std::os::unix::io::AsRawFd;
        self.rx.as_raw_fd()
    }

    #[cfg(not(unix))]
    pub fn fd(&self) -> i32 {
        -1
    }

    /// A cloneable sending half for other threads.
    pub fn handle(&self) -> io::Result<WakeHandle> {
        #[cfg(unix)]
        {
            Ok(WakeHandle {
                tx: std::sync::Arc::new(self.tx.try_clone()?),
            })
        }
        #[cfg(not(unix))]
        {
            Ok(WakeHandle { _unused: () })
        }
    }

    /// Drain pending wakeup bytes after an event on [`Waker::fd`].
    pub fn drain(&self) {
        #[cfg(unix)]
        {
            use std::io::Read;
            let mut buf = [0u8; 64];
            while let Ok(n) = (&self.rx).read(&mut buf) {
                if n == 0 {
                    break;
                }
            }
        }
        #[cfg(not(unix))]
        {
            self.flag.store(false, std::sync::atomic::Ordering::SeqCst);
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn poller_sees_readable() {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.add(b.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut evs = Vec::new();
        // nothing to read yet: a zero timeout returns empty
        p.wait(Some(0), &mut evs).unwrap();
        assert!(evs.iter().all(|e| !e.readable));
        a.write_all(b"x").unwrap();
        evs.clear();
        p.wait(Some(1000), &mut evs).unwrap();
        assert!(evs.iter().any(|e| e.token == 7 && e.readable));
        p.remove(b.as_raw_fd()).unwrap();
    }

    #[test]
    fn poller_sees_writable_and_modify() {
        let (a, _b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.add(a.as_raw_fd(), 3, Interest::READ).unwrap();
        let mut evs = Vec::new();
        p.wait(Some(0), &mut evs).unwrap();
        assert!(evs.iter().all(|e| !e.writable), "not watching for write");
        p.modify(a.as_raw_fd(), 3, Interest::READ_WRITE).unwrap();
        evs.clear();
        p.wait(Some(1000), &mut evs).unwrap();
        assert!(evs.iter().any(|e| e.token == 3 && e.writable));
    }

    #[test]
    fn poller_sees_hangup() {
        let (a, b) = UnixStream::pair().unwrap();
        let mut p = Poller::new().unwrap();
        p.add(b.as_raw_fd(), 1, Interest::READ).unwrap();
        drop(a);
        let mut evs = Vec::new();
        p.wait(Some(1000), &mut evs).unwrap();
        let ev = evs.iter().find(|e| e.token == 1).expect("event");
        assert!(ev.hangup || ev.readable, "peer close surfaces");
        // and the read end now reads EOF
        let mut buf = [0u8; 8];
        b.set_nonblocking(true).unwrap();
        assert_eq!((&b).read(&mut buf).unwrap(), 0);
    }

    #[test]
    fn waker_wakes_and_drains() {
        let waker = Waker::new().unwrap();
        let mut p = Poller::new().unwrap();
        p.add(waker.fd(), 0, Interest::READ).unwrap();
        let handle = waker.handle().unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            handle.wake();
        });
        let mut evs = Vec::new();
        p.wait(Some(5_000), &mut evs).unwrap();
        assert!(evs.iter().any(|e| e.token == 0 && e.readable));
        waker.drain();
        // drained: an immediate wait sees nothing
        evs.clear();
        p.wait(Some(0), &mut evs).unwrap();
        assert!(evs.iter().all(|e| e.token != 0 || !e.readable));
        t.join().unwrap();
    }
}
