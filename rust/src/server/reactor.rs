//! The serve reactor: one event loop owns the listener and every
//! client socket in non-blocking mode, parses request lines
//! incrementally out of per-connection read buffers, routes
//! submits/cancels into the engine loop's channel, and drains response
//! lines through write-readiness-driven per-connection output queues.
//!
//! This replaces the thread-per-connection front end: no reader-thread
//! spawn per accept, no fixed accept-retry sleep, no idle poll — the
//! reactor blocks in `epoll_wait`/`poll` until a socket or the engine
//! ([`Waker`]) has something for it.
//!
//! # Ownership and routing
//!
//! The reactor thread exclusively owns all sockets and the route table
//! (`request id → connection slot`); the engine loop never touches a
//! socket.  Traffic crosses two mpsc channels: [`ServerMsg`]
//! (reactor → engine: submit/cancel/stats) and [`Outbound`]
//! (engine → reactor: response lines), with a [`Waker`] byte to
//! interrupt a blocked wait when responses are ready.  Connection slots
//! are recycled through a generation counter, so a response routed to a
//! request whose connection died (and whose slot was reused) is
//! dropped instead of written to a stranger.
//!
//! # Backpressure
//!
//! `[server] max_conn_buffer_kb` caps both sides of a connection's
//! buffering: an unterminated request line longer than the cap, or a
//! queued-output backlog beyond it (a slow or stalled reader under
//! streaming), disconnects the connection and cancels its in-flight
//! requests — one stalled client cannot hold completion memory
//! unboundedly.  Read/write buffers are pooled across connection churn.
//!
//! # Metrics scrapes
//!
//! The reactor byte-sniffs each framed line: one starting with `GET `
//! flips the connection into HTTP mode and is answered with the
//! engine's last rendered Prometheus snapshot (`/metrics`; anything
//! else 404s), `Connection: close`.  A scrape therefore reads a
//! pre-rendered string under a mutex and never touches the engine
//! queue.  `[server] metrics_addr` optionally binds a second,
//! scrape-only listener onto the same poller.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::poller::{Interest, Poller, WakeHandle, Waker};
use super::{parse_request, sigint_requested, ServerMsg};

/// Engine-loop → reactor traffic.
pub enum Outbound {
    /// One response line for request `id`.  `last` marks the terminal
    /// line of the request (the route is dropped after writing it);
    /// streamed token lines ride ahead of it with `last: false`.
    Line { id: u64, text: String, last: bool },
    /// The drain is complete: flush queued output (bounded by a grace
    /// period) and exit the reactor loop.
    Shutdown,
}

/// Synthetic id namespace for reactor-generated stats requests: client
/// ids are validated to ≤ 2^53 and fallback ids use bit 62 alone, so
/// bits 62|61 together can never collide with either.
const STATS_ID_BITS: u64 = (1 << 62) | (1 << 61);

/// How long the reactor keeps flushing queued output after `Shutdown`.
const FLUSH_GRACE: Duration = Duration::from_secs(5);

/// Pooled-buffer bounds: a buffer over this capacity is shrunk before
/// pooling, and at most this many buffers are retained.
const POOL_BUF_CAP: usize = 256 * 1024;
const POOL_MAX: usize = 256;

const TOKEN_WAKER: usize = 0;
const TOKEN_LISTENER: usize = 1;
const TOKEN_METRICS: usize = 2;
const TOKEN_BASE: usize = 3;

#[cfg(unix)]
fn fd_of<T: std::os::unix::io::AsRawFd>(t: &T) -> i32 {
    use std::os::unix::io::AsRawFd;
    t.as_raw_fd()
}

#[cfg(not(unix))]
fn fd_of<T>(_t: &T) -> i32 {
    -1
}

/// Reactor knobs threaded down from the engine config.
pub(crate) struct ReactorOpts {
    pub default_max_new: usize,
    pub max_new_cap: usize,
    /// per-connection buffer cap in **bytes** (applied independently to
    /// the unterminated read line and the queued output backlog);
    /// 0 = unlimited
    pub max_conn_buffer: usize,
    /// latest rendered `/metrics` exposition, refreshed ~1/s by the
    /// engine loop and cloned into HTTP responses by the reactor
    pub metrics: Arc<Mutex<String>>,
    /// optional dedicated scrape listener (`[server] metrics_addr`)
    pub metrics_listener: Option<TcpListener>,
}

struct Conn {
    stream: TcpStream,
    /// slot-reuse guard: routes carry (slot, gen) and are dropped when
    /// the generation moved on
    gen: u64,
    rbuf: Vec<u8>,
    /// `rbuf[..scan]` is known newline-free (resume point for framing)
    scan: usize,
    obuf: Vec<u8>,
    /// bytes of `obuf` already written to the socket
    osent: usize,
    /// request ids submitted by this connection and not yet terminally
    /// answered — cancelled on EOF/teardown
    submitted: Vec<u64>,
    /// whether the poller registration currently includes writability
    want_write: bool,
    /// the connection sent an HTTP request line; subsequent lines
    /// (headers) are ignored rather than parsed as JSON
    http: bool,
    /// close the connection once the output queue fully drains (set by
    /// the HTTP path: every scrape response is `Connection: close`)
    close_after_flush: bool,
}

pub(crate) struct Reactor {
    listener: Option<TcpListener>,
    /// dedicated scrape listener, when `[server] metrics_addr` is set
    metrics_listener: Option<TcpListener>,
    poller: Poller,
    waker: Waker,
    stop: Arc<AtomicBool>,
    req_tx: mpsc::Sender<ServerMsg>,
    out_rx: mpsc::Receiver<Outbound>,
    conns: Vec<Option<Conn>>,
    free_slots: Vec<usize>,
    /// request id → (slot, gen) of the connection awaiting the response
    routes: HashMap<u64, (usize, u64)>,
    /// recycled read/write buffers (connection churn allocates nothing
    /// in steady state)
    pool: Vec<Vec<u8>>,
    /// shared read chunk and line scratch
    chunk: Vec<u8>,
    line_buf: String,
    next_gen: u64,
    next_fallback: u64,
    next_stats: u64,
    opts: ReactorOpts,
    /// connections dropped by the `max_conn_buffer_kb` policy (slow
    /// readers / oversized lines), shared into the `ServeReport`
    overflow_drops: Arc<AtomicU64>,
}

impl Reactor {
    /// Build the reactor and register the listener + waker.  Returns
    /// the waker handle the engine loop signals completions with.
    pub(crate) fn new(
        listener: TcpListener,
        req_tx: mpsc::Sender<ServerMsg>,
        out_rx: mpsc::Receiver<Outbound>,
        stop: Arc<AtomicBool>,
        mut opts: ReactorOpts,
        overflow_drops: Arc<AtomicU64>,
    ) -> std::io::Result<(Reactor, WakeHandle)> {
        listener.set_nonblocking(true)?;
        let mut poller = Poller::new()?;
        let waker = Waker::new()?;
        let handle = waker.handle()?;
        poller.add(waker.fd(), TOKEN_WAKER, Interest::READ)?;
        poller.add(fd_of(&listener), TOKEN_LISTENER, Interest::READ)?;
        let metrics_listener = opts.metrics_listener.take();
        if let Some(ml) = metrics_listener.as_ref() {
            ml.set_nonblocking(true)?;
            poller.add(fd_of(ml), TOKEN_METRICS, Interest::READ)?;
        }
        Ok((
            Reactor {
                listener: Some(listener),
                metrics_listener,
                poller,
                waker,
                stop,
                req_tx,
                out_rx,
                conns: Vec::new(),
                free_slots: Vec::new(),
                routes: HashMap::new(),
                pool: Vec::new(),
                chunk: vec![0u8; 16 * 1024],
                line_buf: String::new(),
                next_gen: 1,
                next_fallback: 1,
                next_stats: 1,
                opts,
                overflow_drops,
            },
            handle,
        ))
    }

    /// The event loop.  Runs until `Shutdown` arrives (and queued
    /// output is flushed or the grace period expires).
    pub(crate) fn run(mut self) {
        let mut events = Vec::with_capacity(1024);
        let mut shutdown = false;
        let mut flush_deadline = Instant::now(); // set when shutdown flips
        loop {
            // a stop/SIGINT closes the accept socket immediately (the
            // first step of a graceful drain); existing connections
            // keep flowing until the engine finishes draining
            if (self.listener.is_some() || self.metrics_listener.is_some())
                && (self.stop.load(Ordering::SeqCst) || sigint_requested())
            {
                if let Some(l) = self.listener.take() {
                    let _ = self.poller.remove(fd_of(&l));
                }
                if let Some(l) = self.metrics_listener.take() {
                    let _ = self.poller.remove(fd_of(&l));
                }
            }
            if self.pump_outbound() && !shutdown {
                shutdown = true;
                flush_deadline = Instant::now() + FLUSH_GRACE;
            }
            if shutdown {
                let pending = self
                    .conns
                    .iter()
                    .flatten()
                    .any(|c| c.osent < c.obuf.len());
                if !pending || Instant::now() >= flush_deadline {
                    break;
                }
            }
            // heartbeat timeouts, not sleeps: the wait returns the
            // instant a socket or the waker is ready; the bound only
            // re-checks the stop flag when nothing at all happens
            let timeout = if shutdown { 25 } else { 250 };
            events.clear();
            if self.poller.wait(Some(timeout), &mut events).is_err() {
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_WAKER => self.waker.drain(),
                    TOKEN_LISTENER => self.accept_ready(false),
                    TOKEN_METRICS => self.accept_ready(true),
                    t => {
                        let slot = t - TOKEN_BASE;
                        if self.conns.get(slot).map_or(true, |c| c.is_none()) {
                            continue; // closed earlier in this batch
                        }
                        if ev.readable {
                            self.read_conn(slot);
                        } else if ev.hangup {
                            self.close_conn(slot, true);
                            continue;
                        }
                        if ev.writable
                            && self.conns.get(slot).map_or(false, |c| c.is_some())
                        {
                            self.flush_conn(slot);
                        }
                    }
                }
            }
        }
        // loop exit closes every socket (Drop); queued-but-unflushed
        // bytes at grace expiry are abandoned exactly like the old
        // blocking writer abandoned a dead sink
    }

    // -- engine → connections ------------------------------------------

    /// Drain the outbound channel into connection output queues.
    /// Returns true once `Shutdown` has been seen.
    fn pump_outbound(&mut self) -> bool {
        let mut shutdown = false;
        while let Ok(msg) = self.out_rx.try_recv() {
            match msg {
                Outbound::Line { id, text, last } => self.deliver(id, &text, last),
                Outbound::Shutdown => shutdown = true,
            }
        }
        shutdown
    }

    fn deliver(&mut self, id: u64, text: &str, last: bool) {
        let Some(&(slot, gen)) = self.routes.get(&id) else {
            return; // connection died first; drop the line
        };
        let stale = self.conns[slot].as_ref().map_or(true, |c| c.gen != gen);
        if stale {
            self.routes.remove(&id);
            return;
        }
        if last {
            self.routes.remove(&id);
            let c = self.conns[slot].as_mut().unwrap();
            if let Some(i) = c.submitted.iter().position(|&x| x == id) {
                c.submitted.swap_remove(i);
            }
        }
        self.enqueue(slot, text);
    }

    /// Append one line to a connection's output queue, write as much as
    /// the socket takes right now, and arm write-readiness for the rest.
    fn enqueue(&mut self, slot: usize, text: &str) {
        {
            let c = self.conns[slot].as_mut().unwrap();
            c.obuf.extend_from_slice(text.as_bytes());
            c.obuf.push(b'\n');
        }
        self.after_enqueue(slot);
    }

    /// Like [`enqueue`] but byte-exact: no `'\n'` is appended.  Used by
    /// the HTTP path, whose framing is `Content-Length`, not newlines.
    fn enqueue_raw(&mut self, slot: usize, bytes: &[u8]) {
        {
            let c = self.conns[slot].as_mut().unwrap();
            c.obuf.extend_from_slice(bytes);
        }
        self.after_enqueue(slot);
    }

    fn after_enqueue(&mut self, slot: usize) {
        self.flush_conn(slot);
        // slow-reader policy: a backlog beyond the cap disconnects
        let cap = self.opts.max_conn_buffer;
        if cap > 0 {
            let over = self.conns[slot]
                .as_ref()
                .map_or(false, |c| c.obuf.len() - c.osent > cap);
            if over {
                self.overflow_drops.fetch_add(1, Ordering::Relaxed);
                self.close_conn(slot, true);
            }
        }
    }

    fn flush_conn(&mut self, slot: usize) {
        let mut dead = false;
        {
            let c = self.conns[slot].as_mut().unwrap();
            loop {
                if c.osent == c.obuf.len() {
                    c.obuf.clear();
                    c.osent = 0;
                    break;
                }
                match c.stream.write(&c.obuf[c.osent..]) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => c.osent += n,
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
        }
        if dead {
            self.close_conn(slot, true);
            return;
        }
        let (want, have, fd, token) = {
            let c = self.conns[slot].as_ref().unwrap();
            (
                c.osent < c.obuf.len(),
                c.want_write,
                fd_of(&c.stream),
                slot + TOKEN_BASE,
            )
        };
        if want != have {
            let interest = if want {
                Interest::READ_WRITE
            } else {
                Interest::READ
            };
            if self.poller.modify(fd, token, interest).is_ok() {
                self.conns[slot].as_mut().unwrap().want_write = want;
            }
        }
        // HTTP responses are `Connection: close`: drop the connection
        // once the last response byte has hit the socket
        let done = self.conns[slot]
            .as_ref()
            .map_or(false, |c| c.close_after_flush && c.obuf.is_empty());
        if done {
            self.close_conn(slot, true);
        }
    }

    // -- connections → engine ------------------------------------------

    fn accept_ready(&mut self, metrics: bool) {
        loop {
            let listener = if metrics {
                self.metrics_listener.as_ref()
            } else {
                self.listener.as_ref()
            };
            let Some(listener) = listener else { return };
            match listener.accept() {
                Ok((stream, _addr)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue; // one bad socket must not stall accepts
                    }
                    // small per-token lines: don't let Nagle sit on them
                    let _ = stream.set_nodelay(true);
                    self.open_conn(stream);
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // transient accept failures (EMFILE, aborted handshake):
                // drop this round, keep the listener
                Err(_) => break,
            }
        }
    }

    fn open_conn(&mut self, stream: TcpStream) {
        let gen = self.next_gen;
        self.next_gen += 1;
        let rbuf = self.pool.pop().unwrap_or_default();
        let obuf = self.pool.pop().unwrap_or_default();
        let conn = Conn {
            stream,
            gen,
            rbuf,
            scan: 0,
            obuf,
            osent: 0,
            submitted: Vec::new(),
            want_write: false,
            http: false,
            close_after_flush: false,
        };
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.conns[s] = Some(conn);
                s
            }
            None => {
                self.conns.push(Some(conn));
                self.conns.len() - 1
            }
        };
        let fd = fd_of(&self.conns[slot].as_ref().unwrap().stream);
        if self.poller.add(fd, slot + TOKEN_BASE, Interest::READ).is_err() {
            self.close_conn(slot, false);
        }
    }

    fn read_conn(&mut self, slot: usize) {
        let mut eof = false;
        {
            let c = self.conns[slot].as_mut().unwrap();
            loop {
                match c.stream.read(&mut self.chunk) {
                    Ok(0) => {
                        eof = true;
                        break;
                    }
                    Ok(n) => c.rbuf.extend_from_slice(&self.chunk[..n]),
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    // reset/abort reads like EOF: cancel and close
                    Err(_) => {
                        eof = true;
                        break;
                    }
                }
            }
        }
        // frame complete lines out of the buffer
        loop {
            let mut bad_utf8 = false;
            let got_line = {
                let Some(c) = self.conns[slot].as_mut() else { return };
                match c.rbuf[c.scan..].iter().position(|&b| b == b'\n') {
                    None => {
                        c.scan = c.rbuf.len();
                        false
                    }
                    Some(rel) => {
                        let end = c.scan + rel; // exclusive of '\n'
                        let line = &c.rbuf[..end];
                        let line = match line.last() {
                            Some(b'\r') => &line[..end - 1],
                            _ => line,
                        };
                        match std::str::from_utf8(line) {
                            Ok(s) => {
                                self.line_buf.clear();
                                self.line_buf.push_str(s);
                            }
                            // same contract as the old BufReader path:
                            // a non-UTF-8 line closes the connection
                            Err(_) => bad_utf8 = true,
                        }
                        c.rbuf.drain(..=end);
                        c.scan = 0;
                        true
                    }
                }
            };
            if bad_utf8 {
                self.close_conn(slot, true);
                return;
            }
            if !got_line {
                break;
            }
            self.handle_line(slot);
        }
        // an unterminated line beyond the cap is an abusive or broken
        // client: cut it off instead of buffering without bound
        let cap = self.opts.max_conn_buffer;
        if cap > 0 {
            let over = self.conns[slot]
                .as_ref()
                .map_or(false, |c| c.rbuf.len() > cap);
            if over {
                self.overflow_drops.fetch_add(1, Ordering::Relaxed);
                self.close_conn(slot, true);
                return;
            }
        }
        if eof {
            self.close_conn(slot, true);
        }
    }

    /// One complete request line (in `self.line_buf`) from `slot`.
    fn handle_line(&mut self, slot: usize) {
        let line = std::mem::take(&mut self.line_buf);
        self.dispatch_line(slot, &line);
        self.line_buf = line; // keep the allocation
    }

    fn dispatch_line(&mut self, slot: usize, line: &str) {
        let received = Instant::now();
        if self.conns[slot].as_ref().map_or(false, |c| c.http) {
            return; // HTTP header lines after the request line: ignored
        }
        // byte-sniff: an HTTP request line on the JSON-lines port (or
        // the dedicated metrics port) is a scrape, not a request
        if line.starts_with("GET ") {
            self.handle_http(slot, line);
            return;
        }
        if line.trim().is_empty() {
            return;
        }
        // `{"stats": true}` is answered by the engine loop with the
        // counter/latency snapshot; it never touches a lane.  An
        // optional `"traces": K` appends the flight recorder's last K
        // request timelines to the reply.
        if let Ok(v) = Json::parse(line) {
            if v.get("stats").and_then(|x| x.as_bool()) == Some(true) {
                let traces = v.get("traces").and_then(|x| x.as_usize()).unwrap_or(0);
                let id = STATS_ID_BITS | self.next_stats;
                self.next_stats += 1;
                self.register(slot, id);
                let _ = self.req_tx.send(ServerMsg::Stats { id, traces });
                return;
            }
        }
        let fallback = self.next_fallback | (1 << 62);
        self.next_fallback += 1;
        match parse_request(
            line,
            fallback,
            self.opts.default_max_new,
            self.opts.max_new_cap,
        ) {
            Ok(mut req) => {
                req.received_at = Some(received);
                req.parsed_at = Some(Instant::now());
                let id = req.id;
                self.register(slot, id);
                let _ = self.req_tx.send(ServerMsg::Submit(req));
            }
            Err(e) => {
                let reply =
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]).to_string();
                self.enqueue(slot, &reply);
            }
        }
    }

    /// Answer an HTTP request line: `/metrics` serves the last rendered
    /// Prometheus exposition, anything else 404s.  The response is
    /// queued byte-exact and the connection closes once it drains —
    /// one request per connection, no keep-alive, no header parsing.
    fn handle_http(&mut self, slot: usize, line: &str) {
        let path = line.split_whitespace().nth(1).unwrap_or("");
        let (status, body) = if path == "/metrics" || path.starts_with("/metrics?") {
            let body = match self.opts.metrics.lock() {
                Ok(g) => g.clone(),
                Err(p) => p.into_inner().clone(),
            };
            ("200 OK", body)
        } else {
            ("404 Not Found", "not found\n".to_string())
        };
        let resp = format!(
            "HTTP/1.1 {status}\r\n\
             Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        );
        {
            let c = self.conns[slot].as_mut().unwrap();
            c.http = true;
            c.close_after_flush = true;
        }
        self.enqueue_raw(slot, resp.as_bytes());
    }

    /// Route `id`'s responses to `slot` and track it for EOF cancel.
    fn register(&mut self, slot: usize, id: u64) {
        let gen = self.conns[slot].as_ref().unwrap().gen;
        self.routes.insert(id, (slot, gen));
        self.conns[slot].as_mut().unwrap().submitted.push(id);
    }

    // -- teardown -------------------------------------------------------

    /// Drop a connection: deregister, cancel whatever it still has in
    /// flight (when `cancel`), and recycle its buffers.
    fn close_conn(&mut self, slot: usize, cancel: bool) {
        let Some(mut c) = self.conns[slot].take() else { return };
        let _ = self.poller.remove(fd_of(&c.stream));
        for &id in &c.submitted {
            if let Some(&(s, g)) = self.routes.get(&id) {
                if s == slot && g == c.gen {
                    self.routes.remove(&id);
                }
            }
            if cancel {
                let _ = self.req_tx.send(ServerMsg::Cancel(id));
            }
        }
        for mut buf in [std::mem::take(&mut c.rbuf), std::mem::take(&mut c.obuf)] {
            if self.pool.len() >= POOL_MAX {
                break;
            }
            buf.clear();
            buf.shrink_to(POOL_BUF_CAP);
            self.pool.push(buf);
        }
        self.free_slots.push(slot);
        // `c.stream` drops here, closing the socket
    }
}
