//! Native attention over (reconstructed) KV tensors plus the fidelity
//! measures of §9.6: attention-logit preservation and inner-product error
//! under KV compression.  The serving path runs attention inside the XLA
//! executable; this native version exists for the fidelity experiments
//! and as an independent cross-check of the HLO scorer.

use crate::metrics;
use crate::quant::{BatchScratch, PackedSink, Stage1};

/// Single-query multi-head attention:
///   q (H, dh), k (H, T, dh), v (H, T, dh) → (out (H, dh), logits (H, T))
/// logits are scaled by 1/√dh, matching `model.attention_scorer`.
pub fn attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    h: usize,
    t: usize,
    dh: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(q.len(), h * dh);
    assert_eq!(k.len(), h * t * dh);
    assert_eq!(v.len(), h * t * dh);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; h * dh];
    let mut logits = vec![0.0f32; h * t];
    let mut weights = vec![0.0f32; t];
    for hh in 0..h {
        let qh = &q[hh * dh..(hh + 1) * dh];
        // logits
        for tt in 0..t {
            let kv = &k[hh * t * dh + tt * dh..][..dh];
            let mut dot = 0.0f32;
            for i in 0..dh {
                dot += qh[i] * kv[i];
            }
            logits[hh * t + tt] = dot * scale;
        }
        // softmax
        softmax_into(&logits[hh * t..(hh + 1) * t], &mut weights);
        // weighted value sum
        let oh = &mut out[hh * dh..(hh + 1) * dh];
        for tt in 0..t {
            let w = weights[tt];
            if w == 0.0 {
                continue;
            }
            let vv = &v[hh * t * dh + tt * dh..][..dh];
            for i in 0..dh {
                oh[i] += w * vv[i];
            }
        }
    }
    (out, logits)
}

/// Numerically stable softmax into a preallocated buffer.
pub fn softmax_into(logits: &[f32], out: &mut [f32]) {
    debug_assert_eq!(logits.len(), out.len());
    let mut m = f32::NEG_INFINITY;
    for &l in logits {
        m = m.max(l);
    }
    let mut sum = 0.0f32;
    for (o, &l) in out.iter_mut().zip(logits) {
        let e = (l - m).exp();
        *o = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Fidelity report comparing attention with exact vs compressed K/V
/// (§9.6 items 2–3 made concrete).
#[derive(Debug, Clone)]
pub struct FidelityReport {
    /// MSE of attention logits q·k/√dh
    pub logit_mse: f64,
    /// max |Δlogit|
    pub logit_max_err: f64,
    /// relative L2 error of the attention output
    pub out_rel_l2: f64,
    /// top-1 agreement of per-head attention argmax (which token gets
    /// the most attention)
    pub top1_attention: f64,
    /// mean cosine similarity of attention outputs per head
    pub out_cosine: f64,
}

/// Compare attention computed over exact (k, v) vs compressed (k̂, v̂).
pub fn fidelity(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    k_hat: &[f32],
    v_hat: &[f32],
    h: usize,
    t: usize,
    dh: usize,
) -> FidelityReport {
    let (out_a, log_a) = attend(q, k, v, h, t, dh);
    let (out_b, log_b) = attend(q, k_hat, v_hat, h, t, dh);
    let mut max_err = 0.0f64;
    for (&a, &b) in log_a.iter().zip(&log_b) {
        max_err = max_err.max(((a - b) as f64).abs());
    }
    let mut cos = 0.0f64;
    for hh in 0..h {
        cos += metrics::cosine(
            &out_a[hh * dh..(hh + 1) * dh],
            &out_b[hh * dh..(hh + 1) * dh],
        );
    }
    FidelityReport {
        logit_mse: metrics::mse(&log_a, &log_b),
        logit_max_err: max_err,
        out_rel_l2: metrics::rel_l2(&out_a, &out_b),
        top1_attention: metrics::top1_agreement(&log_a, &log_b, t),
        out_cosine: cos / h as f64,
    }
}

/// Attention fidelity of `stage1` KV compression measured through the
/// *packed* batch path — `encode_batch` → `decode_batch`, i.e. exactly
/// the bytes the serving cache stores and the records the gather
/// decodes — rather than the fused in-register roundtrip.  `k`/`v` are
/// `(H, T, dh)` with `dh == stage1.d()`.
pub fn fidelity_compressed(
    stage1: &Stage1,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    h: usize,
    t: usize,
    dh: usize,
) -> FidelityReport {
    assert_eq!(stage1.d(), dh, "stage1 dimension must match d_head");
    assert_eq!(k.len(), h * t * dh);
    assert_eq!(v.len(), h * t * dh);
    let n = h * t;
    let mut sink = PackedSink::new();
    let mut scratch = BatchScratch::new();
    let mut k_hat = vec![0.0f32; k.len()];
    let mut v_hat = vec![0.0f32; v.len()];
    stage1.encode_batch(k, n, &mut sink);
    stage1.decode_batch(sink.as_bytes(), n, &mut k_hat, &mut scratch);
    stage1.encode_batch(v, n, &mut sink);
    stage1.decode_batch(sink.as_bytes(), n, &mut v_hat, &mut scratch);
    fidelity(q, k, v, &k_hat, &v_hat, h, t, dh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Stage1, Stage1Config, Variant};
    use crate::util::prng::Rng;

    #[test]
    fn softmax_sums_to_one() {
        let mut out = vec![0.0f32; 5];
        softmax_into(&[1.0, 2.0, 3.0, -1.0, 0.0], &mut out);
        let s: f32 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(out.iter().all(|&w| w > 0.0));
        // monotone in logits
        assert!(out[2] > out[1] && out[1] > out[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut out = vec![0.0f32; 3];
        softmax_into(&[1e4, 1e4 - 1.0, -1e4], &mut out);
        assert!(out.iter().all(|w| w.is_finite()));
        let s: f32 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn attend_uniform_when_logits_equal() {
        // identical keys → uniform attention → output = mean of values
        let (h, t, dh) = (1usize, 4usize, 2usize);
        let q = vec![1.0f32, 0.0];
        let k = vec![0.0f32; h * t * dh]; // all-zero keys → equal logits
        let mut v = vec![0.0f32; h * t * dh];
        for tt in 0..t {
            v[tt * dh] = tt as f32;
        }
        let (out, logits) = attend(&q, &k, &v, h, t, dh);
        assert!(logits.iter().all(|&l| l == 0.0));
        assert!((out[0] - 1.5).abs() < 1e-6); // mean of 0,1,2,3
        assert!(out[1].abs() < 1e-6);
    }

    #[test]
    fn attend_selects_matching_key() {
        // one key aligned with q and large → attention ≈ that value
        let (h, t, dh) = (1usize, 3usize, 4usize);
        let q = vec![10.0f32, 0.0, 0.0, 0.0];
        let mut k = vec![0.0f32; t * dh];
        k[1 * dh] = 10.0; // token 1 matches
        let mut v = vec![0.0f32; t * dh];
        v[1 * dh + 2] = 7.0;
        let (out, _) = attend(&q, &k, &v, h, t, dh);
        assert!((out[2] - 7.0).abs() < 1e-2);
    }

    #[test]
    fn fidelity_perfect_when_uncompressed() {
        let mut rng = Rng::new(1);
        let (h, t, dh) = (4usize, 16usize, 64usize);
        let q = rng.gaussian_vec_f32(h * dh);
        let k = rng.gaussian_vec_f32(h * t * dh);
        let v = rng.gaussian_vec_f32(h * t * dh);
        let rep = fidelity(&q, &k, &v, &k, &v, h, t, dh);
        assert_eq!(rep.logit_mse, 0.0);
        assert_eq!(rep.out_rel_l2, 0.0);
        assert_eq!(rep.top1_attention, 1.0);
        assert!((rep.out_cosine - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fidelity_degrades_gracefully_with_bits() {
        // compressing K/V with stage-1 at 4 bits must keep logits close;
        // 2 bits strictly worse than 4 bits
        let mut rng = Rng::new(2);
        let (h, t, dh) = (4usize, 32usize, 64usize);
        let q = rng.gaussian_vec_f32(h * dh);
        let k = rng.gaussian_vec_f32(h * t * dh);
        let v = rng.gaussian_vec_f32(h * t * dh);
        let mut reports = Vec::new();
        for bits in [2u8, 4] {
            let s = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, bits));
            let mut k_hat = vec![0.0f32; k.len()];
            let mut v_hat = vec![0.0f32; v.len()];
            s.roundtrip_batch(&k, &mut k_hat, h * t);
            s.roundtrip_batch(&v, &mut v_hat, h * t);
            reports.push(fidelity(&q, &k, &v, &k_hat, &v_hat, h, t, dh));
        }
        assert!(reports[1].logit_mse < reports[0].logit_mse);
        assert!(reports[1].out_rel_l2 < 0.35, "{:?}", reports[1]);
        assert!(reports[1].out_cosine > 0.9);
    }

    #[test]
    fn packed_path_fidelity_matches_fused_roundtrip() {
        // the packed batch path stores/loads the same reconstructions as
        // the fused roundtrip, so both fidelity measures must agree
        let mut rng = Rng::new(3);
        let (h, t, dh) = (2usize, 16usize, 64usize);
        let q = rng.gaussian_vec_f32(h * dh);
        let k = rng.gaussian_vec_f32(h * t * dh);
        let v = rng.gaussian_vec_f32(h * t * dh);
        let s = Stage1::new(Stage1Config::new(Variant::IsoFull, dh, 4));
        let packed = fidelity_compressed(&s, &q, &k, &v, h, t, dh);
        let mut k_hat = vec![0.0f32; k.len()];
        let mut v_hat = vec![0.0f32; v.len()];
        s.roundtrip_batch(&k, &mut k_hat, h * t);
        s.roundtrip_batch(&v, &mut v_hat, h * t);
        let fused = fidelity(&q, &k, &v, &k_hat, &v_hat, h, t, dh);
        assert!((packed.logit_mse - fused.logit_mse).abs() < 1e-9 + 1e-3 * fused.logit_mse);
        assert!((packed.out_rel_l2 - fused.out_rel_l2).abs() < 1e-5);
        assert!(packed.out_cosine > 0.95);
    }
}
