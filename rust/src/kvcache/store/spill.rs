//! Write-behind spill worker: the background thread that makes parked
//! prefix pages durable.
//!
//! The cache manager's zero-ref parking path feeds this thread through
//! [`super::PageStore::spill`] — under either index backend: the flat
//! index passes its entry's chain link verbatim, the radix index
//! derives the identical `(key, parent, tokens)` edge from the parked
//! page's tree path, so the worker (and the on-disk format) is
//! index-agnostic.  Each job owns a copy of the page bytes, so the RAM
//! copy can be evicted the moment the job is queued.  The
//! worker appends records to the active segment, rotates at
//! `segment_bytes`, and enforces the byte budget by retiring whole
//! oldest segments (never the active one).  A failed append poisons the
//! active segment (the next attempt starts a fresh one) so a
//! half-written record is never extended — on the next boot the damaged
//! tail reads as a clean end-of-segment.
//!
//! # Failure handling
//!
//! All segment I/O goes through the store's [`SegmentIo`] shim, so a
//! failing disk is a deterministic test case, not a production
//! surprise.  A failed append (create or write) is retried up to
//! `StoreConfig::retries` times with capped exponential backoff, each
//! attempt on a *fresh* segment.  When a job exhausts its retries it is
//! dropped (`spill_errors`) and counted against
//! `StoreConfig::degrade_after`; once that many jobs fail back-to-back
//! with no durable append in between, the store **degrades to
//! disabled**: `Shared::degraded` flips, queued jobs drain as no-ops,
//! new spills are refused at the door, and the serving stats line
//! carries a STORE-DEGRADED marker.  Reads are untouched — everything
//! already durable keeps serving, and the cache runs exactly as it
//! would with persistence off.
//!
//! Durability: segment data is flushed on every append (plain
//! `write_all` on an unbuffered `File`) and fsync'd on [`Job::Flush`]
//! and at shutdown; per-record fsync is deliberately not done (the
//! store is a cache of recomputable artifacts — losing the last few
//! records to a crash costs a re-encode, not correctness).

use std::collections::HashSet;
use std::fs::File;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::super::page::PrefixKey;
use super::{record, segment_path, SegmentIo, Shared, StoreConfig};

pub(crate) enum Job {
    Spill {
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: Vec<i32>,
        page: Vec<u8>,
        /// page slot the record's original node run began at (0 for
        /// page-aligned runs) — rides the v2 record extension
        start_slot: u32,
        /// retention score at spill time (`SCORE_SCALE` fixed point),
        /// the compactor's rescue criterion
        score: u32,
    },
    /// fsync the active segment, then ack
    Flush(mpsc::Sender<()>),
}

pub(crate) fn spawn(
    cfg: StoreConfig,
    shared: Arc<Mutex<Shared>>,
    io: Arc<dyn SegmentIo>,
    rx: mpsc::Receiver<Job>,
    next_segment: u64,
) -> Result<std::thread::JoinHandle<()>> {
    Ok(std::thread::Builder::new()
        .name("isoquant-spill".into())
        .spawn(move || worker(cfg, shared, io, rx, next_segment))?)
}

struct ActiveSegment {
    id: u64,
    file: File,
    bytes: u64,
}

fn worker(
    cfg: StoreConfig,
    shared: Arc<Mutex<Shared>>,
    io: Arc<dyn SegmentIo>,
    rx: mpsc::Receiver<Job>,
    mut next_id: u64,
) {
    let mut active: Option<ActiveSegment> = None;
    let mut buf: Vec<u8> = Vec::new();
    // recv drains every queued job before reporting disconnect, so
    // dropping the sender (PageStore::drop) is a clean "finish all
    // pending spills, then exit"
    while let Ok(job) = rx.recv() {
        match job {
            Job::Flush(ack) => {
                if let Some(a) = active.as_ref() {
                    let _ = io.sync(&a.file);
                }
                let _ = ack.send(());
            }
            Job::Spill {
                key,
                parent,
                tokens,
                page,
                start_slot,
                score,
            } => {
                append_one(
                    &cfg, &shared, &io, &mut active, &mut next_id, &mut buf, key, parent, &tokens,
                    &page, start_slot, score,
                );
            }
        }
    }
    if let Some(a) = active.as_ref() {
        let _ = io.sync(&a.file);
    }
}

/// Append one record, retrying failed attempts on fresh segments with
/// capped exponential backoff.  Success resets the degrade counter; a
/// job that exhausts its retries is dropped and counted toward
/// degradation.
#[allow(clippy::too_many_arguments)]
fn append_one(
    cfg: &StoreConfig,
    shared: &Arc<Mutex<Shared>>,
    io: &Arc<dyn SegmentIo>,
    active: &mut Option<ActiveSegment>,
    next_id: &mut u64,
    buf: &mut Vec<u8>,
    key: PrefixKey,
    parent: Option<PrefixKey>,
    tokens: &[i32],
    page: &[u8],
    start_slot: u32,
    score: u32,
) {
    // degraded: the channel may still hold queued jobs — drain them
    // without touching the disk again
    if shared.lock().unwrap_or_else(|e| e.into_inner()).degraded {
        let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
        s.pending.remove(&key);
        return;
    }
    for attempt in 0..=cfg.retries {
        if attempt > 0 {
            // capped exponential backoff: backoff * 2^(attempt-1), ≤ 1s
            let ms = cfg
                .retry_backoff_ms
                .saturating_mul(1u64 << (attempt - 1).min(20))
                .min(1_000);
            if ms > 0 {
                std::thread::sleep(Duration::from_millis(ms));
            }
            let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
            s.stats.spill_retries += 1;
        }
        match try_append(
            cfg, shared, io, active, next_id, buf, key, parent, tokens, page, start_slot, score,
        ) {
            Ok(()) => {
                let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
                s.consecutive_failures = 0;
                return;
            }
            Err(()) => {}
        }
    }
    // all attempts failed: drop the job and count toward degradation
    let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
    s.pending.remove(&key);
    s.stats.spill_errors += 1;
    s.consecutive_failures += 1;
    if s.consecutive_failures >= cfg.degrade_after {
        s.degraded = true;
        drop(s);
        crate::log_warn!(
            "store: {} consecutive spill failures — persistence \
             DEGRADED to disabled (serving continues; reads of already-durable \
             records stay enabled; restart to re-arm writes)",
            cfg.degrade_after
        );
    }
}

/// One append attempt.  On failure the active segment is abandoned
/// (its real on-disk size is accounted so a torn tail still counts
/// against the budget) and `Err` is returned — the caller decides
/// whether to retry on a fresh segment.
#[allow(clippy::too_many_arguments)]
fn try_append(
    cfg: &StoreConfig,
    shared: &Arc<Mutex<Shared>>,
    io: &Arc<dyn SegmentIo>,
    active: &mut Option<ActiveSegment>,
    next_id: &mut u64,
    buf: &mut Vec<u8>,
    key: PrefixKey,
    parent: Option<PrefixKey>,
    tokens: &[i32],
    page: &[u8],
    start_slot: u32,
    score: u32,
) -> Result<(), ()> {
    // rotate once the active segment crossed the threshold
    if active.as_ref().is_some_and(|a| a.bytes >= cfg.segment_bytes) {
        if let Some(a) = active.take() {
            let _ = io.sync(&a.file);
        }
    }
    if active.is_none() {
        let id = *next_id;
        // move past the attempted id either way: a create_new collision
        // (e.g. another writer took this id) must not wedge every
        // future spill on the same name
        *next_id += 1;
        match io.create_new(&segment_path(&cfg.dir, id)) {
            Ok(file) => *active = Some(ActiveSegment { id, file, bytes: 0 }),
            Err(_) => return Err(()),
        }
    }
    let a = active.as_mut().unwrap();
    buf.clear();
    record::encode_record(
        buf,
        key,
        parent,
        cfg.fingerprint,
        tokens,
        page,
        start_slot,
        score,
    );
    let offset = a.bytes;
    if io.write_all(&mut a.file, buf).is_err() {
        // the segment may now hold a torn record: abandon it so the
        // tail is never extended (it scans as a clean partial segment).
        // Account the file's *real* size — the torn bytes occupy disk
        // until the segment retires, same as the boot-time scan's view
        let id = a.id;
        let bytes = a
            .file
            .metadata()
            .map(|m| m.len())
            .unwrap_or(a.bytes + buf.len() as u64);
        *active = None;
        if bytes == 0 {
            // nothing landed: no torn tail to protect, drop the file
            let _ = std::fs::remove_file(segment_path(&cfg.dir, id));
        } else {
            let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
            s.segments.insert(id, bytes);
        }
        return Err(());
    }
    a.bytes += buf.len() as u64;
    let (id, seg_bytes) = (a.id, a.bytes);
    let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
    s.segments.insert(id, seg_bytes);
    s.pending.remove(&key);
    s.dir.insert(
        key,
        super::DirEntry {
            segment: id,
            offset,
            len: buf.len() as u64,
            parent,
            tokens: tokens.to_vec(),
            start_slot,
            score,
        },
    );
    s.stats.spilled += 1;
    drop(s);
    // compaction: before whole segments retire below, rewrite their
    // directory-live high-score records into the active segment so a
    // tight budget ages out garbage instead of hot roots
    if cfg.compact_score_threshold > 0 {
        compact_pass(cfg, shared, io, active);
    }
    let protect = active.as_ref().map(|a| a.id);
    let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
    // budget: retire whole oldest segments (never the active one);
    // their directory entries age out with them.  Files are unlinked
    // after the lock drops — lookups racing the unlink read as misses
    let (retired, _) = s.retire_over_budget(cfg.budget_bytes, protect);
    drop(s);
    for old in retired {
        let _ = std::fs::remove_file(segment_path(&cfg.dir, old));
    }
    Ok(())
}

/// One compaction pass, run on the spill thread right before budget
/// retirement.  Previews which whole segments
/// [`Shared::retire_over_budget`] is about to drop, and rewrites their
/// directory-live records whose retention score clears
/// `StoreConfig::compact_score_threshold` into the active segment —
/// highest score first, verbatim bytes (the embedded CRC and identity
/// ride along, so a rescued record is exactly as verified as a fresh
/// one), at most `compact_max_bytes_per_pass` bytes per pass.  All I/O
/// goes through the [`SegmentIo`] transport, so fault injection covers
/// the rescue reads and writes too: a failed read skips that record
/// (it ages out as if compaction were off), a failed write abandons
/// the active segment exactly like a failed spill append (the torn
/// tail is never extended).
fn compact_pass(
    cfg: &StoreConfig,
    shared: &Arc<Mutex<Shared>>,
    io: &Arc<dyn SegmentIo>,
    active: &mut Option<ActiveSegment>,
) {
    // under the lock: preview the doomed segments and collect their
    // rescue-worthy records; all I/O happens after the lock drops
    let mut victims: Vec<(PrefixKey, u64, u64, u64, u32)> = {
        let s = shared.lock().unwrap_or_else(|e| e.into_inner());
        let doomed: HashSet<u64> = s
            .would_retire(cfg.budget_bytes, active.as_ref().map(|a| a.id))
            .into_iter()
            .collect();
        if doomed.is_empty() {
            return;
        }
        s.dir
            .iter()
            .filter(|(_, e)| {
                doomed.contains(&e.segment) && e.score >= cfg.compact_score_threshold
            })
            .map(|(k, e)| (*k, e.segment, e.offset, e.len, e.score))
            .collect()
    };
    if victims.is_empty() {
        return;
    }
    // hottest first, so the per-pass byte budget saves the records the
    // retention policy values most; segment order breaks ties to keep
    // source reads clustered
    victims.sort_by(|x, y| y.4.cmp(&x.4).then(x.1.cmp(&y.1)));
    let mut budget = cfg.compact_max_bytes_per_pass;
    let mut buf: Vec<u8> = Vec::new();
    let mut src: Option<(u64, File)> = None;
    let mut rescued_from: HashSet<u64> = HashSet::new();
    for (key, seg, offset, len, _score) in victims {
        if len > budget {
            continue;
        }
        let Some(a) = active.as_mut() else { return };
        if src.as_ref().map(|(id, _)| *id) != Some(seg) {
            src = match io.open_read(&segment_path(&cfg.dir, seg)) {
                Ok(f) => Some((seg, f)),
                Err(_) => None,
            };
        }
        let Some((_, f)) = src.as_mut() else { continue };
        buf.clear();
        buf.resize(len as usize, 0);
        if io.read_exact_at(f, offset, &mut buf).is_err() {
            continue;
        }
        let new_offset = a.bytes;
        if io.write_all(&mut a.file, &buf).is_err() {
            // same poisoning discipline as a failed spill append: the
            // active segment may hold a torn record now, so abandon it
            // at its real on-disk size and let the next append start a
            // fresh one
            let id = a.id;
            let bytes = a.file.metadata().map(|m| m.len()).unwrap_or(a.bytes);
            *active = None;
            let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
            s.segments.insert(id, bytes);
            return;
        }
        a.bytes += len;
        budget -= len;
        let (aid, abytes) = (a.id, a.bytes);
        let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
        s.segments.insert(aid, abytes);
        // re-point the directory only if it still references the copy
        // we just rescued (a racing failed read may have dropped it)
        if let Some(e) = s.dir.get_mut(&key) {
            if e.segment == seg && e.offset == offset {
                e.segment = aid;
                e.offset = new_offset;
                s.stats.records_compacted += 1;
                if rescued_from.insert(seg) {
                    s.stats.segments_compacted += 1;
                }
            }
        }
    }
}
