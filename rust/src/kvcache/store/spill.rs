//! Write-behind spill worker: the background thread that makes parked
//! prefix pages durable.
//!
//! The cache manager's zero-ref parking path feeds this thread through
//! [`super::PageStore::spill`] — under either index backend: the flat
//! index passes its entry's chain link verbatim, the radix index
//! derives the identical `(key, parent, tokens)` edge from the parked
//! page's tree path, so the worker (and the on-disk format) is
//! index-agnostic.  Each job owns a copy of the page bytes, so the RAM
//! copy can be evicted the moment the job is queued.  The
//! worker appends records to the active segment, rotates at
//! `segment_bytes`, and enforces the byte budget by retiring whole
//! oldest segments (never the active one).  A failed append poisons the
//! active segment (the next job starts a fresh one) so a half-written
//! record is never extended — on the next boot the damaged tail reads
//! as a clean end-of-segment.
//!
//! Durability: segment data is flushed on every append (plain
//! `write_all` on an unbuffered `File`) and fsync'd on [`Job::Flush`]
//! and at shutdown; per-record fsync is deliberately not done (the
//! store is a cache of recomputable artifacts — losing the last few
//! records to a crash costs a re-encode, not correctness).

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Result;

use super::super::page::PrefixKey;
use super::{record, segment_path, Shared, StoreConfig};

pub(crate) enum Job {
    Spill {
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: Vec<i32>,
        page: Vec<u8>,
    },
    /// fsync the active segment, then ack
    Flush(mpsc::Sender<()>),
}

pub(crate) fn spawn(
    cfg: StoreConfig,
    shared: Arc<Mutex<Shared>>,
    rx: mpsc::Receiver<Job>,
    next_segment: u64,
) -> Result<std::thread::JoinHandle<()>> {
    Ok(std::thread::Builder::new()
        .name("isoquant-spill".into())
        .spawn(move || worker(cfg, shared, rx, next_segment))?)
}

struct ActiveSegment {
    id: u64,
    file: File,
    bytes: u64,
}

fn worker(cfg: StoreConfig, shared: Arc<Mutex<Shared>>, rx: mpsc::Receiver<Job>, mut next_id: u64) {
    let mut active: Option<ActiveSegment> = None;
    let mut buf: Vec<u8> = Vec::new();
    // recv drains every queued job before reporting disconnect, so
    // dropping the sender (PageStore::drop) is a clean "finish all
    // pending spills, then exit"
    while let Ok(job) = rx.recv() {
        match job {
            Job::Flush(ack) => {
                if let Some(a) = active.as_ref() {
                    let _ = a.file.sync_all();
                }
                let _ = ack.send(());
            }
            Job::Spill {
                key,
                parent,
                tokens,
                page,
            } => {
                append_one(&cfg, &shared, &mut active, &mut next_id, &mut buf, key, parent, &tokens, &page);
            }
        }
    }
    if let Some(a) = active.as_ref() {
        let _ = a.file.sync_all();
    }
}

#[allow(clippy::too_many_arguments)]
fn append_one(
    cfg: &StoreConfig,
    shared: &Arc<Mutex<Shared>>,
    active: &mut Option<ActiveSegment>,
    next_id: &mut u64,
    buf: &mut Vec<u8>,
    key: PrefixKey,
    parent: Option<PrefixKey>,
    tokens: &[i32],
    page: &[u8],
) {
    // rotate once the active segment crossed the threshold
    if active.as_ref().is_some_and(|a| a.bytes >= cfg.segment_bytes) {
        if let Some(a) = active.take() {
            let _ = a.file.sync_all();
        }
    }
    if active.is_none() {
        let id = *next_id;
        match OpenOptions::new()
            .create_new(true)
            .write(true)
            .open(segment_path(&cfg.dir, id))
        {
            Ok(file) => {
                *next_id += 1;
                *active = Some(ActiveSegment { id, file, bytes: 0 });
            }
            Err(_) => {
                // move past the failed id either way: a create_new
                // collision (e.g. another writer took this id) must
                // not wedge every future spill on the same name
                *next_id += 1;
                let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
                s.pending.remove(&key);
                s.stats.spill_errors += 1;
                return;
            }
        }
    }
    let a = active.as_mut().unwrap();
    buf.clear();
    record::encode_record(buf, key, parent, cfg.fingerprint, tokens, page);
    let offset = a.bytes;
    if a.file.write_all(buf).is_err() {
        // the segment may now hold a torn record: abandon it so the
        // tail is never extended (it scans as a clean partial segment).
        // Account the file's *real* size — the torn bytes occupy disk
        // until the segment retires, same as the boot-time scan's view
        let id = a.id;
        let bytes = a
            .file
            .metadata()
            .map(|m| m.len())
            .unwrap_or(a.bytes + buf.len() as u64);
        *active = None;
        let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
        s.segments.insert(id, bytes);
        s.pending.remove(&key);
        s.stats.spill_errors += 1;
        return;
    }
    a.bytes += buf.len() as u64;
    let (id, seg_bytes) = (a.id, a.bytes);
    let mut s = shared.lock().unwrap_or_else(|e| e.into_inner());
    s.segments.insert(id, seg_bytes);
    s.pending.remove(&key);
    s.dir.insert(
        key,
        super::DirEntry {
            segment: id,
            offset,
            len: buf.len() as u64,
            parent,
            tokens: tokens.to_vec(),
        },
    );
    s.stats.spilled += 1;
    // budget: retire whole oldest segments (never the active one);
    // their directory entries age out with them.  Files are unlinked
    // after the lock drops — lookups racing the unlink read as misses
    let (retired, _) = s.retire_over_budget(cfg.budget_bytes, Some(id));
    drop(s);
    for old in retired {
        let _ = std::fs::remove_file(segment_path(&cfg.dir, old));
    }
}
