//! I/O shim for the persistent store: every byte the spill worker
//! writes and every cold byte the read paths fetch goes through a
//! [`SegmentIo`], so tests can stand in a deterministic fault injector
//! where production uses the passthrough [`RealIo`].
//!
//! The injector ([`FaultyIo`]) is driven by a [`FaultPlan`]: per
//! operation kind (segment create, record write, read open, record
//! read), a set of op indices at which that operation fails.  Indices
//! count per kind from store open, so a plan like "3rd write returns
//! `ENOSPC`, 1st promotion read returns `EIO`" replays identically on
//! every run — the store's degrade/miss behavior under failing disks
//! becomes a regression test instead of an outage postmortem.  Short
//! writes land half the record before failing, exercising the
//! torn-tail abandonment path the boot scan must survive.

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The store's view of segment I/O.  Production is a passthrough to
/// `std::fs`; tests inject failures.  Only the *buffered* transports
/// route through here — the mmap read path is a plain memory view and
/// fault tests run with `StoreConfig::mmap` off (a vanished or short
/// mapping already falls back to the buffered path, which is shimmed).
pub trait SegmentIo: Send + Sync {
    /// Create a fresh segment file (the worker never reopens one).
    fn create_new(&self, path: &Path) -> io::Result<File>;
    /// Open a segment for reading.
    fn open_read(&self, path: &Path) -> io::Result<File>;
    /// Append one encoded record to the active segment.
    fn write_all(&self, file: &mut File, buf: &[u8]) -> io::Result<()>;
    /// fsync the active segment.
    fn sync(&self, file: &File) -> io::Result<()>;
    /// Read exactly `buf.len()` bytes at `offset`.
    fn read_exact_at(&self, file: &mut File, offset: u64, buf: &mut [u8]) -> io::Result<()>;
}

/// Production passthrough: plain `std::fs` calls, no bookkeeping.
#[derive(Debug, Default)]
pub struct RealIo;

impl SegmentIo for RealIo {
    fn create_new(&self, path: &Path) -> io::Result<File> {
        OpenOptions::new().create_new(true).write(true).open(path)
    }

    fn open_read(&self, path: &Path) -> io::Result<File> {
        File::open(path)
    }

    fn write_all(&self, file: &mut File, buf: &[u8]) -> io::Result<()> {
        file.write_all(buf)
    }

    fn sync(&self, file: &File) -> io::Result<()> {
        file.sync_all()
    }

    fn read_exact_at(&self, file: &mut File, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        file.seek(SeekFrom::Start(offset))?;
        file.read_exact(buf)
    }
}

/// Deterministic fault schedule: per operation kind, the op indices
/// (counted from store open, per kind) that fail.  Empty plan = no
/// faults (behaves exactly like [`RealIo`]).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// `create_new` indices that fail (segment creation — `ENOSPC`)
    pub fail_creates: Vec<u64>,
    /// `write_all` indices that fail cleanly, landing zero bytes
    pub fail_writes: Vec<u64>,
    /// `write_all` indices that land *half* the record, then fail —
    /// leaves a torn tail on the active segment
    pub short_writes: Vec<u64>,
    /// `open_read` indices that fail (`EIO`)
    pub fail_opens: Vec<u64>,
    /// `read_exact_at` indices that fail (`EIO`)
    pub fail_reads: Vec<u64>,
}

impl FaultPlan {
    /// Every spill write fails — the fastest route to degraded mode.
    pub fn all_writes_fail() -> FaultPlan {
        FaultPlan {
            // u64::MAX as an open-ended sentinel would need range
            // support; a long explicit prefix is plenty for tests
            fail_writes: (0..10_000).collect(),
            ..FaultPlan::default()
        }
    }
}

/// Test injector: counts operations per kind and fails the ones the
/// plan names; everything else passes straight through to `std::fs`.
/// Counters are atomics so the spill worker thread and reader threads
/// can share one injector.
#[derive(Debug)]
pub struct FaultyIo {
    plan: FaultPlan,
    creates: AtomicU64,
    writes: AtomicU64,
    opens: AtomicU64,
    reads: AtomicU64,
}

impl FaultyIo {
    pub fn new(plan: FaultPlan) -> Arc<FaultyIo> {
        Arc::new(FaultyIo {
            plan,
            creates: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            opens: AtomicU64::new(0),
            reads: AtomicU64::new(0),
        })
    }

    fn err(kind: io::ErrorKind, what: &str) -> io::Error {
        io::Error::new(kind, format!("injected fault: {what}"))
    }
}

impl SegmentIo for FaultyIo {
    fn create_new(&self, path: &Path) -> io::Result<File> {
        let n = self.creates.fetch_add(1, Ordering::Relaxed);
        if self.plan.fail_creates.contains(&n) {
            return Err(Self::err(io::ErrorKind::Other, "create ENOSPC"));
        }
        RealIo.create_new(path)
    }

    fn open_read(&self, path: &Path) -> io::Result<File> {
        let n = self.opens.fetch_add(1, Ordering::Relaxed);
        if self.plan.fail_opens.contains(&n) {
            return Err(Self::err(io::ErrorKind::Other, "open EIO"));
        }
        RealIo.open_read(path)
    }

    fn write_all(&self, file: &mut File, buf: &[u8]) -> io::Result<()> {
        let n = self.writes.fetch_add(1, Ordering::Relaxed);
        if self.plan.fail_writes.contains(&n) {
            return Err(Self::err(io::ErrorKind::Other, "write ENOSPC"));
        }
        if self.plan.short_writes.contains(&n) {
            // land a torn half-record, then report the disk full
            let _ = file.write_all(&buf[..buf.len() / 2]);
            return Err(Self::err(io::ErrorKind::Other, "short write + ENOSPC"));
        }
        file.write_all(buf)
    }

    fn sync(&self, file: &File) -> io::Result<()> {
        file.sync_all()
    }

    fn read_exact_at(&self, file: &mut File, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        let n = self.reads.fetch_add(1, Ordering::Relaxed);
        if self.plan.fail_reads.contains(&n) {
            return Err(Self::err(io::ErrorKind::Other, "read EIO"));
        }
        RealIo.read_exact_at(file, offset, buf)
    }
}
