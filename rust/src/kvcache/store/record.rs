//! On-disk record format of the persistent page store.
//!
//! One record = one sealed prompt page plus everything a cold boot
//! needs to re-verify it before trusting a single byte:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"IQPG"
//!      4     2  version (= 2, little-endian; 1 still readable)
//!      6     2  flags   (bit 0: parent key present, bit 1: sub-run ext)
//!      8     8  key         (PrefixKey)
//!     16     8  parent      (0 when flags bit 0 is clear)
//!     24     8  fingerprint (Stage1Config fingerprint ⊕ page geometry)
//!     32     4  n_tokens    (token ids covered by this page)
//!     36     4  page_len    (bytes of page payload)
//!     40     4  crc32       (IEEE, over bytes [4..40) ++ ext ++ tokens ++ page)
//!     44     8  ext: start_slot u32, score u32   (only when flags bit 1)
//!      …     …  tokens      (n_tokens × i32, little-endian)
//!      …     …  page bytes  (page_len)
//! ```
//!
//! The version-2 **sub-run extension** records where inside the page
//! the covered run begins (`start_slot` — a run published at a radix
//! split point starts mid-page, so a warm boot would otherwise lose
//! that partial-page coverage) and the `(reuse + 1) / (depth + 1)`
//! retention score the page held when it was spilled, in
//! `SCORE_SCALE` fixed point (the segment compactor ranks live records
//! by it).  Version-1 records parse as `start_slot = 0, score = 0` —
//! page-aligned, compacted only above a zero threshold — so stores
//! written before the extension stay readable; a version this reader
//! does not know is corruption, never a guess.
//!
//! The trust model mirrors the in-RAM [`super::super::prefix::PrefixIndex`]:
//! a key alone is never believed.  A record is only served when the
//! magic/version parse, the CRC covers the *exact* token run and page
//! bytes, the fingerprint matches the booting cache's stage-1 config +
//! page geometry, and the caller's token run equals the stored one.
//! Anything less — truncation, a flipped bit, a record written by a
//! different config — reads as a **miss**, never as another prompt's
//! pages.
//!
//! # Records are edges
//!
//! The `(parent, key, tokens)` triple serializes one *edge* of the
//! prefix structure: `parent` is the chain key of everything before
//! this page, `tokens` is the run the page covers, and `key` extends
//! the chain over it.  Replaying a store's records therefore
//! reconstructs the whole prefix graph, and both index backends speak
//! it: the flat [`super::super::prefix::PrefixIndex`] resolves records
//! by exact chain key, while the radix
//! [`super::super::radix::RadixIndex`] re-inserts promoted runs as
//! tree nodes and derives the same `(parent, key)` pair from a parked
//! page's tree path when spilling (`RadixIndex::page_run`) — so a
//! store written under `prefix_index = flat` rehydrates under `radix`
//! and vice versa, with no format change.

use std::io::Read;

use super::super::page::PrefixKey;

pub const MAGIC: [u8; 4] = *b"IQPG";
/// Newest format this writer emits (and the newest this reader knows).
pub const VERSION: u16 = 2;
/// The pre-sub-run format; still fully readable.
pub const VERSION_V1: u16 = 1;
pub const HEADER_LEN: usize = 44;
/// Bytes of the version-2 sub-run extension (`start_slot` + `score`).
pub const EXT_LEN: usize = 8;
const FLAG_HAS_PARENT: u16 = 1;
const FLAG_HAS_EXT: u16 = 2;

/// Upper bounds used only to reject absurd length fields before any
/// allocation happens (a corrupt header must not OOM the scan).
const MAX_TOKENS: u32 = 1 << 20;
const MAX_PAGE_LEN: u32 = 1 << 30;

/// One fully parsed and CRC-verified record.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub key: PrefixKey,
    pub parent: Option<PrefixKey>,
    pub fingerprint: u64,
    pub tokens: Vec<i32>,
    pub page: Vec<u8>,
    /// slot inside the page where the covered run begins (version-2
    /// sub-run extension; 0 for version-1 records)
    pub start_slot: u32,
    /// retention score at spill time, `SCORE_SCALE` fixed point
    /// (version-2 sub-run extension; 0 for version-1 records)
    pub score: u32,
    /// whether the serialized form carried the sub-run extension
    /// (length accounting for mixed-version segment scans)
    pub has_ext: bool,
}

impl Record {
    /// Total serialized size of this record.
    pub fn encoded_len(&self) -> usize {
        let ext = if self.has_ext { EXT_LEN } else { 0 };
        HEADER_LEN + ext + self.tokens.len() * 4 + self.page.len()
    }
}

/// Serialized size of a freshly written (version-2, extension-bearing)
/// record.
pub fn record_len(n_tokens: usize, page_len: usize) -> usize {
    HEADER_LEN + EXT_LEN + n_tokens * 4 + page_len
}

/// Serialize a record, appending to `out`.  Always writes the newest
/// format (version 2 with the sub-run extension).
#[allow(clippy::too_many_arguments)]
pub fn encode_record(
    out: &mut Vec<u8>,
    key: PrefixKey,
    parent: Option<PrefixKey>,
    fingerprint: u64,
    tokens: &[i32],
    page: &[u8],
    start_slot: u32,
    score: u32,
) {
    let mut flags: u16 = FLAG_HAS_EXT;
    if parent.is_some() {
        flags |= FLAG_HAS_PARENT;
    }
    out.reserve(record_len(tokens.len(), page.len()));
    out.extend_from_slice(&MAGIC);
    let body_start = out.len();
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&key.0.to_le_bytes());
    out.extend_from_slice(&parent.map(|k| k.0).unwrap_or(0).to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    out.extend_from_slice(&(page.len() as u32).to_le_bytes());
    let mut ext = [0u8; EXT_LEN];
    ext[0..4].copy_from_slice(&start_slot.to_le_bytes());
    ext[4..8].copy_from_slice(&score.to_le_bytes());
    let mut crc = Crc32::new();
    crc.update(&out[body_start..]);
    crc.update(&ext);
    for &t in tokens {
        crc.update(&(t as u32).to_le_bytes());
    }
    crc.update(page);
    out.extend_from_slice(&crc.finish().to_le_bytes());
    out.extend_from_slice(&ext);
    for &t in tokens {
        out.extend_from_slice(&(t as u32).to_le_bytes());
    }
    out.extend_from_slice(page);
}

/// Serialize a version-1 record (no sub-run extension).  Production
/// code always writes version 2; this exists so compatibility tests can
/// build byte-exact old-format stores.
pub fn encode_record_v1(
    out: &mut Vec<u8>,
    key: PrefixKey,
    parent: Option<PrefixKey>,
    fingerprint: u64,
    tokens: &[i32],
    page: &[u8],
) {
    let flags: u16 = if parent.is_some() { FLAG_HAS_PARENT } else { 0 };
    out.reserve(HEADER_LEN + tokens.len() * 4 + page.len());
    out.extend_from_slice(&MAGIC);
    let body_start = out.len();
    out.extend_from_slice(&VERSION_V1.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&key.0.to_le_bytes());
    out.extend_from_slice(&parent.map(|k| k.0).unwrap_or(0).to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    out.extend_from_slice(&(page.len() as u32).to_le_bytes());
    let mut crc = Crc32::new();
    crc.update(&out[body_start..]);
    for &t in tokens {
        crc.update(&(t as u32).to_le_bytes());
    }
    crc.update(page);
    out.extend_from_slice(&crc.finish().to_le_bytes());
    for &t in tokens {
        out.extend_from_slice(&(t as u32).to_le_bytes());
    }
    out.extend_from_slice(page);
}

/// What one attempted record read produced.
#[derive(Debug)]
pub enum ReadOutcome {
    /// clean end of the segment (zero bytes where the next header
    /// would start)
    Eof,
    /// a fully verified record
    Ok(Record),
    /// a structurally valid, CRC-clean record that belongs to another
    /// cache (stage-1 config / page geometry fingerprint differs) —
    /// safe to skip and keep scanning
    Stale(Record),
    /// the segment is damaged from here on (bad magic/version, absurd
    /// lengths, truncation, or CRC failure) — the scan of this segment
    /// must stop; everything already returned stays trustworthy
    Corrupt(&'static str),
}

/// Read and verify one record.  `expect_fingerprint` and
/// `expect_page_len` pin the booting cache's identity; a CRC-clean
/// record that does not match them is [`ReadOutcome::Stale`].
pub fn read_record(
    r: &mut impl Read,
    expect_fingerprint: u64,
    expect_page_len: usize,
) -> ReadOutcome {
    let mut header = [0u8; HEADER_LEN];
    match read_exact_or_eof(r, &mut header) {
        Fill::Eof => return ReadOutcome::Eof,
        Fill::Partial => return ReadOutcome::Corrupt("truncated header"),
        Fill::Full => {}
    }
    if header[0..4] != MAGIC {
        return ReadOutcome::Corrupt("bad magic");
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION && version != VERSION_V1 {
        return ReadOutcome::Corrupt("unknown version");
    }
    let flags = u16::from_le_bytes([header[6], header[7]]);
    let key = PrefixKey(le_u64(&header[8..16]));
    let parent_raw = le_u64(&header[16..24]);
    let fingerprint = le_u64(&header[24..32]);
    let n_tokens = u32::from_le_bytes(header[32..36].try_into().unwrap());
    let page_len = u32::from_le_bytes(header[36..40].try_into().unwrap());
    let crc_stored = u32::from_le_bytes(header[40..44].try_into().unwrap());
    if n_tokens > MAX_TOKENS || page_len > MAX_PAGE_LEN {
        return ReadOutcome::Corrupt("absurd length field");
    }
    // the sub-run extension exists only in version 2; a version-1
    // record claiming it is malformed
    let has_ext = flags & FLAG_HAS_EXT != 0;
    if has_ext && version == VERSION_V1 {
        return ReadOutcome::Corrupt("v1 record with v2 extension flag");
    }
    let mut ext = [0u8; EXT_LEN];
    if has_ext && !matches!(read_exact_or_eof(r, &mut ext), Fill::Full) {
        return ReadOutcome::Corrupt("truncated extension");
    }
    let mut tok_bytes = vec![0u8; n_tokens as usize * 4];
    if !matches!(read_exact_or_eof(r, &mut tok_bytes), Fill::Full) {
        return ReadOutcome::Corrupt("truncated token run");
    }
    let mut page = vec![0u8; page_len as usize];
    if !matches!(read_exact_or_eof(r, &mut page), Fill::Full) {
        return ReadOutcome::Corrupt("truncated page payload");
    }
    let mut crc = Crc32::new();
    crc.update(&header[4..40]);
    if has_ext {
        crc.update(&ext);
    }
    crc.update(&tok_bytes);
    crc.update(&page);
    if crc.finish() != crc_stored {
        return ReadOutcome::Corrupt("crc mismatch");
    }
    let parent = (flags & FLAG_HAS_PARENT != 0).then_some(PrefixKey(parent_raw));
    let tokens = tok_bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as i32)
        .collect();
    let start_slot = u32::from_le_bytes(ext[0..4].try_into().unwrap());
    let score = u32::from_le_bytes(ext[4..8].try_into().unwrap());
    let rec = Record {
        key,
        parent,
        fingerprint,
        tokens,
        page,
        start_slot,
        score,
        has_ext,
    };
    if fingerprint != expect_fingerprint || page_len as usize != expect_page_len {
        ReadOutcome::Stale(rec)
    } else {
        ReadOutcome::Ok(rec)
    }
}

enum Fill {
    Full,
    Partial,
    Eof,
}

/// `read_exact` that distinguishes a clean EOF at offset 0 (the normal
/// end of a segment) from a mid-record truncation.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Fill {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return if got == 0 { Fill::Eof } else { Fill::Partial },
            Ok(n) => got += n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Fill::Partial,
        }
    }
    Fill::Full
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b.try_into().unwrap())
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3), table-driven — no external crates in the
// offline build, and the polynomial choice matches what readers expect
// from a "crc32" field.
// ---------------------------------------------------------------------

pub struct Crc32 {
    state: u32,
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(parent: bool) -> Vec<u8> {
        let mut buf = Vec::new();
        encode_record(
            &mut buf,
            PrefixKey(0xABCD),
            parent.then_some(PrefixKey(0x1234)),
            77,
            &[5, -2, 900_000],
            &[9u8; 64],
            3,
            0x0002_8000,
        );
        buf
    }

    fn sample_v1(parent: bool) -> Vec<u8> {
        let mut buf = Vec::new();
        encode_record_v1(
            &mut buf,
            PrefixKey(0xABCD),
            parent.then_some(PrefixKey(0x1234)),
            77,
            &[5, -2, 900_000],
            &[9u8; 64],
        );
        buf
    }

    #[test]
    fn crc32_known_vector() {
        // the classic check value: CRC-32("123456789") = 0xCBF43926
        let mut c = Crc32::new();
        c.update(b"123456789");
        assert_eq!(c.finish(), 0xCBF4_3926);
        // streaming in pieces matches one-shot
        let mut s = Crc32::new();
        s.update(b"1234");
        s.update(b"56789");
        assert_eq!(s.finish(), 0xCBF4_3926);
    }

    #[test]
    fn roundtrip_with_and_without_parent() {
        for parent in [false, true] {
            let buf = sample(parent);
            assert_eq!(buf.len(), record_len(3, 64));
            let mut r = &buf[..];
            match read_record(&mut r, 77, 64) {
                ReadOutcome::Ok(rec) => {
                    assert_eq!(rec.key, PrefixKey(0xABCD));
                    assert_eq!(rec.parent, parent.then_some(PrefixKey(0x1234)));
                    assert_eq!(rec.tokens, vec![5, -2, 900_000]);
                    assert_eq!(rec.page, vec![9u8; 64]);
                    assert_eq!(rec.start_slot, 3);
                    assert_eq!(rec.score, 0x0002_8000);
                    assert!(rec.has_ext);
                    assert_eq!(rec.encoded_len(), buf.len());
                }
                other => panic!("expected Ok, got {other:?}"),
            }
            // the stream is fully consumed: next read is a clean EOF
            assert!(matches!(read_record(&mut r, 77, 64), ReadOutcome::Eof));
        }
    }

    #[test]
    fn version1_records_stay_readable() {
        for parent in [false, true] {
            let buf = sample_v1(parent);
            assert_eq!(buf.len(), record_len(3, 64) - EXT_LEN);
            let mut r = &buf[..];
            match read_record(&mut r, 77, 64) {
                ReadOutcome::Ok(rec) => {
                    assert_eq!(rec.key, PrefixKey(0xABCD));
                    assert_eq!(rec.parent, parent.then_some(PrefixKey(0x1234)));
                    assert_eq!(rec.tokens, vec![5, -2, 900_000]);
                    assert_eq!(rec.page, vec![9u8; 64]);
                    assert_eq!(rec.start_slot, 0, "v1 records are page-aligned");
                    assert_eq!(rec.score, 0);
                    assert!(!rec.has_ext);
                    assert_eq!(rec.encoded_len(), buf.len());
                }
                other => panic!("expected Ok, got {other:?}"),
            }
            assert!(matches!(read_record(&mut r, 77, 64), ReadOutcome::Eof));
        }
    }

    #[test]
    fn mixed_version_stream_parses_record_by_record() {
        let mut buf = sample_v1(true);
        buf.extend_from_slice(&sample(true));
        let mut r = &buf[..];
        let first = read_record(&mut r, 77, 64);
        let second = read_record(&mut r, 77, 64);
        assert!(matches!(first, ReadOutcome::Ok(ref rec) if !rec.has_ext));
        assert!(matches!(second, ReadOutcome::Ok(ref rec) if rec.has_ext));
        assert!(matches!(read_record(&mut r, 77, 64), ReadOutcome::Eof));
    }

    #[test]
    fn v1_with_ext_flag_is_corrupt() {
        let mut buf = sample_v1(false);
        // force the ext flag on and fix the CRC so only the version/flag
        // contract itself rejects the record
        buf[6] |= FLAG_HAS_EXT as u8;
        let mut crc = Crc32::new();
        crc.update(&buf[4..40]);
        crc.update(&buf[44..]);
        buf[40..44].copy_from_slice(&crc.finish().to_le_bytes());
        assert!(matches!(
            read_record(&mut &buf[..], 77, 64),
            ReadOutcome::Corrupt("v1 record with v2 extension flag")
        ));
    }

    #[test]
    fn future_version_is_corrupt() {
        let mut buf = sample(false);
        buf[4..6].copy_from_slice(&3u16.to_le_bytes());
        assert!(matches!(
            read_record(&mut &buf[..], 77, 64),
            ReadOutcome::Corrupt("unknown version")
        ));
    }

    #[test]
    fn wrong_fingerprint_or_page_len_is_stale_not_corrupt() {
        let buf = sample(true);
        assert!(matches!(
            read_record(&mut &buf[..], 78, 64),
            ReadOutcome::Stale(_)
        ));
        assert!(matches!(
            read_record(&mut &buf[..], 77, 65),
            ReadOutcome::Stale(_)
        ));
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let buf = sample(true);
        for bit in 0..buf.len() * 8 {
            let mut bad = buf.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            // the CRC covers everything after the magic, and a magic /
            // CRC-field flip fails its own check, so *every* flip must
            // surface as Corrupt — never as a valid or stale record
            match read_record(&mut &bad[..], 77, 64) {
                ReadOutcome::Corrupt(_) => {}
                other => panic!("bit {bit}: flip read as {other:?}"),
            }
        }
    }

    #[test]
    fn truncation_at_any_point_is_corrupt() {
        let buf = sample(false);
        for cut in 1..buf.len() {
            match read_record(&mut &buf[..cut], 77, 64) {
                ReadOutcome::Corrupt(_) => {}
                other => panic!("cut at {cut}: {other:?}"),
            }
        }
        // cutting to zero bytes is the clean EOF
        assert!(matches!(read_record(&mut &buf[..0], 77, 64), ReadOutcome::Eof));
    }

    #[test]
    fn absurd_lengths_rejected_before_allocation() {
        let mut buf = sample(false);
        buf[32..36].copy_from_slice(&u32::MAX.to_le_bytes()); // n_tokens
        assert!(matches!(
            read_record(&mut &buf[..], 77, 64),
            ReadOutcome::Corrupt("absurd length field")
        ));
    }
}
