//! On-disk record format of the persistent page store.
//!
//! One record = one sealed prompt page plus everything a cold boot
//! needs to re-verify it before trusting a single byte:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"IQPG"
//!      4     2  version (= 1, little-endian)
//!      6     2  flags   (bit 0: parent key present)
//!      8     8  key         (PrefixKey)
//!     16     8  parent      (0 when flags bit 0 is clear)
//!     24     8  fingerprint (Stage1Config fingerprint ⊕ page geometry)
//!     32     4  n_tokens    (token ids covered by this page)
//!     36     4  page_len    (bytes of page payload)
//!     40     4  crc32       (IEEE, over bytes [4..40) ++ tokens ++ page)
//!     44     …  tokens      (n_tokens × i32, little-endian)
//!      …     …  page bytes  (page_len)
//! ```
//!
//! The trust model mirrors the in-RAM [`super::super::prefix::PrefixIndex`]:
//! a key alone is never believed.  A record is only served when the
//! magic/version parse, the CRC covers the *exact* token run and page
//! bytes, the fingerprint matches the booting cache's stage-1 config +
//! page geometry, and the caller's token run equals the stored one.
//! Anything less — truncation, a flipped bit, a record written by a
//! different config — reads as a **miss**, never as another prompt's
//! pages.
//!
//! # Records are edges
//!
//! The `(parent, key, tokens)` triple serializes one *edge* of the
//! prefix structure: `parent` is the chain key of everything before
//! this page, `tokens` is the run the page covers, and `key` extends
//! the chain over it.  Replaying a store's records therefore
//! reconstructs the whole prefix graph, and both index backends speak
//! it: the flat [`super::super::prefix::PrefixIndex`] resolves records
//! by exact chain key, while the radix
//! [`super::super::radix::RadixIndex`] re-inserts promoted runs as
//! tree nodes and derives the same `(parent, key)` pair from a parked
//! page's tree path when spilling (`RadixIndex::page_run`) — so a
//! store written under `prefix_index = flat` rehydrates under `radix`
//! and vice versa, with no format change.

use std::io::Read;

use super::super::page::PrefixKey;

pub const MAGIC: [u8; 4] = *b"IQPG";
pub const VERSION: u16 = 1;
pub const HEADER_LEN: usize = 44;
const FLAG_HAS_PARENT: u16 = 1;

/// Upper bounds used only to reject absurd length fields before any
/// allocation happens (a corrupt header must not OOM the scan).
const MAX_TOKENS: u32 = 1 << 20;
const MAX_PAGE_LEN: u32 = 1 << 30;

/// One fully parsed and CRC-verified record.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub key: PrefixKey,
    pub parent: Option<PrefixKey>,
    pub fingerprint: u64,
    pub tokens: Vec<i32>,
    pub page: Vec<u8>,
}

impl Record {
    /// Total serialized size of this record.
    pub fn encoded_len(&self) -> usize {
        record_len(self.tokens.len(), self.page.len())
    }
}

pub fn record_len(n_tokens: usize, page_len: usize) -> usize {
    HEADER_LEN + n_tokens * 4 + page_len
}

/// Serialize a record, appending to `out`.
pub fn encode_record(
    out: &mut Vec<u8>,
    key: PrefixKey,
    parent: Option<PrefixKey>,
    fingerprint: u64,
    tokens: &[i32],
    page: &[u8],
) {
    let flags: u16 = if parent.is_some() { FLAG_HAS_PARENT } else { 0 };
    out.reserve(record_len(tokens.len(), page.len()));
    out.extend_from_slice(&MAGIC);
    let body_start = out.len();
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&key.0.to_le_bytes());
    out.extend_from_slice(&parent.map(|k| k.0).unwrap_or(0).to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    out.extend_from_slice(&(page.len() as u32).to_le_bytes());
    let mut crc = Crc32::new();
    crc.update(&out[body_start..]);
    for &t in tokens {
        crc.update(&(t as u32).to_le_bytes());
    }
    crc.update(page);
    out.extend_from_slice(&crc.finish().to_le_bytes());
    for &t in tokens {
        out.extend_from_slice(&(t as u32).to_le_bytes());
    }
    out.extend_from_slice(page);
}

/// What one attempted record read produced.
#[derive(Debug)]
pub enum ReadOutcome {
    /// clean end of the segment (zero bytes where the next header
    /// would start)
    Eof,
    /// a fully verified record
    Ok(Record),
    /// a structurally valid, CRC-clean record that belongs to another
    /// cache (stage-1 config / page geometry fingerprint differs) —
    /// safe to skip and keep scanning
    Stale(Record),
    /// the segment is damaged from here on (bad magic/version, absurd
    /// lengths, truncation, or CRC failure) — the scan of this segment
    /// must stop; everything already returned stays trustworthy
    Corrupt(&'static str),
}

/// Read and verify one record.  `expect_fingerprint` and
/// `expect_page_len` pin the booting cache's identity; a CRC-clean
/// record that does not match them is [`ReadOutcome::Stale`].
pub fn read_record(
    r: &mut impl Read,
    expect_fingerprint: u64,
    expect_page_len: usize,
) -> ReadOutcome {
    let mut header = [0u8; HEADER_LEN];
    match read_exact_or_eof(r, &mut header) {
        Fill::Eof => return ReadOutcome::Eof,
        Fill::Partial => return ReadOutcome::Corrupt("truncated header"),
        Fill::Full => {}
    }
    if header[0..4] != MAGIC {
        return ReadOutcome::Corrupt("bad magic");
    }
    if u16::from_le_bytes([header[4], header[5]]) != VERSION {
        return ReadOutcome::Corrupt("unknown version");
    }
    let flags = u16::from_le_bytes([header[6], header[7]]);
    let key = PrefixKey(le_u64(&header[8..16]));
    let parent_raw = le_u64(&header[16..24]);
    let fingerprint = le_u64(&header[24..32]);
    let n_tokens = u32::from_le_bytes(header[32..36].try_into().unwrap());
    let page_len = u32::from_le_bytes(header[36..40].try_into().unwrap());
    let crc_stored = u32::from_le_bytes(header[40..44].try_into().unwrap());
    if n_tokens > MAX_TOKENS || page_len > MAX_PAGE_LEN {
        return ReadOutcome::Corrupt("absurd length field");
    }
    let mut tok_bytes = vec![0u8; n_tokens as usize * 4];
    if !matches!(read_exact_or_eof(r, &mut tok_bytes), Fill::Full) {
        return ReadOutcome::Corrupt("truncated token run");
    }
    let mut page = vec![0u8; page_len as usize];
    if !matches!(read_exact_or_eof(r, &mut page), Fill::Full) {
        return ReadOutcome::Corrupt("truncated page payload");
    }
    let mut crc = Crc32::new();
    crc.update(&header[4..40]);
    crc.update(&tok_bytes);
    crc.update(&page);
    if crc.finish() != crc_stored {
        return ReadOutcome::Corrupt("crc mismatch");
    }
    let parent = (flags & FLAG_HAS_PARENT != 0).then_some(PrefixKey(parent_raw));
    let tokens = tok_bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as i32)
        .collect();
    let rec = Record {
        key,
        parent,
        fingerprint,
        tokens,
        page,
    };
    if fingerprint != expect_fingerprint || page_len as usize != expect_page_len {
        ReadOutcome::Stale(rec)
    } else {
        ReadOutcome::Ok(rec)
    }
}

enum Fill {
    Full,
    Partial,
    Eof,
}

/// `read_exact` that distinguishes a clean EOF at offset 0 (the normal
/// end of a segment) from a mid-record truncation.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Fill {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return if got == 0 { Fill::Eof } else { Fill::Partial },
            Ok(n) => got += n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Fill::Partial,
        }
    }
    Fill::Full
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b.try_into().unwrap())
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3), table-driven — no external crates in the
// offline build, and the polynomial choice matches what readers expect
// from a "crc32" field.
// ---------------------------------------------------------------------

pub struct Crc32 {
    state: u32,
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(parent: bool) -> Vec<u8> {
        let mut buf = Vec::new();
        encode_record(
            &mut buf,
            PrefixKey(0xABCD),
            parent.then_some(PrefixKey(0x1234)),
            77,
            &[5, -2, 900_000],
            &[9u8; 64],
        );
        buf
    }

    #[test]
    fn crc32_known_vector() {
        // the classic check value: CRC-32("123456789") = 0xCBF43926
        let mut c = Crc32::new();
        c.update(b"123456789");
        assert_eq!(c.finish(), 0xCBF4_3926);
        // streaming in pieces matches one-shot
        let mut s = Crc32::new();
        s.update(b"1234");
        s.update(b"56789");
        assert_eq!(s.finish(), 0xCBF4_3926);
    }

    #[test]
    fn roundtrip_with_and_without_parent() {
        for parent in [false, true] {
            let buf = sample(parent);
            assert_eq!(buf.len(), record_len(3, 64));
            let mut r = &buf[..];
            match read_record(&mut r, 77, 64) {
                ReadOutcome::Ok(rec) => {
                    assert_eq!(rec.key, PrefixKey(0xABCD));
                    assert_eq!(rec.parent, parent.then_some(PrefixKey(0x1234)));
                    assert_eq!(rec.tokens, vec![5, -2, 900_000]);
                    assert_eq!(rec.page, vec![9u8; 64]);
                }
                other => panic!("expected Ok, got {other:?}"),
            }
            // the stream is fully consumed: next read is a clean EOF
            assert!(matches!(read_record(&mut r, 77, 64), ReadOutcome::Eof));
        }
    }

    #[test]
    fn wrong_fingerprint_or_page_len_is_stale_not_corrupt() {
        let buf = sample(true);
        assert!(matches!(
            read_record(&mut &buf[..], 78, 64),
            ReadOutcome::Stale(_)
        ));
        assert!(matches!(
            read_record(&mut &buf[..], 77, 65),
            ReadOutcome::Stale(_)
        ));
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let buf = sample(true);
        for bit in 0..buf.len() * 8 {
            let mut bad = buf.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            // the CRC covers everything after the magic, and a magic /
            // CRC-field flip fails its own check, so *every* flip must
            // surface as Corrupt — never as a valid or stale record
            match read_record(&mut &bad[..], 77, 64) {
                ReadOutcome::Corrupt(_) => {}
                other => panic!("bit {bit}: flip read as {other:?}"),
            }
        }
    }

    #[test]
    fn truncation_at_any_point_is_corrupt() {
        let buf = sample(false);
        for cut in 1..buf.len() {
            match read_record(&mut &buf[..cut], 77, 64) {
                ReadOutcome::Corrupt(_) => {}
                other => panic!("cut at {cut}: {other:?}"),
            }
        }
        // cutting to zero bytes is the clean EOF
        assert!(matches!(read_record(&mut &buf[..0], 77, 64), ReadOutcome::Eof));
    }

    #[test]
    fn absurd_lengths_rejected_before_allocation() {
        let mut buf = sample(false);
        buf[32..36].copy_from_slice(&u32::MAX.to_le_bytes()); // n_tokens
        assert!(matches!(
            read_record(&mut &buf[..], 77, 64),
            ReadOutcome::Corrupt("absurd length field")
        ));
    }
}
