//! Persistent page store: a cross-restart home for sealed prompt pages.
//!
//! PR 3 made sealed prompt pages immutable, content-addressed byte
//! blocks (chained [`PrefixKey`]s salted with the stage-1 config
//! fingerprint), and the kernel-equivalence suite guarantees the bytes
//! are identical across scalar/AVX2/NEON backends — so a page is a
//! backend-portable artifact that is safe to persist verbatim and
//! rehydrate on a later boot, the way rotated-KV schemes treat the
//! quantized cache as a stable low-bit byte format rather than
//! transient activations.
//!
//! # Shape
//!
//! * **Segmented append-only log** — records (see [`record`]) are
//!   appended to `seg-<n>.iqs` files under the persist directory; a
//!   segment rotates once it crosses `segment_bytes`, and the byte
//!   budget is enforced by retiring whole oldest segments (their
//!   directory entries simply disappear — cold entries age out, they
//!   are never rewritten in place).
//! * **In-memory directory** — `PrefixKey → (segment, offset, token
//!   run, parent link)`, rebuilt by scanning the segments at
//!   [`PageStore::open`].  Like the RAM prefix index, the directory is
//!   a *hint*: every byte served goes back through full record
//!   verification at read time.
//! * **Write-behind spill worker** — [`PageStore::spill`] clones the
//!   page bytes into a job and returns immediately; a background
//!   thread (`spill.rs`) appends, rotates, and retires.  The clone is
//!   what lets pool pressure evict the RAM copy while the write is
//!   still in flight.
//! * **Single-writer lock** — a flock'd owner marker ([`LOCK_FILE`])
//!   makes a second server on the same directory fail loudly at boot
//!   instead of racing segment retirement against the first writer's
//!   appends.  The kernel drops the lock with the process, so a crash
//!   never leaves a stale lock.
//!
//! # Trust model (same as the RAM index, extended to disk)
//!
//! A record is served only when its CRC verifies, its fingerprint
//! matches the booting cache's stage-1 config + page geometry, and its
//! stored token run equals the run the caller is resolving.  A
//! truncated tail, a flipped bit, a stale config, or a hash collision
//! all read as a **miss** — the cache re-encodes, it never adopts
//! wrong bytes.  Corruption stops the scan of that one segment;
//! records already verified (and other segments) stay usable, and the
//! worker always appends to a *fresh* segment so a damaged tail is
//! never extended.

pub mod fault;
pub mod record;
mod spill;

pub use fault::{FaultPlan, FaultyIo, RealIo, SegmentIo};
pub use record::{record_len, Crc32, Record, HEADER_LEN};

use std::collections::{BTreeMap, HashMap, HashSet};
use std::fs::{self, File};
use std::io::{BufReader, Write};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use super::page::PrefixKey;

/// Name of the single-writer owner marker inside a persist directory.
pub const LOCK_FILE: &str = "LOCK";

/// Take an exclusive, non-blocking `flock` on `file`.  `Ok(true)` =
/// lock acquired (held until the file handle closes), `Ok(false)` =
/// another open handle holds it.  `flock` locks follow the open file
/// description, so two [`PageStore::open`] calls conflict even inside
/// one process — which is what the tests exercise.
#[cfg(unix)]
fn try_exclusive_lock(file: &File) -> std::io::Result<bool> {
    use std::os::unix::io::AsRawFd;
    // the symbol lives in the platform libc that std already links;
    // declaring it here keeps the offline build free of a libc crate
    extern "C" {
        fn flock(fd: std::os::raw::c_int, operation: std::os::raw::c_int) -> std::os::raw::c_int;
    }
    const LOCK_EX: std::os::raw::c_int = 2;
    const LOCK_NB: std::os::raw::c_int = 4;
    if unsafe { flock(file.as_raw_fd(), LOCK_EX | LOCK_NB) } == 0 {
        return Ok(true);
    }
    let err = std::io::Error::last_os_error();
    if err.kind() == std::io::ErrorKind::WouldBlock {
        Ok(false)
    } else {
        Err(err)
    }
}

/// Non-unix fallback: no advisory locking — the marker file is still
/// written for diagnostics, but concurrent writers are not detected.
#[cfg(not(unix))]
fn try_exclusive_lock(_file: &File) -> std::io::Result<bool> {
    Ok(true)
}

/// A read-only private mapping of (a prefix of) one segment file.
/// Because the log is append-only and never rewritten in place, every
/// byte inside the mapped length was durably written before the map was
/// created — a private mapping can never observe a torn record.  The
/// kernel keeps an unlinked (retired) segment's pages alive until the
/// last map drops, so retirement needs no coordination with in-flight
/// reads.
#[cfg(unix)]
struct SegmentMap {
    ptr: *mut std::os::raw::c_void,
    len: usize,
}

// the mapping is immutable shared memory: plain `&[u8]` access from any
// thread is sound, and munmap runs once from whichever thread drops last
#[cfg(unix)]
unsafe impl Send for SegmentMap {}
#[cfg(unix)]
unsafe impl Sync for SegmentMap {}

#[cfg(unix)]
impl SegmentMap {
    /// Map the first `len` bytes of `path` read-only.  `None` on any
    /// failure — including `len == 0`, which `mmap` rejects — and the
    /// caller falls back to buffered reads.
    fn map(path: &std::path::Path, len: usize) -> Option<SegmentMap> {
        use std::os::unix::io::AsRawFd;
        // same idiom as `try_exclusive_lock`: the symbols live in the
        // platform libc std already links
        extern "C" {
            fn mmap(
                addr: *mut std::os::raw::c_void,
                len: usize,
                prot: std::os::raw::c_int,
                flags: std::os::raw::c_int,
                fd: std::os::raw::c_int,
                offset: std::os::raw::c_long,
            ) -> *mut std::os::raw::c_void;
        }
        const PROT_READ: std::os::raw::c_int = 1;
        const MAP_PRIVATE: std::os::raw::c_int = 2;
        if len == 0 {
            return None;
        }
        let file = File::open(path).ok()?;
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is -1, not null
        if ptr as isize == -1 {
            return None;
        }
        Some(SegmentMap { ptr, len })
    }

    fn len(&self) -> usize {
        self.len
    }

    fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

#[cfg(unix)]
impl Drop for SegmentMap {
    fn drop(&mut self) {
        extern "C" {
            fn munmap(addr: *mut std::os::raw::c_void, len: usize) -> std::os::raw::c_int;
        }
        unsafe { munmap(self.ptr, self.len) };
    }
}

/// Non-unix fallback: mapping never succeeds, so every read takes the
/// buffered path regardless of `StoreConfig::mmap`.
#[cfg(not(unix))]
struct SegmentMap;

#[cfg(not(unix))]
impl SegmentMap {
    fn map(_path: &std::path::Path, _len: usize) -> Option<SegmentMap> {
        None
    }

    fn len(&self) -> usize {
        0
    }

    fn as_slice(&self) -> &[u8] {
        &[]
    }
}

/// Identity + placement of a page store.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    pub dir: PathBuf,
    /// the owning cache's fingerprint (stage-1 config ⊕ page geometry);
    /// records from any other fingerprint are invisible
    pub fingerprint: u64,
    /// exact page payload size this cache reads/writes
    pub page_bytes: usize,
    /// total on-disk budget in bytes (0 = unlimited); enforced by
    /// retiring oldest segments
    pub budget_bytes: u64,
    /// segment rotation threshold
    pub segment_bytes: u64,
    /// serve cold reads from mmap'd segment views instead of buffered
    /// seek+read (`[cache] persist_mmap`).  Purely a transport choice:
    /// every record still goes through full CRC/fingerprint/token
    /// verification, and any mapping failure (or a non-unix host)
    /// silently falls back to the buffered path
    pub mmap: bool,
    /// write attempts per spill job beyond the first
    /// (`[cache] persist_retries`); each retry abandons the torn
    /// segment and starts a fresh one
    pub retries: u32,
    /// initial backoff between spill retries in milliseconds
    /// (`[cache] persist_retry_backoff_ms`), doubled per attempt and
    /// capped at 1s
    pub retry_backoff_ms: u64,
    /// consecutive spill-job failures (all retries exhausted) before
    /// the store degrades to disabled — persistence stops, serving
    /// continues (`[cache] persist_degrade_after`; must be ≥ 1)
    pub degrade_after: u32,
    /// minimum retention score (`(reuse+1)/(depth+1)` in
    /// `SCORE_SCALE` fixed-point, the same units the RAM indexes rank
    /// eviction victims by) a directory-live record must carry to be
    /// rescued by compaction before its segment retires
    /// (`[cache] compact_threshold`; 0 disables compaction entirely,
    /// preserving plain whole-segment FIFO retirement)
    pub compact_score_threshold: u32,
    /// upper bound on bytes the compactor may rewrite per spill-side
    /// pass (`[cache] compact_max_bytes_per_pass`); keeps a single
    /// append's tail latency bounded even when a huge segment retires
    pub compact_max_bytes_per_pass: u64,
}

impl StoreConfig {
    /// Config for a cache with the given identity: segments sized to
    /// hold a healthy run of pages (≥ 64 pages or 8 MiB, whichever is
    /// larger) so retirement granularity stays reasonable.
    pub fn for_cache(
        dir: PathBuf,
        fingerprint: u64,
        page_bytes: usize,
        budget_bytes: u64,
    ) -> StoreConfig {
        let segment_bytes = (8u64 << 20).max(64 * record::record_len(64, page_bytes) as u64);
        StoreConfig {
            dir,
            fingerprint,
            page_bytes,
            budget_bytes,
            segment_bytes,
            mmap: true,
            retries: 3,
            retry_backoff_ms: 50,
            degrade_after: 5,
            compact_score_threshold: 0,
            compact_max_bytes_per_pass: 4 << 20,
        }
    }

    /// Toggle mmap'd cold reads (`[cache] persist_mmap`).
    pub fn with_mmap(mut self, mmap: bool) -> StoreConfig {
        self.mmap = mmap;
        self
    }

    /// Tune the spill worker's failure handling (`[cache]
    /// persist_retries` / `persist_retry_backoff_ms` /
    /// `persist_degrade_after`).
    pub fn with_fault_policy(
        mut self,
        retries: u32,
        retry_backoff_ms: u64,
        degrade_after: u32,
    ) -> StoreConfig {
        assert!(degrade_after >= 1, "degrade_after must be >= 1");
        self.retries = retries;
        self.retry_backoff_ms = retry_backoff_ms;
        self.degrade_after = degrade_after;
        self
    }

    /// Tune segment compaction (`[cache] compact_threshold` /
    /// `compact_max_bytes_per_pass`).  `score_threshold` is in
    /// `SCORE_SCALE` fixed-point; 0 keeps compaction off.
    pub fn with_compaction(
        mut self,
        score_threshold: u32,
        max_bytes_per_pass: u64,
    ) -> StoreConfig {
        self.compact_score_threshold = score_threshold;
        self.compact_max_bytes_per_pass = max_bytes_per_pass;
        self
    }
}

/// Store-side counters (see also `metrics::ShareStats` for the
/// cache-side spill/promote view).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// records adopted into the directory by the boot-time scan
    pub rehydrated: u64,
    /// CRC-clean records skipped because they belong to another
    /// config/geometry fingerprint
    pub stale_skipped: u64,
    /// segments whose scan stopped early on a damaged record
    pub corrupt_tails: u64,
    /// records durably appended by the spill worker
    pub spilled: u64,
    /// spill append failures after all retries (record dropped, fresh
    /// segment next time)
    pub spill_errors: u64,
    /// spill write attempts beyond the first (retry with backoff)
    pub spill_retries: u64,
    /// whole segments retired to stay inside the byte budget
    pub retired_segments: u64,
    /// read-time verification failures (entry dropped, served as miss)
    pub read_errors: u64,
    /// live records rewritten into the active segment by the compactor
    /// before their old segment retired
    pub records_compacted: u64,
    /// segments that had at least one record rescued before retirement
    pub segments_compacted: u64,
}

/// Where one key's record lives on disk.
#[derive(Debug)]
struct DirEntry {
    segment: u64,
    offset: u64,
    len: u64,
    parent: Option<PrefixKey>,
    tokens: Vec<i32>,
    /// page slot the record's *original* node run began at (v2 record
    /// extension; 0 for page-aligned runs and all v1 records) — the
    /// persisted split point a warm boot reports as a sub-run promotion
    start_slot: u32,
    /// retention score at spill time (`SCORE_SCALE` fixed-point; 0 for
    /// v1 records), the compactor's rescue criterion
    score: u32,
}

/// State shared between the front-end API and the spill worker.
pub(crate) struct Shared {
    dir: HashMap<PrefixKey, DirEntry>,
    /// bytes per segment currently on disk (the largest id is the
    /// worker's active segment)
    segments: BTreeMap<u64, u64>,
    /// keys enqueued for spill but not yet durable (write dedup)
    pending: HashSet<PrefixKey>,
    stats: StoreStats,
    /// spill jobs that failed with every retry exhausted, with no
    /// durable append in between; reaching `StoreConfig::degrade_after`
    /// trips `degraded`
    consecutive_failures: u32,
    /// once true the store stops persisting (spill becomes a no-op and
    /// queued jobs are dropped); reads stay enabled — what is already
    /// durable keeps serving.  Only a reopen clears it
    degraded: bool,
}

impl Shared {
    /// Retire whole oldest segments until `budget` is met, never
    /// touching `protect` (the spill worker's active segment).  Drops
    /// the retired segments' directory entries and returns (retired
    /// segment ids for the caller to unlink, directory entries
    /// dropped).  The one retirement policy for both the boot scan and
    /// the steady-state append path.
    fn retire_over_budget(&mut self, budget: u64, protect: Option<u64>) -> (Vec<u64>, u64) {
        let mut retired = Vec::new();
        let mut dropped = 0u64;
        if budget == 0 {
            return (retired, dropped);
        }
        while self.segments.values().sum::<u64>() > budget {
            let Some((&oldest, _)) = self.segments.first_key_value() else {
                break;
            };
            if Some(oldest) == protect {
                break;
            }
            self.segments.remove(&oldest);
            let before = self.dir.len();
            self.dir.retain(|_, e| e.segment != oldest);
            dropped += (before - self.dir.len()) as u64;
            self.stats.retired_segments += 1;
            retired.push(oldest);
        }
        (retired, dropped)
    }

    /// Preview which whole segments [`Shared::retire_over_budget`]
    /// would retire right now, without mutating anything.  The
    /// compactor runs this before the real retirement to learn which
    /// segments' directory-live records are about to vanish.
    fn would_retire(&self, budget: u64, protect: Option<u64>) -> Vec<u64> {
        let mut retired = Vec::new();
        if budget == 0 {
            return retired;
        }
        let mut total: u64 = self.segments.values().sum();
        for (&id, &len) in &self.segments {
            if total <= budget || Some(id) == protect {
                break;
            }
            retired.push(id);
            total -= len;
        }
        retired
    }
}

pub struct PageStore {
    cfg: StoreConfig,
    shared: Arc<Mutex<Shared>>,
    /// segment I/O transport: [`RealIo`] in production, a fault
    /// injector in tests.  Shared with the spill worker
    io: Arc<dyn SegmentIo>,
    tx: Option<mpsc::Sender<spill::Job>>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// lazily created read-only segment mappings (`StoreConfig::mmap`),
    /// one per segment, shared across concurrent readers.  The active
    /// segment grows under the spill worker, so a cached map that is
    /// too short for a requested record is remapped at the file's
    /// current length; maps of retired segments are pruned on the next
    /// mapping miss
    maps: Mutex<HashMap<u64, Arc<SegmentMap>>>,
    /// flock'd single-writer owner marker: held (the fd stays open) for
    /// the store's whole lifetime, released when the store drops
    _lock: File,
}

impl std::fmt::Debug for PageStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PageStore")
            .field("dir", &self.cfg.dir)
            .field("entries", &self.len())
            .finish()
    }
}

/// Path of segment `id` under `dir` — the one source of the segment
/// naming scheme (tests build/inspect segment files through this).
pub fn segment_path(dir: &std::path::Path, id: u64) -> PathBuf {
    dir.join(format!("seg-{id:08}.iqs"))
}

impl PageStore {
    /// Open (or create) the store at `cfg.dir` and rehydrate the
    /// directory by scanning every segment.  Damaged records terminate
    /// their segment's scan; stale-fingerprint records are skipped;
    /// duplicate keys keep the newest copy (the content is identical
    /// by construction, and the newest segment outlives retirement
    /// longest).
    ///
    /// **Single-writer**: the directory is guarded by a flock'd owner
    /// marker ([`LOCK_FILE`]).  A second store on the same directory —
    /// same process or another one — fails loudly here instead of
    /// silently racing segment retirement against the first writer's
    /// appends.  The lock releases when the store drops (or the
    /// process dies — flock is kernel-held, so a crashed server never
    /// leaves a stale lock behind).
    pub fn open(cfg: StoreConfig) -> Result<PageStore> {
        PageStore::open_with_io(cfg, Arc::new(RealIo))
    }

    /// [`PageStore::open`] with an explicit segment-I/O transport.
    /// Production uses [`RealIo`]; fault-injection tests pass a
    /// [`FaultyIo`] so failing disks replay deterministically.
    pub fn open_with_io(cfg: StoreConfig, io: Arc<dyn SegmentIo>) -> Result<PageStore> {
        fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("create persist dir {}", cfg.dir.display()))?;
        let mut lock = fs::OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(false)
            .open(cfg.dir.join(LOCK_FILE))
            .with_context(|| format!("open lockfile in {}", cfg.dir.display()))?;
        match try_exclusive_lock(&lock) {
            Ok(true) => {
                // best-effort pid marker for the operator debugging a
                // refused boot; the flock itself is the real guard
                let _ = lock.set_len(0);
                let _ = writeln!(lock, "{}", std::process::id());
            }
            Ok(false) => bail!(
                "persist dir {} is already owned by another running store \
                 (flock on {LOCK_FILE} is held) — two servers must not share \
                 one persist_dir",
                cfg.dir.display()
            ),
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("flock lockfile in {}", cfg.dir.display())
                })
            }
        }
        let mut shared = Shared {
            dir: HashMap::new(),
            segments: BTreeMap::new(),
            pending: HashSet::new(),
            stats: StoreStats::default(),
            consecutive_failures: 0,
            degraded: false,
        };
        let mut ids: Vec<u64> = Vec::new();
        for entry in fs::read_dir(&cfg.dir)
            .with_context(|| format!("read persist dir {}", cfg.dir.display()))?
        {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(id) = name
                .strip_prefix("seg-")
                .and_then(|s| s.strip_suffix(".iqs"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                ids.push(id);
            }
        }
        ids.sort_unstable();
        for &id in &ids {
            scan_segment(&cfg, id, &mut shared);
        }
        // enforce the budget at boot too: a store written under a
        // larger budget (or whose entries only ever re-park, which the
        // spill dedup skips) must shrink to the configured bound now,
        // not wait for an append that may never come.  Records the
        // retirement discards were never really rehydrated
        let (retired, dropped) = shared.retire_over_budget(cfg.budget_bytes, None);
        shared.stats.rehydrated = shared.stats.rehydrated.saturating_sub(dropped);
        for id in retired {
            let _ = fs::remove_file(segment_path(&cfg.dir, id));
        }
        // the worker never appends to an existing segment: a damaged
        // tail must not be extended, and retirement stays whole-file
        let next_segment = ids.last().map(|&i| i + 1).unwrap_or(0);
        let shared = Arc::new(Mutex::new(shared));
        let (tx, rx) = mpsc::channel();
        let worker = spill::spawn(cfg.clone(), shared.clone(), io.clone(), rx, next_segment)?;
        Ok(PageStore {
            cfg,
            shared,
            io,
            tx: Some(tx),
            worker: Some(worker),
            maps: Mutex::new(HashMap::new()),
            _lock: lock,
        })
    }

    pub fn cfg(&self) -> &StoreConfig {
        &self.cfg
    }

    pub fn fingerprint(&self) -> u64 {
        self.cfg.fingerprint
    }

    fn lock(&self) -> MutexGuard<'_, Shared> {
        self.shared.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Cold entries currently resolvable from disk.
    pub fn len(&self) -> usize {
        self.lock().dir.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total segment bytes on disk.
    pub fn disk_bytes(&self) -> u64 {
        self.lock().segments.values().sum()
    }

    pub fn stats(&self) -> StoreStats {
        self.lock().stats
    }

    /// Has the store tripped into degraded mode (persistence disabled
    /// after `StoreConfig::degrade_after` consecutive spill failures)?
    /// Reads stay enabled; only a reopen re-arms writes.
    pub fn degraded(&self) -> bool {
        self.lock().degraded
    }

    /// Verified membership probe (no I/O): does the store hold a record
    /// for exactly this chain link?  Token + parent verification makes
    /// a key collision read as a miss, matching the RAM index contract.
    pub fn lookup_meta(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: &[i32],
    ) -> bool {
        let s = self.lock();
        s.dir
            .get(&key)
            .is_some_and(|e| e.parent == parent && e.tokens == tokens)
    }

    /// Like [`PageStore::lookup_meta`], but also reports the record's
    /// persisted split point: `Some(start_slot)` on a verified hit,
    /// `None` on a miss.  Slot 0 is a page-aligned run; a non-zero slot
    /// marks a sub-run record — one whose node run began mid-page —
    /// which the cache counts as a sub-run promotion when adopted.
    pub fn lookup_start_slot(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: &[i32],
    ) -> Option<u32> {
        let s = self.lock();
        s.dir
            .get(&key)
            .filter(|e| e.parent == parent && e.tokens == tokens)
            .map(|e| e.start_slot)
    }

    /// Read and fully re-verify one page from disk.  Any failure —
    /// vanished segment, torn read, CRC, identity mismatch — drops the
    /// directory entry and returns `None` (a miss, never wrong bytes).
    pub fn read_page(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: &[i32],
    ) -> Option<Vec<u8>> {
        let loc = {
            let s = self.lock();
            let e = s.dir.get(&key)?;
            if e.parent != parent || e.tokens != tokens {
                return None;
            }
            (e.segment, e.offset, e.len)
        };
        let page = self.fetch_verified((key, parent, tokens), loc);
        if page.is_none() {
            let mut s = self.lock();
            s.dir.remove(&key);
            s.stats.read_errors += 1;
        }
        page
    }

    /// Batch read-ahead over many chain links: resolve everything under
    /// one directory lock, then fetch per segment — straight out of the
    /// segment map when mmap is on, otherwise grouping records by
    /// offset and merging strictly contiguous ones into one sequential
    /// read each (a full-chain cold hit scans its segment once instead
    /// of seeking per page).  Results come back in request order; each
    /// record is independently re-verified, and a failed slot is `None`
    /// with its directory entry dropped, exactly as
    /// [`PageStore::read_page`] would.
    pub fn read_pages(
        &self,
        requests: &[(PrefixKey, Option<PrefixKey>, &[i32])],
    ) -> Vec<Option<Vec<u8>>> {
        let locs: Vec<Option<(u64, u64, u64)>> = {
            let s = self.lock();
            requests
                .iter()
                .map(|&(key, parent, tokens)| {
                    s.dir.get(&key).and_then(|e| {
                        (e.parent == parent && e.tokens == tokens)
                            .then(|| (e.segment, e.offset, e.len))
                    })
                })
                .collect()
        };
        let mut out: Vec<Option<Vec<u8>>> = vec![None; requests.len()];
        let mut by_seg: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        for (i, loc) in locs.iter().enumerate() {
            if let Some((seg, _, _)) = loc {
                by_seg.entry(*seg).or_default().push(i);
            }
        }
        for (seg, mut idxs) in by_seg {
            idxs.sort_by_key(|&i| locs[i].unwrap().1);
            if self.cfg.mmap {
                let need = idxs
                    .iter()
                    .map(|&i| {
                        let (_, offset, len) = locs[i].unwrap();
                        offset + len
                    })
                    .max()
                    .unwrap_or(0);
                if let Some(map) = self.segment_map(seg, need) {
                    for &i in &idxs {
                        let (_, offset, len) = locs[i].unwrap();
                        let (a, b) = (offset as usize, (offset + len) as usize);
                        if b <= map.len() {
                            out[i] = self.verify_record(requests[i], &map.as_slice()[a..b]);
                        }
                    }
                    continue;
                }
                // mapping unavailable: buffered fallback below
            }
            let Ok(mut f) = self.io.open_read(&segment_path(&self.cfg.dir, seg)) else {
                continue;
            };
            let mut e0 = 0usize;
            while e0 < idxs.len() {
                let (_, start, mut ext) = locs[idxs[e0]].unwrap();
                let mut e1 = e0 + 1;
                while e1 < idxs.len() {
                    let (_, offset, len) = locs[idxs[e1]].unwrap();
                    if offset != start + ext {
                        break;
                    }
                    ext += len;
                    e1 += 1;
                }
                let mut buf = vec![0u8; ext as usize];
                if self.io.read_exact_at(&mut f, start, &mut buf).is_ok() {
                    for &i in &idxs[e0..e1] {
                        let (_, offset, len) = locs[i].unwrap();
                        let a = (offset - start) as usize;
                        out[i] = self.verify_record(requests[i], &buf[a..a + len as usize]);
                    }
                }
                e0 = e1;
            }
        }
        // a resolved-but-failed slot loses its directory entry, same as
        // the single-read path
        let mut s = self.lock();
        for (i, loc) in locs.iter().enumerate() {
            if loc.is_some() && out[i].is_none() {
                s.dir.remove(&requests[i].0);
                s.stats.read_errors += 1;
            }
        }
        out
    }

    /// One verified fetch: through the shared segment map when mmap is
    /// on and a mapping is available, buffered seek+read otherwise.
    fn fetch_verified(
        &self,
        req: (PrefixKey, Option<PrefixKey>, &[i32]),
        (segment, offset, len): (u64, u64, u64),
    ) -> Option<Vec<u8>> {
        if self.cfg.mmap {
            if let Some(map) = self.segment_map(segment, offset + len) {
                let (a, b) = (offset as usize, (offset + len) as usize);
                if b <= map.len() {
                    return self.verify_record(req, &map.as_slice()[a..b]);
                }
            }
        }
        let mut f = self
            .io
            .open_read(&segment_path(&self.cfg.dir, segment))
            .ok()?;
        let mut buf = vec![0u8; len as usize];
        self.io.read_exact_at(&mut f, offset, &mut buf).ok()?;
        self.verify_record(req, &buf)
    }

    /// Full CRC/fingerprint/token verification of one raw record
    /// against the chain link that looked it up — shared by every read
    /// transport, so mmap'd reads are exactly as paranoid as buffered
    /// ones.
    fn verify_record(
        &self,
        (key, parent, tokens): (PrefixKey, Option<PrefixKey>, &[i32]),
        bytes: &[u8],
    ) -> Option<Vec<u8>> {
        match record::read_record(&mut &*bytes, self.cfg.fingerprint, self.cfg.page_bytes) {
            record::ReadOutcome::Ok(rec)
                if rec.key == key && rec.parent == parent && rec.tokens == tokens =>
            {
                Some(rec.page)
            }
            _ => None,
        }
    }

    /// Get, create, or grow the shared mapping of `segment` so it
    /// covers at least `need` bytes.  The active segment grows as the
    /// spill worker appends, so a cached map that is too short is
    /// replaced by a fresh map of the file's current length; mapping
    /// misses also prune maps of retired segments (dropping a map is
    /// what finally releases an unlinked segment's pages).  `None` =
    /// mapping unavailable — callers fall back to buffered reads.
    fn segment_map(&self, segment: u64, need: u64) -> Option<Arc<SegmentMap>> {
        let mut maps = self.maps.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(m) = maps.get(&segment) {
            if m.len() as u64 >= need {
                return Some(m.clone());
            }
            maps.remove(&segment);
        }
        {
            let s = self.lock();
            maps.retain(|id, _| s.segments.contains_key(id));
        }
        let path = segment_path(&self.cfg.dir, segment);
        let len = fs::metadata(&path).ok()?.len();
        if len < need {
            return None;
        }
        let map = Arc::new(SegmentMap::map(&path, len as usize)?);
        maps.insert(segment, map.clone());
        Some(map)
    }

    /// Enqueue a page for write-behind persistence.  Returns `true`
    /// when a job was actually queued (a key already durable or already
    /// pending is skipped — content addressing makes rewrites useless).
    /// The page bytes are cloned into the job, so the caller may evict
    /// or reuse the RAM copy immediately.  `start_slot` is the page
    /// slot the record's original node run began at (0 for page-aligned
    /// runs); `score` is the retention score at spill time, the
    /// compactor's rescue criterion — both ride the v2 record
    /// extension.
    pub fn spill(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: &[i32],
        page: &[u8],
        start_slot: u32,
        score: u32,
    ) -> bool {
        debug_assert_eq!(page.len(), self.cfg.page_bytes);
        {
            let mut s = self.lock();
            // degraded: persistence is disabled, drop the job at the door
            if s.degraded || s.dir.contains_key(&key) || !s.pending.insert(key) {
                return false;
            }
        }
        let job = spill::Job::Spill {
            key,
            parent,
            tokens: tokens.to_vec(),
            page: page.to_vec(),
            start_slot,
            score,
        };
        match self.tx.as_ref().map(|tx| tx.send(job)) {
            Some(Ok(())) => true,
            _ => {
                self.lock().pending.remove(&key);
                false
            }
        }
    }

    /// Block until every spill enqueued so far is durable (fsync'd).
    pub fn flush(&self) {
        let (ack_tx, ack_rx) = mpsc::channel();
        if let Some(tx) = self.tx.as_ref() {
            if tx.send(spill::Job::Flush(ack_tx)).is_ok() {
                let _ = ack_rx.recv();
            }
        }
    }
}

impl Drop for PageStore {
    fn drop(&mut self) {
        // closing the channel lets the worker drain the queue and exit;
        // joining makes shutdown persistence deterministic
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Scan one segment into the directory.  Stops at the first damaged
/// record; everything before it is trustworthy (and re-verified again
/// at read time anyway).
fn scan_segment(cfg: &StoreConfig, id: u64, shared: &mut Shared) {
    let path = segment_path(&cfg.dir, id);
    let Ok(file) = File::open(&path) else { return };
    let disk_len = file.metadata().map(|m| m.len()).unwrap_or(0);
    let mut r = BufReader::new(file);
    let mut offset = 0u64;
    loop {
        match record::read_record(&mut r, cfg.fingerprint, cfg.page_bytes) {
            record::ReadOutcome::Eof => break,
            record::ReadOutcome::Ok(rec) => {
                let len = rec.encoded_len() as u64;
                // newest copy wins (segments scan oldest→newest): a key
                // can legitimately recur — a dropped-then-respilled
                // entry, or a second writer — and the bytes are
                // identical by content addressing, so pointing at the
                // newest record keeps the key resolvable for as long
                // as budget retirement allows.  `rehydrated` counts
                // unique resolvable keys, not raw records
                let prev = shared.dir.insert(
                    rec.key,
                    DirEntry {
                        segment: id,
                        offset,
                        len,
                        parent: rec.parent,
                        tokens: rec.tokens,
                        start_slot: rec.start_slot,
                        score: rec.score,
                    },
                );
                if prev.is_none() {
                    shared.stats.rehydrated += 1;
                }
                offset += len;
            }
            record::ReadOutcome::Stale(rec) => {
                shared.stats.stale_skipped += 1;
                offset += rec.encoded_len() as u64;
            }
            record::ReadOutcome::Corrupt(_) => {
                shared.stats.corrupt_tails += 1;
                break;
            }
        }
    }
    // budget accounting uses the real file size (a damaged tail still
    // occupies disk until its segment retires)
    shared.segments.insert(id, disk_len);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::page::chain_key;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "isoquant-store-{}-{}-{tag}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn cfg(dir: &PathBuf, fingerprint: u64) -> StoreConfig {
        StoreConfig {
            dir: dir.clone(),
            fingerprint,
            page_bytes: 64,
            budget_bytes: 0,
            segment_bytes: 4096,
            mmap: false,
            // no retries / effectively no degradation: these tests
            // exercise the happy path and explicit corruption, not the
            // fault-injection policy (see tests/request_lifecycle.rs)
            retries: 0,
            retry_backoff_ms: 0,
            degrade_after: 1_000_000,
            compact_score_threshold: 0,
            compact_max_bytes_per_pass: 4 << 20,
        }
    }

    fn key(i: u64) -> PrefixKey {
        chain_key(None, &[i as i32], 0xF00D)
    }

    #[test]
    fn spill_flush_reopen_roundtrip() {
        let dir = tmpdir("roundtrip");
        let page_a = vec![0xA5u8; 64];
        let page_b = vec![0x3Cu8; 64];
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            assert!(store.spill(key(1), None, &[10, 11], &page_a, 0, 0));
            assert!(store.spill(key(2), Some(key(1)), &[12], &page_b, 0, 0));
            // dedup: same key again is a no-op
            assert!(!store.spill(key(1), None, &[10, 11], &page_a, 0, 0));
            store.flush();
            assert_eq!(store.len(), 2);
            assert_eq!(store.stats().spilled, 2);
            // verified reads
            assert_eq!(store.read_page(key(1), None, &[10, 11]), Some(page_a.clone()));
            // wrong tokens / parent → miss without touching the entry
            assert!(!store.lookup_meta(key(1), None, &[10, 12]));
            assert!(!store.lookup_meta(key(2), None, &[12]));
        }
        // reopen: directory rebuilt from disk
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.stats().rehydrated, 2);
        assert_eq!(store.read_page(key(2), Some(key(1)), &[12]), Some(page_b));
        // a different fingerprint sees nothing
        drop(store);
        let other = PageStore::open(cfg(&dir, 8)).unwrap();
        assert_eq!(other.len(), 0);
        assert_eq!(other.stats().stale_skipped, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_tail_rehydrates_partial_and_appends_to_fresh_segment() {
        let dir = tmpdir("trunc");
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            for i in 0..3u64 {
                store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 0);
            }
            store.flush();
        }
        // chop the single segment mid-way through the last record
        let seg = segment_path(&dir, 0);
        let len = fs::metadata(&seg).unwrap().len();
        let f = fs::OpenOptions::new().write(true).open(&seg).unwrap();
        f.set_len(len - 10).unwrap();
        drop(f);
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            assert_eq!(store.len(), 2, "two intact records survive");
            assert_eq!(store.stats().corrupt_tails, 1);
            assert_eq!(store.read_page(key(0), None, &[0]), Some(vec![0u8; 64]));
            assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![1u8; 64]));
            assert!(store.read_page(key(2), None, &[2]).is_none());
            // new spills land in seg-1, not after the damaged tail
            store.spill(key(9), None, &[9], &vec![9u8; 64], 0, 0);
            store.flush();
            assert!(segment_path(&dir, 1).exists());
        }
        // and the recovered store reopens clean
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(store.len(), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bit_flip_drops_only_the_damaged_suffix() {
        let dir = tmpdir("flip");
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            for i in 0..3u64 {
                store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 0);
            }
            store.flush();
        }
        // flip one bit inside record 1's page payload
        let seg = segment_path(&dir, 0);
        let mut bytes = fs::read(&seg).unwrap();
        let rec_len = record::record_len(1, 64);
        bytes[rec_len + record::HEADER_LEN + 4 + 7] ^= 0x10;
        fs::write(&seg, &bytes).unwrap();
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        // record 0 intact; the scan stops at the damaged record, so 2
        // is also gone — a *partial* index, never wrong bytes
        assert_eq!(store.len(), 1);
        assert_eq!(store.read_page(key(0), None, &[0]), Some(vec![0u8; 64]));
        assert!(store.read_page(key(1), None, &[1]).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_retires_oldest_segments() {
        let dir = tmpdir("budget");
        let one_record = record::record_len(1, 64) as u64;
        let mut c = cfg(&dir, 7);
        c.segment_bytes = one_record; // one record per segment
        c.budget_bytes = 3 * one_record;
        let store = PageStore::open(c).unwrap();
        for i in 0..6u64 {
            store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 0);
        }
        store.flush();
        let stats = store.stats();
        assert_eq!(stats.spilled, 6);
        assert!(stats.retired_segments >= 2, "budget must retire segments");
        assert!(store.disk_bytes() <= 3 * one_record + one_record);
        // oldest keys aged out, newest still resolvable
        assert!(store.read_page(key(0), None, &[0]).is_none());
        assert_eq!(store.read_page(key(5), None, &[5]), Some(vec![5u8; 64]));
        // an aged-out key can be re-spilled
        assert!(store.spill(key(0), None, &[0], &vec![0u8; 64], 0, 0));
        store.flush();
        assert_eq!(store.read_page(key(0), None, &[0]), Some(vec![0u8; 64]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopening_under_a_smaller_budget_retires_at_boot() {
        let dir = tmpdir("shrink");
        let one_record = record::record_len(1, 64) as u64;
        let mut c = cfg(&dir, 7);
        c.segment_bytes = one_record; // one record per segment
        {
            let store = PageStore::open(c.clone()).unwrap();
            for i in 0..5u64 {
                store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 0);
            }
            store.flush();
            assert_eq!(store.len(), 5);
        }
        // the operator lowers the budget and restarts: the store must
        // shrink immediately, not wait for a future append
        c.budget_bytes = 2 * one_record;
        let store = PageStore::open(c).unwrap();
        assert!(store.disk_bytes() <= 2 * one_record);
        assert_eq!(store.len(), 2, "only the newest records survive");
        assert_eq!(
            store.stats().rehydrated,
            2,
            "records discarded by boot retirement must not count as rehydrated"
        );
        assert!(store.read_page(key(0), None, &[0]).is_none());
        assert_eq!(store.read_page(key(4), None, &[4]), Some(vec![4u8; 64]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_writer_on_same_dir_fails_loudly() {
        let dir = tmpdir("lock");
        let first = PageStore::open(cfg(&dir, 7)).unwrap();
        // a second store on the same directory — even a different
        // fingerprint, even in the same process — must be refused
        let err = PageStore::open(cfg(&dir, 8)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("already owned"), "unexpected error: {msg}");
        // the refused open must not have disturbed the owner
        assert!(first.spill(key(1), None, &[1], &vec![1u8; 64], 0, 0));
        first.flush();
        assert_eq!(first.len(), 1);
        // dropping the owner releases the flock; the next open succeeds
        drop(first);
        let second = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(second.len(), 1, "segments survive the handover");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lockfile_is_not_scanned_as_a_segment() {
        let dir = tmpdir("lockscan");
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            store.spill(key(1), None, &[1], &vec![1u8; 64], 0, 0);
            store.flush();
        }
        assert!(dir.join(LOCK_FILE).exists());
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.stats().corrupt_tails, 0, "LOCK must not be scanned");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn vanished_segment_reads_as_miss() {
        let dir = tmpdir("vanish");
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        store.spill(key(1), None, &[1], &vec![1u8; 64], 0, 0);
        store.flush();
        fs::remove_file(segment_path(&dir, 0)).unwrap();
        assert!(store.read_page(key(1), None, &[1]).is_none());
        assert_eq!(store.stats().read_errors, 1);
        // the broken entry is dropped, not retried forever
        assert_eq!(store.len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mmap_reads_match_buffered_and_see_appends() {
        // the mmap transport must serve byte-identical pages, including
        // records appended after the first map was created (the active
        // segment grows → remap)
        let dir = tmpdir("mmap");
        let store = PageStore::open(cfg(&dir, 7).with_mmap(true)).unwrap();
        store.spill(key(1), None, &[1], &vec![0x11u8; 64], 0, 0);
        store.flush();
        assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0x11u8; 64]));
        // grow the active segment after the map exists
        store.spill(key(2), Some(key(1)), &[2], &vec![0x22u8; 64], 0, 0);
        store.flush();
        assert_eq!(
            store.read_page(key(2), Some(key(1)), &[2]),
            Some(vec![0x22u8; 64])
        );
        // identity mismatches stay misses without touching the entries
        assert!(store.read_page(key(1), None, &[9]).is_none());
        assert_eq!(store.len(), 2);
        drop(store);
        // a buffered reopen sees the same bytes
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(store.read_page(key(1), None, &[1]), Some(vec![0x11u8; 64]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mmap_bit_flip_reads_as_miss() {
        // a record damaged on disk after rehydration must read as a
        // miss through the map, exactly like the buffered path
        let dir = tmpdir("mmapflip");
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            for i in 0..2u64 {
                store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 0);
            }
            store.flush();
        }
        let seg = segment_path(&dir, 0);
        let mut bytes = fs::read(&seg).unwrap();
        let rec_len = record::record_len(1, 64);
        bytes[rec_len + record::HEADER_LEN + 4 + 7] ^= 0x10;
        fs::write(&seg, &bytes).unwrap();
        let store = PageStore::open(cfg(&dir, 7).with_mmap(true)).unwrap();
        assert_eq!(store.read_page(key(0), None, &[0]), Some(vec![0u8; 64]));
        assert!(store.read_page(key(1), None, &[1]).is_none());
        assert_eq!(store.stats().read_errors, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mmap_vanished_segment_falls_back_and_misses() {
        let dir = tmpdir("mmapvanish");
        let store = PageStore::open(cfg(&dir, 7).with_mmap(true)).unwrap();
        store.spill(key(1), None, &[1], &vec![1u8; 64], 0, 0);
        store.flush();
        fs::remove_file(segment_path(&dir, 0)).unwrap();
        assert!(store.read_page(key(1), None, &[1]).is_none());
        assert_eq!(store.stats().read_errors, 1);
        assert_eq!(store.len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_pages_batches_in_request_order() {
        // read_pages == read_page per slot, in request order, on both
        // transports — including unknown keys (None without an error)
        // and records spread across several segments
        for mmap in [false, true] {
            let dir = tmpdir(if mmap { "batch-mmap" } else { "batch-buf" });
            let one_record = record::record_len(1, 64) as u64;
            let mut c = cfg(&dir, 7).with_mmap(mmap);
            c.segment_bytes = 2 * one_record; // force several segments
            let store = PageStore::open(c).unwrap();
            for i in 0..5u64 {
                store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 0);
            }
            store.flush();
            let t: Vec<[i32; 1]> = (0..5).map(|i| [i as i32]).collect();
            let missing = [99i32];
            // out of order, with a miss in the middle
            let requests: Vec<(PrefixKey, Option<PrefixKey>, &[i32])> = vec![
                (key(3), None, &t[3]),
                (key(99), None, &missing),
                (key(0), None, &t[0]),
                (key(4), None, &t[4]),
                (key(1), None, &t[1]),
                (key(2), Some(key(0)), &t[2]), // wrong parent → miss
            ];
            let got = store.read_pages(&requests);
            assert_eq!(
                got,
                vec![
                    Some(vec![3u8; 64]),
                    None,
                    Some(vec![0u8; 64]),
                    Some(vec![4u8; 64]),
                    Some(vec![1u8; 64]),
                    None,
                ],
                "mmap={mmap}"
            );
            // unresolved keys are not read errors; entries survive
            assert_eq!(store.stats().read_errors, 0, "mmap={mmap}");
            assert_eq!(store.len(), 5, "mmap={mmap}");
            let _ = fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn sub_run_start_slot_survives_spill_and_reboot() {
        let dir = tmpdir("subrun");
        {
            let store = PageStore::open(cfg(&dir, 7)).unwrap();
            store.spill(key(1), None, &[10, 11, 12, 13], &vec![0xABu8; 64], 2, 777);
            store.flush();
            assert_eq!(
                store.lookup_start_slot(key(1), None, &[10, 11, 12, 13]),
                Some(2)
            );
        }
        // the split point rides the record extension across a reboot
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(
            store.lookup_start_slot(key(1), None, &[10, 11, 12, 13]),
            Some(2)
        );
        // identity mismatch is still a miss, not a zero
        assert_eq!(store.lookup_start_slot(key(1), None, &[10, 11]), None);
        assert_eq!(
            store.read_page(key(1), None, &[10, 11, 12, 13]),
            Some(vec![0xABu8; 64])
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_written_segments_rehydrate_with_zero_extension() {
        // a store written before the sub-run extension existed must
        // boot under the v2 reader: page-aligned, score 0
        let dir = tmpdir("v1seg");
        let mut buf = Vec::new();
        record::encode_record_v1(&mut buf, key(1), None, 7, &[5], &[0x5Au8; 64]);
        fs::write(segment_path(&dir, 0), &buf).unwrap();
        let store = PageStore::open(cfg(&dir, 7)).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.stats().rehydrated, 1);
        assert_eq!(store.lookup_start_slot(key(1), None, &[5]), Some(0));
        assert_eq!(store.read_page(key(1), None, &[5]), Some(vec![0x5Au8; 64]));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_rescues_high_score_records_before_retirement() {
        let dir = tmpdir("compact");
        let one_record = record::record_len(1, 64) as u64;
        let mut c = cfg(&dir, 7);
        c.segment_bytes = one_record; // one record per segment
        c.budget_bytes = 3 * one_record;
        c.compact_score_threshold = 1000;
        let store = PageStore::open(c).unwrap();
        // key 0 is the hot root (high score); the rest are cold
        for i in 0..6u64 {
            let score = if i == 0 { 50_000 } else { 10 };
            store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, score);
            store.flush(); // deterministic segment order
        }
        let stats = store.stats();
        assert!(
            stats.records_compacted >= 1,
            "the hot record must be rewritten forward: {stats:?}"
        );
        assert!(stats.segments_compacted >= 1, "{stats:?}");
        // the hot key outlives every retirement wave; cold ones age out
        assert_eq!(store.read_page(key(0), None, &[0]), Some(vec![0u8; 64]));
        assert!(store.read_page(key(1), None, &[1]).is_none());
        // the budget still holds (modulo the worker's active segment)
        assert!(store.disk_bytes() <= 4 * one_record);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_off_keeps_plain_fifo_retirement() {
        // threshold 0 (the default) must behave exactly like the seed:
        // whole-segment FIFO, nothing rewritten
        let dir = tmpdir("nocompact");
        let one_record = record::record_len(1, 64) as u64;
        let mut c = cfg(&dir, 7);
        c.segment_bytes = one_record;
        c.budget_bytes = 3 * one_record;
        let store = PageStore::open(c).unwrap();
        for i in 0..6u64 {
            store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 50_000);
            store.flush();
        }
        let stats = store.stats();
        assert_eq!(stats.records_compacted, 0);
        assert_eq!(stats.segments_compacted, 0);
        assert!(store.read_page(key(0), None, &[0]).is_none(), "FIFO aged out");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_pass_respects_its_byte_budget() {
        let dir = tmpdir("compactcap");
        let one_record = record::record_len(1, 64) as u64;
        let mut c = cfg(&dir, 7);
        c.segment_bytes = 2 * one_record; // two records per segment
        c.budget_bytes = 4 * one_record;
        c.compact_score_threshold = 1000;
        c.compact_max_bytes_per_pass = one_record; // at most one rescue per pass
        let store = PageStore::open(c).unwrap();
        for i in 0..8u64 {
            store.spill(key(i), None, &[i as i32], &vec![i as u8; 64], 0, 50_000);
            store.flush();
        }
        // every record is hot, but each retirement wave may only rewrite
        // one record's worth — so some hot records still age out
        let stats = store.stats();
        assert!(stats.records_compacted >= 1, "{stats:?}");
        let alive = (0..8u64)
            .filter(|&i| store.lookup_meta(key(i), None, &[i as i32]))
            .count();
        assert!(alive < 8, "the cap must have let some records retire");
        let _ = fs::remove_dir_all(&dir);
    }
}
