//! Per-sequence compressed KV cache: block tables over pooled pages,
//! compress-on-append, reconstruct-on-gather.
//!
//! This is where IsoQuant sits on the serving critical path: every
//! generated token's K/V head vectors are stage-1 *encoded* once on
//! append and *decoded* on every subsequent decode step's gather — the
//! deployment pattern the paper's fused-kernel latency argument is
//! about.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::allocator::{PageAllocator, PageId};
use super::page::PageConfig;
use crate::quant::Stage1;

pub type SeqId = u64;

/// Per-sequence state: block table + token count.
#[derive(Debug, Default, Clone)]
struct SeqCache {
    pages: Vec<PageId>,
    len: usize,
    /// optional uncompressed shadow copy (fidelity experiments):
    /// layout [layer][head][token][dh], appended per token
    shadow_k: Vec<f32>,
    shadow_v: Vec<f32>,
}

/// The engine-wide KV cache.
pub struct CacheManager {
    alloc: PageAllocator,
    stage1: Stage1,
    seqs: HashMap<SeqId, SeqCache>,
    /// keep an uncompressed shadow (for fidelity measurement only; off on
    /// the real serving path)
    pub keep_shadow: bool,
}

impl CacheManager {
    pub fn new(stage1: Stage1, page_cfg: PageConfig, max_pages: usize) -> CacheManager {
        assert_eq!(stage1.d(), page_cfg.d_head);
        assert_eq!(stage1.encoded_len(), page_cfg.encoded_len);
        CacheManager {
            alloc: PageAllocator::new(page_cfg, max_pages),
            stage1,
            seqs: HashMap::new(),
            keep_shadow: false,
        }
    }

    pub fn stage1(&self) -> &Stage1 {
        &self.stage1
    }

    pub fn page_cfg(&self) -> PageConfig {
        *self.alloc.cfg()
    }

    pub fn seq_len(&self, seq: SeqId) -> usize {
        self.seqs.get(&seq).map(|s| s.len).unwrap_or(0)
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.alloc.allocated()
    }

    /// Pages needed to grow a sequence to `new_len` tokens.
    pub fn pages_needed(&self, seq: SeqId, new_len: usize) -> usize {
        let tp = self.alloc.cfg().tokens_per_page;
        let have = self.seqs.get(&seq).map(|s| s.pages.len()).unwrap_or(0);
        let need = new_len.div_ceil(tp);
        need.saturating_sub(have)
    }

    /// Admission check for a new sequence of `prompt_len` + `gen_len`.
    pub fn can_admit(&self, total_len: usize) -> bool {
        let tp = self.alloc.cfg().tokens_per_page;
        self.alloc.can_alloc(total_len.div_ceil(tp))
    }

    pub fn start_seq(&mut self, seq: SeqId) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already exists");
        }
        self.seqs.insert(seq, SeqCache::default());
        Ok(())
    }

    pub fn drop_seq(&mut self, seq: SeqId) {
        if let Some(s) = self.seqs.remove(&seq) {
            for p in s.pages {
                self.alloc.release(p);
            }
        }
    }

    /// Append one token's K/V: `k_t`/`v_t` are laid out `[layer][head][dh]`
    /// (the `k_new`/`v_new` outputs of the decode artifact for one batch
    /// lane).  Compresses each head vector independently.
    pub fn append_token(&mut self, seq: SeqId, k_t: &[f32], v_t: &[f32]) -> Result<()> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_t.len() != l * h * dh || v_t.len() != l * h * dh {
            bail!(
                "append_token: expected {}x{}x{} floats, got k={} v={}",
                l, h, dh, k_t.len(), v_t.len()
            );
        }
        // reserve the page first so failure leaves the sequence unchanged
        let (page_id, slot) = {
            let s = self.seqs.get(&seq).context("unknown sequence")?;
            let tp = cfg.tokens_per_page;
            let slot = s.len % tp;
            if slot == 0 {
                (None, 0)
            } else {
                (Some(*s.pages.last().unwrap()), slot)
            }
        };
        let page_id = match page_id {
            Some(p) => p,
            None => {
                let p = self.alloc.alloc()?;
                self.seqs.get_mut(&seq).unwrap().pages.push(p);
                p
            }
        };

        let mut buf = Vec::with_capacity(cfg.encoded_len);
        for layer in 0..l {
            for head in 0..h {
                let base = (layer * h + head) * dh;
                for (is_v, src) in [(false, k_t), (true, v_t)] {
                    buf.clear();
                    self.stage1.encode(&src[base..base + dh], &mut buf);
                    self.alloc
                        .page_mut(page_id)
                        .slot_mut(&cfg, slot, layer, head, is_v)
                        .copy_from_slice(&buf);
                }
            }
        }
        let s = self.seqs.get_mut(&seq).unwrap();
        s.len += 1;
        if self.keep_shadow {
            s.shadow_k.extend_from_slice(k_t);
            s.shadow_v.extend_from_slice(v_t);
        }
        Ok(())
    }

    /// Reconstruct this sequence's cache into caller buffers shaped
    /// `[layer][head][t_max][dh]` (padded with zeros beyond `len`).
    /// This is the decode-side hot loop.
    pub fn gather(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_out.len() != l * h * t_max * dh || v_out.len() != l * h * t_max * dh {
            bail!("gather: output buffer shape mismatch");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        k_out.fill(0.0);
        v_out.fill(0.0);
        let tp = cfg.tokens_per_page;
        for t in 0..n {
            let page = self.alloc.page(s.pages[t / tp]);
            let slot = t % tp;
            for layer in 0..l {
                for head in 0..h {
                    let dst = ((layer * h + head) * t_max + t) * dh;
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, false),
                        &mut k_out[dst..dst + dh],
                    );
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, true),
                        &mut v_out[dst..dst + dh],
                    );
                }
            }
        }
        Ok(n)
    }

    /// Reconstruct directly into a batched `(L, B, H, T, dh)` buffer at
    /// batch lane `lane` — the layout the decode artifact consumes.
    /// Avoids an intermediate per-sequence copy on the serving hot path.
    pub fn gather_into_batch(
        &self,
        seq: SeqId,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = l * batch * h * t_max * dh;
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_into_batch: buffer shape mismatch");
        }
        if lane >= batch {
            bail!("gather_into_batch: lane {lane} >= batch {batch}");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        let tp = cfg.tokens_per_page;
        for layer in 0..l {
            for head in 0..h {
                // zero this lane's strip (slots ≥ n must not leak)
                let strip = (((layer * batch) + lane) * h + head) * t_max * dh;
                k_out[strip..strip + t_max * dh].fill(0.0);
                v_out[strip..strip + t_max * dh].fill(0.0);
            }
        }
        for t in 0..n {
            let page = self.alloc.page(s.pages[t / tp]);
            let slot = t % tp;
            for layer in 0..l {
                for head in 0..h {
                    let dst = ((((layer * batch) + lane) * h + head) * t_max + t) * dh;
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, false),
                        &mut k_out[dst..dst + dh],
                    );
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, true),
                        &mut v_out[dst..dst + dh],
                    );
                }
            }
        }
        Ok(n)
    }

    /// Shadow (uncompressed) cache in the same `[l][h][t][dh]` layout —
    /// only valid when `keep_shadow` was set before appends.
    pub fn gather_shadow(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        k_out.fill(0.0);
        v_out.fill(0.0);
        for t in 0..n {
            for layer in 0..l {
                for head in 0..h {
                    let src = (t * l * h + layer * h + head) * dh;
                    let dst = ((layer * h + head) * t_max + t) * dh;
                    k_out[dst..dst + dh].copy_from_slice(&s.shadow_k[src..src + dh]);
                    v_out[dst..dst + dh].copy_from_slice(&s.shadow_v[src..src + dh]);
                }
            }
        }
        Ok(n)
    }

    /// compressed bytes per token slot (for metrics)
    pub fn slot_bytes(&self) -> (usize, usize) {
        let cfg = self.alloc.cfg();
        (cfg.slot_bytes(), cfg.slot_bytes_uncompressed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Stage1, Stage1Config, Variant};
    use crate::util::prng::Rng;

    fn mk(max_pages: usize, bits: u8) -> CacheManager {
        let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, 64, bits));
        let cfg = PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 64,
            encoded_len: stage1.encoded_len(),
        };
        CacheManager::new(stage1, cfg, max_pages)
    }

    fn token(rng: &mut Rng, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
    }

    #[test]
    fn append_gather_roundtrip_quality() {
        let mut m = mk(64, 4);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(1);
        m.start_seq(1).unwrap();
        let mut truth_k = Vec::new();
        for _ in 0..10 {
            let (k, v) = token(&mut rng, &cfg);
            truth_k.push(k.clone());
            m.append_token(1, &k, &v).unwrap();
        }
        assert_eq!(m.seq_len(1), 10);
        let t_max = 16;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut k_out = vec![0.0f32; sz];
        let mut v_out = vec![0.0f32; sz];
        let n = m.gather(1, t_max, &mut k_out, &mut v_out).unwrap();
        assert_eq!(n, 10);
        // token 3, layer 1, head 0 reconstruction ≈ original
        let dh = cfg.d_head;
        let t = 3;
        let dst = ((1 * cfg.n_heads + 0) * t_max + t) * dh;
        let src = (1 * cfg.n_heads + 0) * dh;
        let rel = crate::metrics::rel_l2(&truth_k[t][src..src + dh], &k_out[dst..dst + dh]);
        assert!(rel < 0.25, "rel {rel}");
        // padding stays zero
        let pad = ((0 * cfg.n_heads) * t_max + 12) * dh;
        assert!(k_out[pad..pad + dh].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pages_allocated_lazily_and_released() {
        let mut m = mk(8, 2);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(2);
        m.start_seq(7).unwrap();
        assert_eq!(m.pages_in_use(), 0);
        for i in 0..9 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(7, &k, &v).unwrap();
            assert_eq!(m.pages_in_use(), i / 4 + 1);
        }
        m.drop_seq(7);
        assert_eq!(m.pages_in_use(), 0);
    }

    #[test]
    fn capacity_exhaustion_fails_cleanly() {
        let mut m = mk(1, 2);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(3);
        m.start_seq(1).unwrap();
        for _ in 0..4 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(1, &k, &v).unwrap();
        }
        let (k, v) = token(&mut rng, &cfg);
        let err = m.append_token(1, &k, &v);
        assert!(err.is_err());
        // sequence state unchanged by the failed append
        assert_eq!(m.seq_len(1), 4);
    }

    #[test]
    fn admission_math() {
        let m = mk(4, 2);
        assert!(m.can_admit(16)); // 4 pages × 4 tokens
        assert!(!m.can_admit(17));
    }

    #[test]
    fn shadow_matches_truth_exactly() {
        let mut m = mk(16, 2);
        m.keep_shadow = true;
        let cfg = m.page_cfg();
        let mut rng = Rng::new(4);
        m.start_seq(1).unwrap();
        let (k, v) = token(&mut rng, &cfg);
        m.append_token(1, &k, &v).unwrap();
        let t_max = 4;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut k_out = vec![0.0f32; sz];
        let mut v_out = vec![0.0f32; sz];
        m.gather_shadow(1, t_max, &mut k_out, &mut v_out).unwrap();
        let dh = cfg.d_head;
        for layer in 0..cfg.n_layers {
            for head in 0..cfg.n_heads {
                let src = (layer * cfg.n_heads + head) * dh;
                let dst = ((layer * cfg.n_heads + head) * t_max) * dh;
                assert_eq!(&k_out[dst..dst + dh], &k[src..src + dh]);
                assert_eq!(&v_out[dst..dst + dh], &v[src..src + dh]);
            }
        }
    }

    #[test]
    fn unknown_seq_rejected() {
        let mut m = mk(4, 2);
        let cfg = m.page_cfg();
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        assert!(m.append_token(99, &vec![0.0; n], &vec![0.0; n]).is_err());
        let mut buf = vec![0.0f32; cfg.n_layers * cfg.n_heads * 4 * cfg.d_head];
        let mut buf2 = buf.clone();
        assert!(m.gather(99, 4, &mut buf, &mut buf2).is_err());
    }

    #[test]
    fn duplicate_seq_rejected() {
        let mut m = mk(4, 2);
        m.start_seq(1).unwrap();
        assert!(m.start_seq(1).is_err());
    }

    #[test]
    fn multiple_sequences_isolated() {
        let mut m = mk(32, 4);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(5);
        m.start_seq(1).unwrap();
        m.start_seq(2).unwrap();
        let (k1, v1) = token(&mut rng, &cfg);
        let (k2, v2) = token(&mut rng, &cfg);
        m.append_token(1, &k1, &v1).unwrap();
        m.append_token(2, &k2, &v2).unwrap();
        let t_max = 4;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut a = vec![0.0f32; sz];
        let mut b = vec![0.0f32; sz];
        let mut tmp = vec![0.0f32; sz];
        m.gather(1, t_max, &mut a, &mut tmp).unwrap();
        m.gather(2, t_max, &mut b, &mut tmp).unwrap();
        // different tokens → different reconstructions
        assert_ne!(a, b);
        m.drop_seq(1);
        // seq 2 still readable after seq 1 dropped
        assert!(m.gather(2, t_max, &mut b, &mut tmp).is_ok());
    }
}
