//! Per-sequence compressed KV cache: block tables over pooled pages,
//! compress-on-append, reconstruct-on-gather.
//!
//! This is where IsoQuant sits on the serving critical path: every
//! generated token's K/V head vectors are stage-1 *encoded* once on
//! append and *decoded* on every subsequent decode step's gather — the
//! deployment pattern the paper's fused-kernel latency argument is
//! about.
//!
//! Both directions run the batch-first stage-1 API
//! (`quant::pipeline`'s `encode_batch` / `decode_batch_strided`):
//!
//! * **append** batch-encodes a token's `n_layers × n_heads` contiguous
//!   K (then V) head vectors into a persistent [`PackedSink`] and fans
//!   the records out to page slots — zero steady-state allocation; the
//!   prefill path appends whole chunks at once through
//!   [`CacheManager::append_run`] (one `encode_batch` per side covering
//!   `tokens × layers × heads` vectors, page slots written in slot
//!   order);
//! * **gather** decomposes into `n_layers × n_heads` independent
//!   *strips* (one `[t][dh]` destination run per (layer, head)), each
//!   decoded page-by-page with strided batch decodes, optionally in
//!   parallel across strips per the manager's [`ParallelPolicy`]; the
//!   engine gathers *all* active lanes through one
//!   [`CacheManager::gather_lanes_into_batch_ws`] drain so every lane's
//!   strip units share one work queue.
//!
//! The pre-batch per-vector path survives as
//! [`CacheManager::gather_reference`]: the property-test oracle and the
//! bench baseline (`benches/gather_throughput.rs`).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::allocator::{PageAllocator, PageId};
use super::page::PageConfig;
use crate::quant::{BatchScratch, PackedSink, Stage1};
use crate::util::pool::{scope_units, ParallelPolicy};

pub type SeqId = u64;

/// Below this many encoded vectors (tokens × layers × heads × K/V) a
/// gather runs single-threaded even under `ParallelPolicy::Auto` —
/// spawning scoped threads costs tens of microseconds, which only pays
/// off once the decode work dwarfs it.
const MIN_PARALLEL_VECTORS: usize = 512;

/// Per-sequence state: block table + token count.
#[derive(Debug, Default, Clone)]
struct SeqCache {
    pages: Vec<PageId>,
    len: usize,
    /// optional uncompressed shadow copy (fidelity experiments):
    /// layout [layer][head][token][dh], appended per token
    shadow_k: Vec<f32>,
    shadow_v: Vec<f32>,
}

/// Persistent scratch for the batched gather path: one decode scratch
/// per (layer, head) strip so strips can decode concurrently, plus the
/// strip-base table.  Keep one per engine (or per bench loop); the hot
/// inner-loop buffers then persist across gathers — the only remaining
/// per-call allocation is the O(lanes × layers × heads) strip/job-list
/// bookkeeping, whose `&mut`/`&SeqCache` lifetimes are necessarily
/// per-call.
#[derive(Debug, Default)]
pub struct GatherWorkspace {
    scratch: Vec<BatchScratch>,
    bases: Vec<usize>,
}

impl GatherWorkspace {
    pub fn new() -> GatherWorkspace {
        GatherWorkspace::default()
    }
}

/// The engine-wide KV cache.
pub struct CacheManager {
    alloc: PageAllocator,
    stage1: Stage1,
    seqs: HashMap<SeqId, SeqCache>,
    /// persistent encode sink for appends (K batch, then V batch)
    sink: PackedSink,
    /// threading policy for the strip-parallel gather path
    pub parallel: ParallelPolicy,
    /// keep an uncompressed shadow (for fidelity measurement only; off on
    /// the real serving path)
    pub keep_shadow: bool,
}

impl CacheManager {
    pub fn new(stage1: Stage1, page_cfg: PageConfig, max_pages: usize) -> CacheManager {
        assert_eq!(stage1.d(), page_cfg.d_head);
        assert_eq!(stage1.encoded_len(), page_cfg.encoded_len);
        CacheManager {
            alloc: PageAllocator::new(page_cfg, max_pages),
            stage1,
            seqs: HashMap::new(),
            sink: PackedSink::new(),
            parallel: ParallelPolicy::Off,
            keep_shadow: false,
        }
    }

    pub fn stage1(&self) -> &Stage1 {
        &self.stage1
    }

    pub fn page_cfg(&self) -> PageConfig {
        *self.alloc.cfg()
    }

    pub fn seq_len(&self, seq: SeqId) -> usize {
        self.seqs.get(&seq).map(|s| s.len).unwrap_or(0)
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.alloc.allocated()
    }

    /// Pages needed to grow a sequence to `new_len` tokens.
    pub fn pages_needed(&self, seq: SeqId, new_len: usize) -> usize {
        let tp = self.alloc.cfg().tokens_per_page;
        let have = self.seqs.get(&seq).map(|s| s.pages.len()).unwrap_or(0);
        let need = new_len.div_ceil(tp);
        need.saturating_sub(have)
    }

    /// Admission check for a new sequence of `prompt_len` + `gen_len`.
    pub fn can_admit(&self, total_len: usize) -> bool {
        let tp = self.alloc.cfg().tokens_per_page;
        self.alloc.can_alloc(total_len.div_ceil(tp))
    }

    pub fn start_seq(&mut self, seq: SeqId) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already exists");
        }
        self.seqs.insert(seq, SeqCache::default());
        Ok(())
    }

    pub fn drop_seq(&mut self, seq: SeqId) {
        if let Some(s) = self.seqs.remove(&seq) {
            for p in s.pages {
                self.alloc.release(p);
            }
        }
    }

    /// Append one token's K/V: `k_t`/`v_t` are laid out `[layer][head][dh]`
    /// (the `k_new`/`v_new` outputs of the decode artifact for one batch
    /// lane).  A run of length 1 — see [`CacheManager::append_run`].
    pub fn append_token(&mut self, seq: SeqId, k_t: &[f32], v_t: &[f32]) -> Result<()> {
        self.append_run(seq, k_t, v_t, 1)
    }

    /// Append a run of `n_tokens` tokens' K/V in one batched encode per
    /// side: `k_run`/`v_run` are token-major `[t][layer][head][dh]`.
    /// Each side is a *single* `encode_batch` call over `n_tokens × L ×
    /// H` vectors into the persistent sink (so the SIMD tile kernels
    /// see the whole run), and the resulting records are fanned out to
    /// page slots in ascending slot order.  This is the batched prefill
    /// append: `Engine::step_prefill` stages a whole chunk per lane and
    /// appends it here instead of looping `append_token`.
    ///
    /// Pages are reserved up front, so failure (pool exhaustion or an
    /// unknown sequence) leaves the sequence unchanged.
    pub fn append_run(
        &mut self,
        seq: SeqId,
        k_run: &[f32],
        v_run: &[f32],
        n_tokens: usize,
    ) -> Result<()> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = n_tokens * l * h * dh;
        if k_run.len() != expect || v_run.len() != expect {
            bail!(
                "append_run: expected {}x{}x{}x{} floats, got k={} v={}",
                n_tokens, l, h, dh, k_run.len(), v_run.len()
            );
        }
        if n_tokens == 0 {
            self.seqs.get(&seq).context("unknown sequence")?;
            return Ok(());
        }
        let tp = cfg.tokens_per_page;
        // reserve every page the run needs before touching anything
        let (start_len, have_pages) = {
            let s = self.seqs.get(&seq).context("unknown sequence")?;
            (s.len, s.pages.len())
        };
        let need = (start_len + n_tokens).div_ceil(tp).saturating_sub(have_pages);
        let mut fresh: Vec<PageId> = Vec::with_capacity(need);
        for _ in 0..need {
            match self.alloc.alloc() {
                Ok(p) => fresh.push(p),
                Err(e) => {
                    for p in fresh {
                        self.alloc.release(p);
                    }
                    return Err(e);
                }
            }
        }
        self.seqs.get_mut(&seq).unwrap().pages.extend(fresh);

        for (is_v, src) in [(false, k_run), (true, v_run)] {
            self.stage1.encode_batch(src, n_tokens * l * h, &mut self.sink);
            // record (t, layer, head) is sink index (t·L + layer)·H + head
            // — walking tokens then layers then heads writes page slots
            // in ascending offset order
            for t in 0..n_tokens {
                let tok = start_len + t;
                let page_id = self.seqs.get(&seq).unwrap().pages[tok / tp];
                let slot = tok % tp;
                let page = self.alloc.page_mut(page_id);
                for layer in 0..l {
                    for head in 0..h {
                        page.slot_mut(&cfg, slot, layer, head, is_v)
                            .copy_from_slice(self.sink.encoded((t * l + layer) * h + head));
                    }
                }
            }
        }
        let s = self.seqs.get_mut(&seq).unwrap();
        s.len += n_tokens;
        if self.keep_shadow {
            s.shadow_k.extend_from_slice(k_run);
            s.shadow_v.extend_from_slice(v_run);
        }
        Ok(())
    }

    /// Reconstruct this sequence's cache into caller buffers shaped
    /// `[layer][head][t_max][dh]` (padded with zeros beyond `len`).
    /// This is the decode-side hot loop; `ws` persists decode scratch
    /// across calls.
    pub fn gather_ws(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_out.len() != l * h * t_max * dh || v_out.len() != l * h * t_max * dh {
            bail!("gather: output buffer shape mismatch");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = self.gather_strips(s, t_max, k_out, v_out, ws, |layer, head| {
            (layer * h + head) * t_max * dh
        });
        Ok(n)
    }

    /// [`CacheManager::gather_ws`] with a throwaway workspace (tests and
    /// one-off callers; the engine holds a persistent workspace).
    pub fn gather(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        self.gather_ws(seq, t_max, k_out, v_out, &mut GatherWorkspace::new())
    }

    /// Reconstruct directly into a batched `(L, B, H, T, dh)` buffer at
    /// batch lane `lane` — the layout the decode artifact consumes.
    /// Avoids an intermediate per-sequence copy on the serving hot path.
    pub fn gather_into_batch_ws(
        &self,
        seq: SeqId,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = l * batch * h * t_max * dh;
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_into_batch: buffer shape mismatch");
        }
        if lane >= batch {
            bail!("gather_into_batch: lane {lane} >= batch {batch}");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = self.gather_strips(s, t_max, k_out, v_out, ws, |layer, head| {
            (((layer * batch) + lane) * h + head) * t_max * dh
        });
        Ok(n)
    }

    /// [`CacheManager::gather_into_batch_ws`] with a throwaway workspace.
    pub fn gather_into_batch(
        &self,
        seq: SeqId,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        self.gather_into_batch_ws(
            seq,
            lane,
            batch,
            t_max,
            k_out,
            v_out,
            &mut GatherWorkspace::new(),
        )
    }

    /// Reconstruct the caches of several sequences into disjoint lanes
    /// of one batched `(L, B, H, T, dh)` buffer pair in a *single*
    /// strip-parallel drain: the `(layer, head)` strip units of every
    /// listed lane feed one `scope_units` queue, so a fast lane's
    /// threads help finish a slow lane instead of idling at per-lane
    /// barriers (ROADMAP cross-lane item).  `lanes` pairs each sequence
    /// with its batch lane and must be strictly ascending by lane.
    /// Returns the reconstructed token count per listed lane.
    pub fn gather_lanes_into_batch_ws(
        &self,
        lanes: &[(SeqId, usize)],
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) -> Result<Vec<usize>> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = l * batch * h * t_max * dh;
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_lanes: buffer shape mismatch");
        }
        let mut seqs = Vec::with_capacity(lanes.len());
        let mut prev: Option<usize> = None;
        for &(seq, lane) in lanes {
            if lane >= batch {
                bail!("gather_lanes: lane {lane} >= batch {batch}");
            }
            if prev.is_some_and(|p| lane <= p) {
                bail!("gather_lanes: lanes must be strictly ascending");
            }
            prev = Some(lane);
            seqs.push(self.seqs.get(&seq).context("unknown sequence")?);
        }
        // iterate layer-major, then lane, then head: strip bases ascend
        // strictly, which carve_strips requires
        let mut jobs = Vec::with_capacity(l * lanes.len() * h);
        for layer in 0..l {
            for (i, &(_, lane)) in lanes.iter().enumerate() {
                for head in 0..h {
                    let base = (((layer * batch) + lane) * h + head) * t_max * dh;
                    jobs.push((seqs[i], layer, head, base));
                }
            }
        }
        self.gather_strips_multi(jobs, t_max, k_out, v_out, ws);
        Ok(seqs.iter().map(|s| s.len.min(t_max)).collect())
    }

    /// The single-sequence strip gather: build this sequence's
    /// `n_layers × n_heads` strip jobs located by `strip_base` and run
    /// them through the shared drain.
    fn gather_strips(
        &self,
        s: &SeqCache,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
        strip_base: impl Fn(usize, usize) -> usize,
    ) -> usize {
        let cfg = *self.alloc.cfg();
        let (l, h) = (cfg.n_layers, cfg.n_heads);
        let mut jobs = Vec::with_capacity(l * h);
        for layer in 0..l {
            for head in 0..h {
                jobs.push((s, layer, head, strip_base(layer, head)));
            }
        }
        self.gather_strips_multi(jobs, t_max, k_out, v_out, ws);
        s.len.min(t_max)
    }

    /// The shared batched gather core: carve `k_out`/`v_out` into the
    /// disjoint strips located by the (strictly ascending) job bases,
    /// zero each strip, then decode it page-run by page-run with
    /// strided batch decodes — in parallel across all jobs when the
    /// policy allows.  Jobs may reference different sequences (the
    /// cross-lane drain).
    fn gather_strips_multi(
        &self,
        jobs: Vec<(&SeqCache, usize, usize, usize)>,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) {
        let cfg = *self.alloc.cfg();
        let dh = cfg.d_head;
        let tp = cfg.tokens_per_page;
        let slot_bytes = cfg.slot_bytes();
        let strip_len = t_max * dh;
        ws.scratch.resize_with(jobs.len(), BatchScratch::new);
        ws.bases.clear();
        ws.bases.extend(jobs.iter().map(|&(_, _, _, base)| base));

        let total_vecs: usize =
            jobs.iter().map(|&(s, _, _, _)| s.len.min(t_max)).sum::<usize>() * 2;
        let k_strips = carve_strips(k_out, &ws.bases, strip_len);
        let v_strips = carve_strips(v_out, &ws.bases, strip_len);
        let units: Vec<(&SeqCache, usize, usize, &mut [f32], &mut [f32], &mut BatchScratch)> =
            jobs.into_iter()
                .zip(k_strips.into_iter().zip(v_strips))
                .zip(ws.scratch.iter_mut())
                .map(|(((s, layer, head, _), (ks, vs)), sc)| (s, layer, head, ks, vs, sc))
                .collect();

        // scoped threads rather than the long-lived ThreadPool: the units
        // borrow the caller's output buffers, which `ThreadPool`'s
        // 'static jobs cannot; the spawn cost is gated on work size
        let threads = if total_vecs < MIN_PARALLEL_VECTORS {
            1
        } else {
            self.parallel.threads(units.len())
        };
        scope_units(units, threads, |(s, layer, head, k_strip, v_strip, scratch)| {
            let n = s.len.min(t_max);
            k_strip.fill(0.0);
            v_strip.fill(0.0);
            let mut t = 0usize;
            while t < n {
                let run = tp.min(n - t);
                let page = self.alloc.page(s.pages[t / tp]);
                let (k_col, stride) = page.column(&cfg, layer, head, false);
                let (v_col, _) = page.column(&cfg, layer, head, true);
                debug_assert_eq!(stride, slot_bytes);
                self.stage1.decode_batch_strided(
                    k_col,
                    slot_bytes,
                    run,
                    &mut k_strip[t * dh..(t + run) * dh],
                    scratch,
                );
                self.stage1.decode_batch_strided(
                    v_col,
                    slot_bytes,
                    run,
                    &mut v_strip[t * dh..(t + run) * dh],
                    scratch,
                );
                t += run;
            }
        });
    }

    /// The pre-batch per-vector gather (one `Stage1::decode` call per
    /// (token, layer, head) vector, allocating inside each call) —
    /// retained as the property-test oracle and the
    /// `gather_throughput` bench baseline.  Same output layout and
    /// zero-padding semantics as [`CacheManager::gather_ws`].
    pub fn gather_reference(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_out.len() != l * h * t_max * dh || v_out.len() != l * h * t_max * dh {
            bail!("gather_reference: output buffer shape mismatch");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        k_out.fill(0.0);
        v_out.fill(0.0);
        let tp = cfg.tokens_per_page;
        for t in 0..n {
            let page = self.alloc.page(s.pages[t / tp]);
            let slot = t % tp;
            for layer in 0..l {
                for head in 0..h {
                    let dst = ((layer * h + head) * t_max + t) * dh;
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, false),
                        &mut k_out[dst..dst + dh],
                    );
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, true),
                        &mut v_out[dst..dst + dh],
                    );
                }
            }
        }
        Ok(n)
    }

    /// Shadow (uncompressed) cache in the same `[l][h][t][dh]` layout —
    /// only valid when `keep_shadow` was set before appends.
    pub fn gather_shadow(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        k_out.fill(0.0);
        v_out.fill(0.0);
        for t in 0..n {
            for layer in 0..l {
                for head in 0..h {
                    let src = (t * l * h + layer * h + head) * dh;
                    let dst = ((layer * h + head) * t_max + t) * dh;
                    k_out[dst..dst + dh].copy_from_slice(&s.shadow_k[src..src + dh]);
                    v_out[dst..dst + dh].copy_from_slice(&s.shadow_v[src..src + dh]);
                }
            }
        }
        Ok(n)
    }

    /// compressed bytes per token slot (for metrics)
    pub fn slot_bytes(&self) -> (usize, usize) {
        let cfg = self.alloc.cfg();
        (cfg.slot_bytes(), cfg.slot_bytes_uncompressed())
    }
}

/// Split `buf` into disjoint `strip_len`-sized mutable windows starting
/// at the (strictly ascending, non-overlapping) `bases`, skipping the
/// gaps between them.  Lets the strip-parallel gather hand each worker
/// an owned `&mut` window of a shared output buffer safely.
fn carve_strips<'a>(
    mut buf: &'a mut [f32],
    bases: &[usize],
    strip_len: usize,
) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(bases.len());
    let mut cursor = 0usize;
    for &base in bases {
        debug_assert!(base >= cursor, "strip bases must ascend without overlap");
        let tmp = buf;
        let (_gap, rest) = tmp.split_at_mut(base - cursor);
        let (strip, rest) = rest.split_at_mut(strip_len);
        out.push(strip);
        buf = rest;
        cursor = base + strip_len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Stage1, Stage1Config, Variant};
    use crate::util::prng::Rng;

    fn mk(max_pages: usize, bits: u8) -> CacheManager {
        let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, 64, bits));
        let cfg = PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 64,
            encoded_len: stage1.encoded_len(),
        };
        CacheManager::new(stage1, cfg, max_pages)
    }

    fn token(rng: &mut Rng, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
    }

    #[test]
    fn append_gather_roundtrip_quality() {
        let mut m = mk(64, 4);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(1);
        m.start_seq(1).unwrap();
        let mut truth_k = Vec::new();
        for _ in 0..10 {
            let (k, v) = token(&mut rng, &cfg);
            truth_k.push(k.clone());
            m.append_token(1, &k, &v).unwrap();
        }
        assert_eq!(m.seq_len(1), 10);
        let t_max = 16;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut k_out = vec![0.0f32; sz];
        let mut v_out = vec![0.0f32; sz];
        let n = m.gather(1, t_max, &mut k_out, &mut v_out).unwrap();
        assert_eq!(n, 10);
        // token 3, layer 1, head 0 reconstruction ≈ original
        let dh = cfg.d_head;
        let t = 3;
        let dst = ((1 * cfg.n_heads + 0) * t_max + t) * dh;
        let src = (1 * cfg.n_heads + 0) * dh;
        let rel = crate::metrics::rel_l2(&truth_k[t][src..src + dh], &k_out[dst..dst + dh]);
        assert!(rel < 0.25, "rel {rel}");
        // padding stays zero
        let pad = ((0 * cfg.n_heads) * t_max + 12) * dh;
        assert!(k_out[pad..pad + dh].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batched_gather_bit_exact_with_reference() {
        // the batch path (any threading policy) must reproduce the
        // per-vector reference path bit for bit
        for policy in [
            ParallelPolicy::Off,
            ParallelPolicy::Auto,
            ParallelPolicy::Fixed(3),
        ] {
            let mut m = mk(64, 3);
            m.parallel = policy;
            let cfg = m.page_cfg();
            let mut rng = Rng::new(7);
            m.start_seq(1).unwrap();
            // 64 tokens × 2L × 2H × 2 = 512 vectors: crosses
            // MIN_PARALLEL_VECTORS so the threaded path really runs
            for _ in 0..64 {
                let (k, v) = token(&mut rng, &cfg);
                m.append_token(1, &k, &v).unwrap();
            }
            let t_max = 68;
            let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
            let (mut ka, mut va) = (vec![0.0f32; sz], vec![0.0f32; sz]);
            let (mut kb, mut vb) = (vec![1.0f32; sz], vec![1.0f32; sz]);
            let mut ws = GatherWorkspace::new();
            let na = m.gather_reference(1, t_max, &mut ka, &mut va).unwrap();
            let nb = m.gather_ws(1, t_max, &mut kb, &mut vb, &mut ws).unwrap();
            assert_eq!(na, nb);
            assert_eq!(
                ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} K"
            );
            assert_eq!(
                va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} V"
            );
        }
    }

    #[test]
    fn batched_lane_gather_matches_single_gather() {
        let mut m = mk(64, 4);
        m.parallel = ParallelPolicy::Auto;
        let cfg = m.page_cfg();
        let mut rng = Rng::new(8);
        m.start_seq(1).unwrap();
        for _ in 0..18 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(1, &k, &v).unwrap();
        }
        let (t_max, batch, lane) = (20usize, 3usize, 1usize);
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let single = l * h * t_max * dh;
        let (mut k1, mut v1) = (vec![0.0f32; single], vec![0.0f32; single]);
        m.gather(1, t_max, &mut k1, &mut v1).unwrap();
        let wide = l * batch * h * t_max * dh;
        let (mut kb, mut vb) = (vec![9.0f32; wide], vec![9.0f32; wide]);
        let mut ws = GatherWorkspace::new();
        m.gather_into_batch_ws(1, lane, batch, t_max, &mut kb, &mut vb, &mut ws)
            .unwrap();
        for layer in 0..l {
            for head in 0..h {
                let a = (layer * h + head) * t_max * dh;
                let b = (((layer * batch) + lane) * h + head) * t_max * dh;
                assert_eq!(
                    &k1[a..a + t_max * dh],
                    &kb[b..b + t_max * dh],
                    "layer {layer} head {head}"
                );
                assert_eq!(&v1[a..a + t_max * dh], &vb[b..b + t_max * dh]);
            }
        }
        // other lanes untouched by the lane gather
        let other = (((0 * batch) + 0) * h + 0) * t_max * dh;
        assert!(kb[other..other + dh].iter().all(|&x| x == 9.0));
    }

    #[test]
    fn append_run_matches_append_token_loop() {
        // one chunk-append must leave pages bit-identical to the same
        // tokens appended one at a time (ragged page boundary included:
        // 3 tokens pre-seeded, then a 9-token run over 4-token pages)
        let (mut a, mut b) = (mk(64, 3), mk(64, 3));
        let cfg = a.page_cfg();
        let tok_n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        let mut rng = Rng::new(21);
        a.start_seq(1).unwrap();
        b.start_seq(1).unwrap();
        let seed: Vec<(Vec<f32>, Vec<f32>)> = (0..3).map(|_| token(&mut rng, &cfg)).collect();
        for (k, v) in &seed {
            a.append_token(1, k, v).unwrap();
            b.append_token(1, k, v).unwrap();
        }
        let run: Vec<(Vec<f32>, Vec<f32>)> = (0..9).map(|_| token(&mut rng, &cfg)).collect();
        let mut k_run = Vec::new();
        let mut v_run = Vec::new();
        for (k, v) in &run {
            k_run.extend_from_slice(k);
            v_run.extend_from_slice(v);
            b.append_token(1, k, v).unwrap();
        }
        assert_eq!(k_run.len(), 9 * tok_n);
        a.append_run(1, &k_run, &v_run, 9).unwrap();
        assert_eq!(a.seq_len(1), 12);
        assert_eq!(a.seq_len(1), b.seq_len(1));
        let t_max = 12;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut ka, mut va) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let (mut kb, mut vb) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        a.gather(1, t_max, &mut ka, &mut va).unwrap();
        b.gather(1, t_max, &mut kb, &mut vb).unwrap();
        assert_eq!(
            ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn append_run_failure_leaves_sequence_unchanged() {
        // pool of 2 pages × 4 tokens = 8; a 9-token run must fail and
        // roll back the pre-reserved pages
        let mut m = mk(2, 2);
        let cfg = m.page_cfg();
        let tok_n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        let mut rng = Rng::new(22);
        m.start_seq(1).unwrap();
        let k_run = rng.gaussian_vec_f32(9 * tok_n);
        let v_run = rng.gaussian_vec_f32(9 * tok_n);
        assert!(m.append_run(1, &k_run, &v_run, 9).is_err());
        assert_eq!(m.seq_len(1), 0);
        assert_eq!(m.pages_in_use(), 0, "reserved pages must be released");
        // an 8-token run then fits
        m.append_run(1, &k_run[..8 * tok_n], &v_run[..8 * tok_n], 8).unwrap();
        assert_eq!(m.seq_len(1), 8);
    }

    #[test]
    fn append_run_empty_and_shadow() {
        let mut m = mk(8, 4);
        m.keep_shadow = true;
        let cfg = m.page_cfg();
        let tok_n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        let mut rng = Rng::new(23);
        m.start_seq(1).unwrap();
        m.append_run(1, &[], &[], 0).unwrap();
        assert_eq!(m.seq_len(1), 0);
        assert!(m.append_run(99, &[], &[], 0).is_err());
        let k = rng.gaussian_vec_f32(2 * tok_n);
        let v = rng.gaussian_vec_f32(2 * tok_n);
        m.append_run(1, &k, &v, 2).unwrap();
        let t_max = 2;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut ks, mut vs) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        m.gather_shadow(1, t_max, &mut ks, &mut vs).unwrap();
        // token 1, layer 1, head 0 of the shadow equals the run input
        let dh = cfg.d_head;
        let src = (1 * cfg.n_layers * cfg.n_heads + 1 * cfg.n_heads) * dh;
        let dst = ((1 * cfg.n_heads) * t_max + 1) * dh;
        assert_eq!(&ks[dst..dst + dh], &k[src..src + dh]);
    }

    #[test]
    fn multi_lane_gather_matches_per_lane_gathers() {
        for policy in [ParallelPolicy::Off, ParallelPolicy::Auto] {
            let mut m = mk(64, 4);
            m.parallel = policy;
            let cfg = m.page_cfg();
            let mut rng = Rng::new(24);
            // three sequences of different lengths on lanes 0, 2, 3 of 4
            let lens = [5usize, 11, 64];
            let lanes = [0usize, 2, 3];
            for (i, &len) in lens.iter().enumerate() {
                m.start_seq(i as u64 + 1).unwrap();
                for _ in 0..len {
                    let (k, v) = token(&mut rng, &cfg);
                    m.append_token(i as u64 + 1, &k, &v).unwrap();
                }
            }
            let (t_max, batch) = (64usize, 4usize);
            let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
            let wide = l * batch * h * t_max * dh;
            let (mut ka, mut va) = (vec![7.0f32; wide], vec![7.0f32; wide]);
            let (mut kb, mut vb) = (vec![7.0f32; wide], vec![7.0f32; wide]);
            let mut ws = GatherWorkspace::new();
            // reference: one gather_into_batch per lane
            for (i, &lane) in lanes.iter().enumerate() {
                m.gather_into_batch_ws(i as u64 + 1, lane, batch, t_max, &mut ka, &mut va, &mut ws)
                    .unwrap();
            }
            // one cross-lane drain
            let pairs: Vec<(SeqId, usize)> =
                lanes.iter().enumerate().map(|(i, &lane)| (i as u64 + 1, lane)).collect();
            let ns = m
                .gather_lanes_into_batch_ws(&pairs, batch, t_max, &mut kb, &mut vb, &mut ws)
                .unwrap();
            assert_eq!(ns, lens.to_vec());
            assert_eq!(
                ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} K"
            );
            assert_eq!(
                va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} V"
            );
            // untouched lane 1 keeps its sentinel
            let lane1 = ((0 * batch + 1) * h) * t_max * dh;
            assert!(kb[lane1..lane1 + dh].iter().all(|&x| x == 7.0));
        }
    }

    #[test]
    fn multi_lane_gather_validates_lanes() {
        let mut m = mk(8, 2);
        m.start_seq(1).unwrap();
        m.start_seq(2).unwrap();
        let cfg = m.page_cfg();
        let sz = cfg.n_layers * 4 * cfg.n_heads * 8 * cfg.d_head;
        let (mut k, mut v) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let mut ws = GatherWorkspace::new();
        // out-of-range lane
        assert!(m
            .gather_lanes_into_batch_ws(&[(1, 4)], 4, 8, &mut k, &mut v, &mut ws)
            .is_err());
        // non-ascending lanes
        assert!(m
            .gather_lanes_into_batch_ws(&[(1, 2), (2, 1)], 4, 8, &mut k, &mut v, &mut ws)
            .is_err());
        // unknown sequence
        assert!(m
            .gather_lanes_into_batch_ws(&[(9, 0)], 4, 8, &mut k, &mut v, &mut ws)
            .is_err());
        // empty lane list is a no-op
        let ns = m
            .gather_lanes_into_batch_ws(&[], 4, 8, &mut k, &mut v, &mut ws)
            .unwrap();
        assert!(ns.is_empty());
    }

    #[test]
    fn pages_allocated_lazily_and_released() {
        let mut m = mk(8, 2);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(2);
        m.start_seq(7).unwrap();
        assert_eq!(m.pages_in_use(), 0);
        for i in 0..9 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(7, &k, &v).unwrap();
            assert_eq!(m.pages_in_use(), i / 4 + 1);
        }
        m.drop_seq(7);
        assert_eq!(m.pages_in_use(), 0);
    }

    #[test]
    fn capacity_exhaustion_fails_cleanly() {
        let mut m = mk(1, 2);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(3);
        m.start_seq(1).unwrap();
        for _ in 0..4 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(1, &k, &v).unwrap();
        }
        let (k, v) = token(&mut rng, &cfg);
        let err = m.append_token(1, &k, &v);
        assert!(err.is_err());
        // sequence state unchanged by the failed append
        assert_eq!(m.seq_len(1), 4);
    }

    #[test]
    fn admission_math() {
        let m = mk(4, 2);
        assert!(m.can_admit(16)); // 4 pages × 4 tokens
        assert!(!m.can_admit(17));
    }

    #[test]
    fn shadow_matches_truth_exactly() {
        let mut m = mk(16, 2);
        m.keep_shadow = true;
        let cfg = m.page_cfg();
        let mut rng = Rng::new(4);
        m.start_seq(1).unwrap();
        let (k, v) = token(&mut rng, &cfg);
        m.append_token(1, &k, &v).unwrap();
        let t_max = 4;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut k_out = vec![0.0f32; sz];
        let mut v_out = vec![0.0f32; sz];
        m.gather_shadow(1, t_max, &mut k_out, &mut v_out).unwrap();
        let dh = cfg.d_head;
        for layer in 0..cfg.n_layers {
            for head in 0..cfg.n_heads {
                let src = (layer * cfg.n_heads + head) * dh;
                let dst = ((layer * cfg.n_heads + head) * t_max) * dh;
                assert_eq!(&k_out[dst..dst + dh], &k[src..src + dh]);
                assert_eq!(&v_out[dst..dst + dh], &v[src..src + dh]);
            }
        }
    }

    #[test]
    fn unknown_seq_rejected() {
        let mut m = mk(4, 2);
        let cfg = m.page_cfg();
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        assert!(m.append_token(99, &vec![0.0; n], &vec![0.0; n]).is_err());
        let mut buf = vec![0.0f32; cfg.n_layers * cfg.n_heads * 4 * cfg.d_head];
        let mut buf2 = buf.clone();
        assert!(m.gather(99, 4, &mut buf, &mut buf2).is_err());
    }

    #[test]
    fn duplicate_seq_rejected() {
        let mut m = mk(4, 2);
        m.start_seq(1).unwrap();
        assert!(m.start_seq(1).is_err());
    }

    #[test]
    fn multiple_sequences_isolated() {
        let mut m = mk(32, 4);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(5);
        m.start_seq(1).unwrap();
        m.start_seq(2).unwrap();
        let (k1, v1) = token(&mut rng, &cfg);
        let (k2, v2) = token(&mut rng, &cfg);
        m.append_token(1, &k1, &v1).unwrap();
        m.append_token(2, &k2, &v2).unwrap();
        let t_max = 4;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut a = vec![0.0f32; sz];
        let mut b = vec![0.0f32; sz];
        let mut tmp = vec![0.0f32; sz];
        m.gather(1, t_max, &mut a, &mut tmp).unwrap();
        m.gather(2, t_max, &mut b, &mut tmp).unwrap();
        // different tokens → different reconstructions
        assert_ne!(a, b);
        m.drop_seq(1);
        // seq 2 still readable after seq 1 dropped
        assert!(m.gather(2, t_max, &mut b, &mut tmp).is_ok());
    }

    #[test]
    fn carve_strips_tiles_and_skips_gaps() {
        let mut buf = vec![0.0f32; 40];
        let strips = carve_strips(&mut buf, &[5, 15, 30], 5);
        assert_eq!(strips.len(), 3);
        for (i, s) in strips.into_iter().enumerate() {
            s.fill((i + 1) as f32);
        }
        assert_eq!(&buf[5..10], &[1.0; 5]);
        assert_eq!(&buf[15..20], &[2.0; 5]);
        assert_eq!(&buf[30..35], &[3.0; 5]);
        // gaps untouched
        assert!(buf[0..5].iter().all(|&x| x == 0.0));
        assert!(buf[10..15].iter().all(|&x| x == 0.0));
        assert!(buf[20..30].iter().all(|&x| x == 0.0));
        assert!(buf[35..].iter().all(|&x| x == 0.0));
    }
}
