//! Per-sequence compressed KV cache: block tables over pooled pages,
//! compress-on-append, reconstruct-on-gather.
//!
//! This is where IsoQuant sits on the serving critical path: every
//! generated token's K/V head vectors are stage-1 *encoded* once on
//! append and *decoded* on every subsequent decode step's gather — the
//! deployment pattern the paper's fused-kernel latency argument is
//! about.
//!
//! Both directions run the batch-first stage-1 API
//! (`quant::pipeline`'s `encode_batch` / `decode_batch_strided`):
//!
//! * **append** batch-encodes a token's `n_layers × n_heads` contiguous
//!   K (then V) head vectors into a persistent [`PackedSink`] and fans
//!   the records out to page slots — zero steady-state allocation; the
//!   prefill path appends whole chunks at once through
//!   [`CacheManager::append_run`] (one `encode_batch` per side covering
//!   `tokens × layers × heads` vectors, page slots written in slot
//!   order);
//! * **gather** decomposes into `n_layers × n_heads` independent
//!   *strips* (one `[t][dh]` destination run per (layer, head)), each
//!   decoded page-by-page with strided batch decodes, optionally in
//!   parallel across strips per the manager's [`ParallelPolicy`]; the
//!   engine gathers *all* active lanes through one
//!   [`CacheManager::gather_lanes_into_batch_ws`] drain so every lane's
//!   strip units share one work queue.
//!
//! The pre-batch per-vector path survives as
//! [`CacheManager::gather_reference`]: the property-test oracle and the
//! bench baseline (`benches/gather_throughput.rs`).
//!
//! With `prefix_sharing` on, page ownership is refcounted and sealed
//! prompt pages are shared between same-prefix sequences through one of
//! two index backends (`[cache] prefix_index`): the whole-page
//! [`super::prefix::PrefixIndex`] (flat, the default) or the
//! token-level [`super::radix::RadixIndex`], whose sub-page matches
//! become slot-range copies so prefill resumes at a *token* boundary —
//! see the `kvcache` module docs for the sealed/open/CoW invariants.
//! All gather paths are read-only and unaffected by sharing or the
//! index choice.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::allocator::{PageAllocator, PageId};
use super::page::{chain_key, PageConfig, PrefixKey};
use super::prefix::{PrefixIndex, PrefixIndexKind};
use super::radix::RadixIndex;
use super::store::PageStore;
use crate::metrics::ShareStats;
use crate::quant::{BatchScratch, PackedSink, Stage1};
use crate::util::pool::{scope_units, ParallelPolicy};

pub type SeqId = u64;

/// Below this many encoded vectors (tokens × layers × heads × K/V) a
/// gather runs single-threaded even under `ParallelPolicy::Auto` —
/// spawning scoped threads costs tens of microseconds, which only pays
/// off once the decode work dwarfs it.
const MIN_PARALLEL_VECTORS: usize = 512;

/// Per-sequence state: block table + token count.
#[derive(Debug, Default, Clone)]
struct SeqCache {
    pages: Vec<PageId>,
    len: usize,
    /// the prompt's token ids (prefix sharing only) — published index
    /// entries carry the exact token run they cover, so lookups verify
    /// content rather than trusting a 64-bit hash
    prompt: Vec<i32>,
    /// chain keys of the prompt's full pages (prefix sharing only; set
    /// by [`CacheManager::start_seq_with_prompt`]) — page `i` of the
    /// sequence, once full, seals under `prompt_keys[i]`
    prompt_keys: Vec<PrefixKey>,
    /// chain key of the prompt's partial last page, if any
    tail_key: Option<PrefixKey>,
    /// how many leading tokens of this sequence are prompt tokens (0
    /// when admitted without a prompt, or with sharing off)
    prompt_len: usize,
    /// radix index only: the prompt's final page was assembled by a
    /// sub-page slot-range copy and stays *open* (exclusively owned),
    /// so decode appends write in place — it must not seal/publish at
    /// prompt completion the way a freshly encoded tail does
    tail_copied: bool,
    /// optional uncompressed shadow copy (fidelity experiments):
    /// layout [layer][head][token][dh], appended per token
    shadow_k: Vec<f32>,
    shadow_v: Vec<f32>,
}

/// What prefix-index adoption contributed to a newly admitted sequence.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixReuse {
    /// whole sealed pages adopted from the index
    pub pages: usize,
    /// prompt tokens those pages cover (already cached — prefill can
    /// skip them)
    pub tokens: usize,
}

/// One adoptable link of a prompt's chain, as discovered by a probe.
#[derive(Debug, Clone)]
struct ProbeHit {
    key: PrefixKey,
    parent: Option<PrefixKey>,
    /// `Some` = resident page (hot or warm) to adopt by refcount;
    /// `None` = cold: resolvable only from the persistent store, needs
    /// promotion into a freshly allocated page
    page: Option<PageId>,
    /// prompt token range `[start, end)` this page covers
    start: usize,
    end: usize,
    /// chain depth (page index; the partial tail is one past the last
    /// full page)
    depth: u32,
}

/// Read-only result of walking the prefix index (and, when attached,
/// the persistent store) over a prompt.
#[derive(Default)]
struct PrefixProbe {
    /// adoptable chain links, in sequence order (full pages, then
    /// possibly the sealed partial tail); the walk stops at the first
    /// total miss
    hits: Vec<ProbeHit>,
    /// resident hits on *full* prompt pages — the only hits that need
    /// no allocation (a tail hit still costs its copy-on-write
    /// replacement; a cold hit costs the page it promotes into)
    warm_full_hits: usize,
    /// resident hits that are currently zero-ref cached — adopting them
    /// consumes pages the admission math would otherwise count as
    /// evictable
    cached_hits: usize,
    /// the partial tail resolved to a *resident* page — the only tail
    /// outcome that costs no allocation beyond its counted slot (a
    /// cold tail promotes into a fresh page, a missed tail encodes
    /// into one; either way the sealed result is then copy-on-write
    /// replaced by the first generated token, costing a second page)
    warm_tail: bool,
}

/// One resolved step of a radix adoption plan
/// ([`CacheManager::plan_radix`]), in page-position order.
enum RadixStep {
    /// a resident sealed page fully covers tokens `[start, end)`:
    /// adopt it whole by refcount — no allocation.  For the prompt's
    /// partial tail this also covers the *strict sub-prefix* case
    /// (gathers read only the leading slots), which the flat index
    /// cannot match at all
    Adopt {
        page: PageId,
        start: usize,
        end: usize,
    },
    /// tokens `[start, end)` resolve only from the persistent store:
    /// promote into a fresh page (full re-verification; failure is a
    /// miss)
    Promote {
        key: PrefixKey,
        parent: Option<PrefixKey>,
        start: usize,
        end: usize,
    },
    /// resident coverage that no single page serves whole: copy the
    /// covered slot ranges `srcs = (page, slot0, n)` into a fresh
    /// *open* page.  For a *full* span split across source pages the
    /// plan continues (the assembled page is complete); a *partial*
    /// span ends the plan, and prefill re-encodes only the divergent
    /// suffix
    Copy {
        srcs: Vec<(PageId, usize, usize)>,
        start: usize,
        end: usize,
    },
}

/// What [`CacheManager::adopt_radix`] produced for a new sequence.
#[derive(Default)]
struct RadixAdoption {
    /// the sequence's leading pages, in position order (adopted,
    /// promoted, and at most one trailing slot-copy page)
    pages: Vec<PageId>,
    /// prompt tokens covered — prefill resumes here (token, not page,
    /// granularity)
    tokens: usize,
    /// the prompt's final page is an open slot-copy (suppresses the
    /// tail seal/publish and the decode-time CoW)
    tail_copied: bool,
    /// whole resident full pages adopted (the zero-allocation hits)
    warm_full: usize,
    /// index hits (adopted + promoted pages; the copy page is an
    /// allocation, not a hit)
    hit_pages: usize,
}

/// Persistent scratch for the batched gather path: one decode scratch
/// per (layer, head) strip so strips can decode concurrently, plus the
/// strip-base table.  Keep one per engine (or per bench loop); the hot
/// inner-loop buffers then persist across gathers — the only remaining
/// per-call allocation is the O(lanes × layers × heads) strip/job-list
/// bookkeeping, whose `&mut`/`&SeqCache` lifetimes are necessarily
/// per-call.
#[derive(Debug, Default)]
pub struct GatherWorkspace {
    scratch: Vec<BatchScratch>,
    bases: Vec<usize>,
}

impl GatherWorkspace {
    pub fn new() -> GatherWorkspace {
        GatherWorkspace::default()
    }
}

/// The engine-wide KV cache.
pub struct CacheManager {
    alloc: PageAllocator,
    stage1: Stage1,
    seqs: HashMap<SeqId, SeqCache>,
    /// content-addressed whole-page index of sealed prompt pages
    /// (active when `index_kind` is [`PrefixIndexKind::Flat`])
    prefix: PrefixIndex,
    /// token-level radix tree over the same pages (active when
    /// `index_kind` is [`PrefixIndexKind::Radix`])
    radix: RadixIndex,
    /// which index structure answers prefix lookups
    /// (`[cache] prefix_index`); set before the first sequence starts
    pub index_kind: PrefixIndexKind,
    /// chain-hash salt: stage-1 config fingerprint mixed with the page
    /// geometry, so caches with different encodings or layouts never
    /// share pages
    fingerprint: u64,
    /// persistent encode sink for appends (K batch, then V batch)
    sink: PackedSink,
    /// threading policy for the strip-parallel gather path
    pub parallel: ParallelPolicy,
    /// keep an uncompressed shadow (for fidelity measurement only; off on
    /// the real serving path)
    pub keep_shadow: bool,
    /// share sealed prompt pages between sequences (`[cache]
    /// prefix_sharing`); off reproduces the exclusive-ownership cache
    pub prefix_sharing: bool,
    /// decode each distinct (page, slot-range) strip once per cross-lane
    /// gather and fan duplicate rows out by copy (`[engine]
    /// gather_dedup`); output is byte-identical either way, only the
    /// `ShareStats` gather-dedup counters observe the difference
    pub gather_dedup: bool,
    /// prefix-sharing accounting (hits, CoW copies, bytes deduplicated)
    pub share: ShareStats,
    /// optional persistent page store: zero-ref parks spill to it
    /// (write-behind) and index misses consult it before re-encoding
    store: Option<PageStore>,
}

impl CacheManager {
    pub fn new(stage1: Stage1, page_cfg: PageConfig, max_pages: usize) -> CacheManager {
        assert_eq!(stage1.d(), page_cfg.d_head);
        assert_eq!(stage1.encoded_len(), page_cfg.encoded_len);
        let mut fingerprint = stage1.cfg.fingerprint();
        for v in [
            page_cfg.tokens_per_page,
            page_cfg.n_layers,
            page_cfg.n_heads,
        ] {
            fingerprint = crate::util::prng::mix64(fingerprint, v as u64);
        }
        CacheManager {
            alloc: PageAllocator::new(page_cfg, max_pages),
            stage1,
            seqs: HashMap::new(),
            prefix: PrefixIndex::new(),
            radix: RadixIndex::new(page_cfg.tokens_per_page),
            index_kind: PrefixIndexKind::Flat,
            fingerprint,
            sink: PackedSink::new(),
            parallel: ParallelPolicy::Off,
            keep_shadow: false,
            prefix_sharing: false,
            gather_dedup: true,
            share: ShareStats::default(),
            store: None,
        }
    }

    pub fn stage1(&self) -> &Stage1 {
        &self.stage1
    }

    /// The chain-hash salt: stage-1 config fingerprint mixed with the
    /// page geometry.  A persistent store must be opened with exactly
    /// this value so its records are interchangeable with this cache's
    /// pages.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Attach a persistent page store (must share this cache's
    /// fingerprint and page size).  From here on, zero-ref parks spill
    /// to it and prefix-index misses consult it before re-encoding.
    pub fn attach_store(&mut self, store: PageStore) {
        assert_eq!(
            store.fingerprint(),
            self.fingerprint,
            "store fingerprint must match the cache"
        );
        assert_eq!(
            store.cfg().page_bytes,
            self.alloc.cfg().page_bytes(),
            "store page size must match the cache"
        );
        self.share.pages_rehydrated += store.stats().rehydrated;
        self.store = Some(store);
    }

    pub fn store(&self) -> Option<&PageStore> {
        self.store.as_ref()
    }

    /// Cold entries resolvable from the persistent store (0 without one).
    pub fn cold_pages(&self) -> usize {
        self.store.as_ref().map(|s| s.len()).unwrap_or(0)
    }

    /// Block until every spill enqueued so far is durable (shutdown /
    /// test barrier; a no-op without a store).
    pub fn flush_store(&mut self) {
        if let Some(s) = &self.store {
            s.flush();
        }
        self.note_store_health();
    }

    /// Mirror the store's degraded flag and compaction counters into
    /// [`ShareStats`] so the serving stats line (and tests) see
    /// persistence health without reaching into the store.  Cheap;
    /// called after spill/flush.
    pub fn note_store_health(&mut self) {
        if let Some(s) = self.store.as_ref() {
            let st = s.stats();
            self.share.records_compacted = st.records_compacted;
            self.share.segments_compacted = st.segments_compacted;
            if s.degraded() {
                self.share.store_degraded = 1;
            }
        }
    }

    pub fn page_cfg(&self) -> PageConfig {
        *self.alloc.cfg()
    }

    pub fn seq_len(&self, seq: SeqId) -> usize {
        self.seqs.get(&seq).map(|s| s.len).unwrap_or(0)
    }

    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Pages resident outside the free pool — includes zero-ref pages
    /// the prefix index keeps warm (see [`CacheManager::live_pages`]).
    pub fn pages_in_use(&self) -> usize {
        self.alloc.allocated()
    }

    /// Zero-ref cached pages of whichever index backend is active.
    fn index_cached_len(&self) -> usize {
        match self.index_kind {
            PrefixIndexKind::Flat => self.prefix.cached_len(),
            PrefixIndexKind::Radix => self.radix.cached_len(),
        }
    }

    /// Pages owned by at least one live sequence.
    pub fn live_pages(&self) -> usize {
        self.alloc.allocated() - self.index_cached_len()
    }

    /// Zero-ref sealed pages the prefix index keeps resident (evictable).
    pub fn cached_pages(&self) -> usize {
        self.index_cached_len()
    }

    pub fn high_water_pages(&self) -> usize {
        self.alloc.high_water_pages()
    }

    /// Hard pool capacity in pages.
    pub fn page_capacity(&self) -> usize {
        self.alloc.capacity()
    }

    /// Cap the radix index's run-length nodes at `n` pages per node
    /// (0 = unlimited, 1 = the v1 one-node-per-page shape).  Benches
    /// and the state-machine suite use this to compare tree shapes;
    /// only affects nodes inserted from here on.
    pub fn set_radix_max_run_pages(&mut self, n: usize) {
        self.radix.set_max_run_pages(n);
    }

    /// Number of nodes in the radix tree (0 under the flat index).
    /// The shape metric for cross-page runs: a P-page stem is one node
    /// under v2 runs, P nodes under the v1 one-page-per-node shape.
    pub fn radix_node_count(&self) -> usize {
        self.radix.node_count()
    }

    /// Read-only longest-cached-prefix probe: how many leading tokens
    /// of `prompt` the resident cache already covers.  Under the radix
    /// index this is one tree walk (token-granular); under the flat
    /// index it is the chain-key walk (page-granular, including cold
    /// store hits).  The batcher uses it to drain deepest-LCP-first
    /// under pool pressure.
    pub fn cached_lcp(&self, prompt: &[i32]) -> usize {
        if !self.prefix_sharing || prompt.is_empty() {
            return 0;
        }
        match self.index_kind {
            PrefixIndexKind::Radix => self.radix.match_prefix(prompt).1,
            PrefixIndexKind::Flat => self
                .probe_prefix(prompt)
                .hits
                .last()
                .map(|h| h.end)
                .unwrap_or(0),
        }
    }

    /// Pages shared by 2+ sequences.
    pub fn shared_pages(&self) -> usize {
        self.alloc.shared_pages()
    }

    /// Pages owned by exactly one sequence.
    pub fn exclusive_pages(&self) -> usize {
        self.alloc.exclusive_pages()
    }

    /// Total page ownerships across all sequences (0 ⇔ every sequence
    /// dropped returned its pages).
    pub fn live_refs(&self) -> u64 {
        self.alloc.live_refs()
    }

    /// Prefix-index entries (sealed prompt pages addressable by content
    /// — flat map entries, or radix-referenced pages).
    pub fn prefix_index_len(&self) -> usize {
        match self.index_kind {
            PrefixIndexKind::Flat => self.prefix.len(),
            PrefixIndexKind::Radix => self.radix.len(),
        }
    }

    /// Pages a new allocation could draw on: the free pool plus
    /// zero-ref cached pages (evictable on demand).
    pub fn available_pages(&self) -> usize {
        self.alloc.free_count() + self.index_cached_len()
    }

    /// Pages needed to grow a sequence to `new_len` tokens.
    pub fn pages_needed(&self, seq: SeqId, new_len: usize) -> usize {
        let tp = self.alloc.cfg().tokens_per_page;
        let have = self.seqs.get(&seq).map(|s| s.pages.len()).unwrap_or(0);
        let need = new_len.div_ceil(tp);
        need.saturating_sub(have)
    }

    /// Admission check for a new sequence of `prompt_len` + `gen_len`
    /// with an unknown prompt (no prefix reuse assumed).
    pub fn can_admit(&self, total_len: usize) -> bool {
        let tp = self.alloc.cfg().tokens_per_page;
        self.available_pages() >= total_len.div_ceil(tp)
    }

    /// Prefix-aware admission: whether a request with this prompt and
    /// `total_len` = prompt + generation budget fits, counting only the
    /// *new* pages it needs after index reuse.  A burst of same-prefix
    /// requests therefore admits far more lanes than raw
    /// `pages_needed(total_len)` math would.
    pub fn can_admit_prompt(&self, prompt: &[i32], total_len: usize) -> bool {
        if self.index_kind == PrefixIndexKind::Radix {
            return self.can_admit_prompt_radix(prompt, total_len);
        }
        let tp = self.alloc.cfg().tokens_per_page;
        let pages_total = total_len.div_ceil(tp);
        let probe = self.probe_prefix(prompt);
        // adopted resident full pages need no allocation; an adopted
        // tail still costs its copy-on-write replacement, and a cold
        // (store-only) hit costs the page it promotes into, so neither
        // is subtracted — cold hits save prefill work, not pool pages.
        // A prompt that ends mid-page and will generate needs one page
        // beyond its counted tail slot unless the tail is resident:
        // the sealed tail (freshly encoded or promoted, either way
        // sequence-owned and non-evictable) is CoW-replaced by the
        // first generated token while still occupying its page
        let cow_extra = (self.prefix_sharing
            && prompt.len() % tp != 0
            && total_len > prompt.len()
            && !probe.warm_tail) as usize;
        let needed = pages_total.saturating_sub(probe.warm_full_hits) + cow_extra;
        // pages we are about to adopt are no longer evictable headroom
        let evictable = self.prefix.cached_len() - probe.cached_hits;
        self.alloc.free_count() + evictable >= needed
    }

    /// [`CacheManager::can_admit_prompt`] for the radix index: the same
    /// arithmetic over a radix adoption plan.  Whole resident full-page
    /// adoptions are free; a promotion or a slot-range copy consumes the
    /// page slot `pages_total` already counts for that position; the
    /// CoW surcharge applies only when the prompt's sealed tail will be
    /// copy-on-write replaced by the first generated token — which a
    /// copied (open) tail never is.
    fn can_admit_prompt_radix(&self, prompt: &[i32], total_len: usize) -> bool {
        let tp = self.alloc.cfg().tokens_per_page;
        let pages_total = total_len.div_ceil(tp);
        if !self.prefix_sharing || prompt.is_empty() {
            return self.can_admit(total_len);
        }
        let (keys, tail_key) = self.prompt_chain(prompt);
        let plan = self.plan_radix(prompt, &keys, tail_key);
        let mut warm_full = 0usize;
        let mut cached_hits = 0usize;
        // whether the decode-time CoW of the prompt's sealed tail needs
        // a page *beyond* the counted tail slot.  A miss or a promoted
        // tail consumes the counted slot for the encode/promotion and
        // pays the CoW on top; an *adopted* resident tail costs nothing
        // now (its later CoW is what the counted slot pays for — the
        // flat path's `warm_tail` case); an open copied final page
        // never CoWs at all
        let mut cow_needs_extra = prompt.len() % tp != 0;
        for step in &plan {
            match step {
                RadixStep::Adopt { page, start, end } => {
                    if self.alloc.refcount(*page) == 0 {
                        cached_hits += 1;
                    }
                    if end - start == tp {
                        warm_full += 1;
                    } else {
                        cow_needs_extra = false; // warm tail: slot covers the CoW
                    }
                }
                RadixStep::Promote { .. } => {
                    // consumes its counted slot; a promoted tail is
                    // sealed, so the default `cow_needs_extra` holds
                }
                RadixStep::Copy { srcs, start, .. } => {
                    for &(p, _, _) in srcs {
                        if self.alloc.refcount(p) == 0 {
                            cached_hits += 1;
                        }
                    }
                    if *start / tp == (prompt.len() - 1) / tp {
                        cow_needs_extra = false; // open copied final page: no CoW
                    }
                }
            }
        }
        let cow_extra =
            (prompt.len() % tp != 0 && total_len > prompt.len() && cow_needs_extra) as usize;
        let needed = pages_total.saturating_sub(warm_full) + cow_extra;
        let evictable = self.radix.cached_len().saturating_sub(cached_hits);
        self.alloc.free_count() + evictable >= needed
    }

    pub fn start_seq(&mut self, seq: SeqId) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already exists");
        }
        self.seqs.insert(seq, SeqCache::default());
        Ok(())
    }

    /// Start a sequence for a known prompt: walk the prefix index,
    /// adopt every sealed page whose chained content key matches a
    /// leading run of `prompt` (whole full pages, plus the sealed
    /// partial tail on a complete-prefix hit), and record the chain keys
    /// so this sequence's own prompt pages seal-and-publish as they
    /// fill.  Adopted tokens are already cached: prefill can skip them
    /// (the engine starts at `PrefixReuse::tokens`).
    ///
    /// With `prefix_sharing` off this is exactly [`CacheManager::start_seq`].
    pub fn start_seq_with_prompt(&mut self, seq: SeqId, prompt: &[i32]) -> Result<PrefixReuse> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already exists");
        }
        let mut sc = SeqCache::default();
        let mut reuse = PrefixReuse::default();
        if self.prefix_sharing && !prompt.is_empty() && self.index_kind == PrefixIndexKind::Radix
        {
            // radix index: token-granular adoption — whole sealed pages
            // by refcount where the tree covers a full page, cold pages
            // promoted from the store, and a partial match turned into
            // a slot-range copy (the sub-page dedup path)
            let (keys, tail) = self.prompt_chain(prompt);
            let adoption = self.adopt_radix(prompt, &keys, tail);
            reuse = PrefixReuse {
                pages: adoption.hit_pages,
                tokens: adoption.tokens,
            };
            sc.len = adoption.tokens;
            sc.pages = adoption.pages;
            sc.prompt = prompt.to_vec();
            sc.prompt_keys = keys;
            sc.tail_key = tail;
            sc.prompt_len = prompt.len();
            sc.tail_copied = adoption.tail_copied;
            self.share.prefix_hit_pages += reuse.pages as u64;
            self.share.prefix_hit_tokens += reuse.tokens as u64;
            // dedup credit: whole resident full pages, as in flat mode
            self.share.bytes_deduped +=
                (adoption.warm_full * self.alloc.cfg().page_bytes()) as u64;
        } else if self.prefix_sharing && !prompt.is_empty() {
            let tp = self.alloc.cfg().tokens_per_page;
            let (keys, tail) = self.prompt_chain(prompt);
            let probe = self.probe_prefix_with(prompt, &keys, tail);
            // pin every *resident* hit first: promotions below may
            // allocate (and therefore evict zero-ref pages), and a
            // parked page this walk is about to adopt must not be the
            // victim.  Reuse credit waits until the page is actually
            // kept — a failed walk must not inflate retention scores
            for hit in &probe.hits {
                if let Some(p) = hit.page {
                    self.prefix.unpark(p);
                    self.alloc.retain(p);
                }
            }
            // read ahead every cold hit of the chain in one store call:
            // a full-chain cold hit becomes a single sequential segment
            // scan instead of one seek per page (the mmap path resolves
            // per record either way).  Results come back in request
            // order; each is fully re-verified or `None`
            let mut cold_bytes = match &self.store {
                Some(store) => {
                    let requests: Vec<(PrefixKey, Option<PrefixKey>, &[i32])> = probe
                        .hits
                        .iter()
                        .filter(|h| h.page.is_none())
                        .map(|h| (h.key, h.parent, &prompt[h.start..h.end]))
                        .collect();
                    store.read_pages(&requests).into_iter()
                }
                None => Vec::new().into_iter(),
            };
            // adopt in chain order; a cold hit promotes its pre-read
            // bytes into a fresh page.  The first failure truncates
            // reuse there — later pinned pages are released back to the
            // warm tier
            let mut pages: Vec<PageId> = Vec::with_capacity(probe.hits.len());
            let mut tokens = 0usize;
            let mut warm_full_adopted = 0usize;
            let mut failed = false;
            for hit in &probe.hits {
                if failed {
                    if let Some(p) = hit.page {
                        self.release_page(p);
                    }
                    continue;
                }
                match hit.page {
                    Some(p) => {
                        self.prefix.credit_reuse(hit.key, p);
                        pages.push(p);
                        tokens = hit.end;
                        if hit.end - hit.start == tp {
                            warm_full_adopted += 1;
                        }
                    }
                    None => {
                        let run = &prompt[hit.start..hit.end];
                        let bytes = cold_bytes.next().flatten();
                        match self.promote_page(hit.key, hit.parent, run, hit.depth, bytes) {
                            Some(p) => {
                                pages.push(p);
                                tokens = hit.end;
                            }
                            None => failed = true,
                        }
                    }
                }
            }
            reuse = PrefixReuse {
                pages: pages.len(),
                tokens,
            };
            sc.pages = pages;
            sc.len = tokens;
            sc.prompt = prompt.to_vec();
            sc.prompt_keys = keys;
            sc.tail_key = tail;
            sc.prompt_len = prompt.len();
            self.share.prefix_hit_pages += reuse.pages as u64;
            self.share.prefix_hit_tokens += reuse.tokens as u64;
            // dedup credit counts whole *shared* resident pages only:
            // an adopted tail still costs its CoW replacement, and a
            // promotion costs a fresh page (same reasoning as the
            // admission math)
            self.share.bytes_deduped +=
                (warm_full_adopted * self.alloc.cfg().page_bytes()) as u64;
        }
        self.seqs.insert(seq, sc);
        Ok(reuse)
    }

    pub fn drop_seq(&mut self, seq: SeqId) {
        if let Some(s) = self.seqs.remove(&seq) {
            for p in s.pages {
                self.release_page(p);
            }
        }
    }

    // ------------------------------------------------------------------
    // prefix-sharing internals
    // ------------------------------------------------------------------

    /// Chain keys over a prompt: one key per full page of tokens, plus
    /// the partial-tail key when the prompt ends mid-page.
    fn prompt_chain(&self, prompt: &[i32]) -> (Vec<PrefixKey>, Option<PrefixKey>) {
        let tp = self.alloc.cfg().tokens_per_page;
        let n_full = prompt.len() / tp;
        let mut keys = Vec::with_capacity(n_full);
        let mut parent = None;
        for i in 0..n_full {
            let k = chain_key(parent, &prompt[i * tp..(i + 1) * tp], self.fingerprint);
            keys.push(k);
            parent = Some(k);
        }
        let rem = prompt.len() % tp;
        let tail =
            (rem > 0).then(|| chain_key(parent, &prompt[n_full * tp..], self.fingerprint));
        (keys, tail)
    }

    /// [`CacheManager::probe_prefix_with`] computing the chain itself
    /// (admission-check path; `start_seq_with_prompt` reuses its own
    /// chain to avoid hashing the prompt twice).
    fn probe_prefix(&self, prompt: &[i32]) -> PrefixProbe {
        if !self.prefix_sharing || prompt.is_empty() {
            return PrefixProbe::default();
        }
        let (keys, tail) = self.prompt_chain(prompt);
        self.probe_prefix_with(prompt, &keys, tail)
    }

    /// Read-only walk over the prefix index *and* (when attached) the
    /// persistent store: which leading pages of `prompt` are adoptable
    /// right now, and from which tier.  Stops at the first total miss;
    /// the partial tail only counts when every full page hit (pages
    /// adopt in prefix order or not at all).  Every lookup — RAM or
    /// disk — is token-verified: a key collision reads as a miss,
    /// never as another prompt's pages.
    fn probe_prefix_with(
        &self,
        prompt: &[i32],
        keys: &[PrefixKey],
        tail: Option<PrefixKey>,
    ) -> PrefixProbe {
        let mut probe = PrefixProbe::default();
        if !self.prefix_sharing || prompt.is_empty() {
            return probe;
        }
        let tp = self.alloc.cfg().tokens_per_page;
        for (i, &key) in keys.iter().enumerate() {
            let parent = if i > 0 { Some(keys[i - 1]) } else { None };
            let run = &prompt[i * tp..(i + 1) * tp];
            let Some(hit) = self.probe_one(key, parent, run, i * tp, (i + 1) * tp, i as u32)
            else {
                return probe;
            };
            match hit.page {
                Some(p) => {
                    if self.alloc.refcount(p) == 0 {
                        probe.cached_hits += 1;
                    }
                    probe.warm_full_hits += 1;
                }
                None => {}
            }
            probe.hits.push(hit);
        }
        if let Some(key) = tail {
            let parent = keys.last().copied();
            let start = keys.len() * tp;
            if let Some(hit) =
                self.probe_one(key, parent, &prompt[start..], start, prompt.len(), keys.len() as u32)
            {
                match hit.page {
                    Some(p) => {
                        if self.alloc.refcount(p) == 0 {
                            probe.cached_hits += 1;
                        }
                        probe.warm_tail = true;
                    }
                    None => {}
                }
                probe.hits.push(hit);
            }
        }
        probe
    }

    /// Resolve one chain link: resident index first (warm/hot), then
    /// the persistent store (cold).  `None` = total miss.
    fn probe_one(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        run: &[i32],
        start: usize,
        end: usize,
        depth: u32,
    ) -> Option<ProbeHit> {
        if let Some(p) = self.prefix.lookup(key, parent, run) {
            debug_assert!(self.alloc.page(p).is_sealed());
            return Some(ProbeHit {
                key,
                parent,
                page: Some(p),
                start,
                end,
                depth,
            });
        }
        let cold = self
            .store
            .as_ref()
            .is_some_and(|s| s.lookup_meta(key, parent, run));
        cold.then_some(ProbeHit {
            key,
            parent,
            page: None,
            start,
            end,
            depth,
        })
    }

    /// Promote one cold page from its pre-read (and already fully
    /// re-verified) store bytes: allocate a fresh page (evicting warm
    /// pages if the pool demands it), install the bytes sealed under
    /// `key`, and publish it back to the resident index.  Any failure —
    /// a `None` read, size mismatch, pool exhaustion — returns `None`:
    /// a miss, so the caller re-encodes instead of ever adopting wrong
    /// bytes.
    fn promote_page(
        &mut self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        run: &[i32],
        depth: u32,
        bytes: Option<Vec<u8>>,
    ) -> Option<PageId> {
        let bytes = bytes?;
        if bytes.len() != self.alloc.cfg().page_bytes() {
            return None;
        }
        let p = self.alloc_page().ok()?;
        self.alloc.page_mut(p).data.copy_from_slice(&bytes);
        self.alloc.page_mut(p).seal(Some(key));
        let published = self.prefix.publish(key, p, parent, run, depth);
        debug_assert!(published, "promoted a key that was already resident");
        self.share.pages_promoted += 1;
        Some(p)
    }

    // ------------------------------------------------------------------
    // radix-index internals ([`PrefixIndexKind::Radix`])
    // ------------------------------------------------------------------

    /// Walk the radix tree (and, beyond its coverage, the persistent
    /// store) over `prompt` and decide, per page position, how that
    /// page's tokens are served.  Read-only: shared by the admission
    /// check and the adoption path.  The plan is in page-position
    /// order; a *partial* [`RadixStep::Copy`] or a missing position
    /// ends the plan (coverage past the first unmatched token is
    /// unknowable; pages adopt in prefix order or not at all), while a
    /// fully-covered span — adopted whole or assembled from several
    /// source pages — lets the walk continue.
    fn plan_radix(
        &self,
        prompt: &[i32],
        keys: &[PrefixKey],
        tail_key: Option<PrefixKey>,
    ) -> Vec<RadixStep> {
        let tp = self.alloc.cfg().tokens_per_page;
        let plen = prompt.len();
        let (segs, matched) = self.radix.match_prefix(prompt);
        let mut steps = Vec::new();
        for pi in 0..plen.div_ceil(tp) {
            let s = pi * tp;
            let e = (s + tp).min(plen);
            let covered = matched.min(e).saturating_sub(s);
            // resident pieces covering [s, s + covered), coalesced so
            // adjacent segments of one page become one copy/pin unit
            let mut pieces: Vec<(PageId, usize, usize)> = Vec::new();
            for seg in &segs {
                let ss = seg.start.max(s);
                let se = (seg.start + seg.len).min(s + covered);
                if ss >= se {
                    continue;
                }
                let slot0 = seg.slot0 + (ss - seg.start);
                match pieces.last_mut() {
                    Some((p, ps, pn)) if *p == seg.page && *ps + *pn == slot0 => {
                        *pn += se - ss;
                    }
                    _ => pieces.push((seg.page, slot0, se - ss)),
                }
            }
            if covered == e - s && pieces.len() == 1 {
                // the whole span is resident on one sealed page: adopt
                // it by refcount — including a *partial tail* span,
                // which the flat index can only match on an exact
                // whole-run key (gathers read only the leading slots)
                steps.push(RadixStep::Adopt {
                    page: pieces[0].0,
                    start: s,
                    end: e,
                });
                continue;
            }
            // not fully resident on one page: the store may hold the
            // whole page-aligned run (full pages under their chain
            // keys, the partial tail under its tail key)
            let store_key = if e - s == tp { keys.get(pi).copied() } else { tail_key };
            let parent = if pi > 0 { keys.get(pi - 1).copied() } else { None };
            if let Some(k) = store_key {
                let cold = self
                    .store
                    .as_ref()
                    .is_some_and(|st| st.lookup_meta(k, parent, &prompt[s..e]));
                if cold {
                    steps.push(RadixStep::Promote {
                        key: k,
                        parent,
                        start: s,
                        end: e,
                    });
                    continue;
                }
            }
            if covered == e - s && !pieces.is_empty() {
                // the whole span is resident but split across source
                // pages (an earlier divergence left the shared head on
                // one page and the suffix on another): assemble a full
                // copy and keep walking — later positions are still
                // matched and adoptable
                steps.push(RadixStep::Copy {
                    srcs: pieces,
                    start: s,
                    end: e,
                });
                continue;
            }
            if covered > 0 {
                // sub-page partial coverage: copy the covered slots
                // into a fresh open page; prefill resumes at token
                // `s + covered`, re-encoding only the divergent suffix
                steps.push(RadixStep::Copy {
                    srcs: pieces,
                    start: s,
                    end: s + covered,
                });
            }
            break;
        }
        steps
    }

    /// Execute a radix adoption plan for a new sequence.  Mirrors the
    /// flat walk's discipline: every *resident* page the plan touches
    /// (whole adoptions and copy sources) is pinned first, so the
    /// allocations promotions and copies make cannot evict a page the
    /// same walk is about to use; reuse credit lands only on executed
    /// steps; the first failure truncates reuse there and releases the
    /// remaining pins back to the warm tier.
    fn adopt_radix(
        &mut self,
        prompt: &[i32],
        keys: &[PrefixKey],
        tail_key: Option<PrefixKey>,
    ) -> RadixAdoption {
        let tp = self.alloc.cfg().tokens_per_page;
        let steps = self.plan_radix(prompt, keys, tail_key);
        for step in &steps {
            match step {
                RadixStep::Adopt { page, .. } => {
                    self.radix.unpark(*page);
                    self.alloc.retain(*page);
                }
                RadixStep::Copy { srcs, .. } => {
                    for &(p, _, _) in srcs {
                        self.radix.unpark(p);
                        self.alloc.retain(p);
                    }
                }
                RadixStep::Promote { .. } => {}
            }
        }
        let mut out = RadixAdoption::default();
        let mut failed = false;
        for step in &steps {
            if failed {
                match step {
                    RadixStep::Adopt { page, .. } => self.release_page(*page),
                    RadixStep::Copy { srcs, .. } => {
                        for &(p, _, _) in srcs {
                            self.release_page(p);
                        }
                    }
                    RadixStep::Promote { .. } => {}
                }
                continue;
            }
            match step {
                RadixStep::Adopt { page, start, end } => {
                    debug_assert!(self.alloc.page(*page).is_sealed());
                    self.radix.credit_page(*page);
                    out.pages.push(*page);
                    out.tokens = *end;
                    out.hit_pages += 1;
                    if end - start == tp {
                        out.warm_full += 1;
                    }
                }
                RadixStep::Promote { key, parent, start, end } => {
                    match self.promote_radix(*key, *parent, prompt, *start, *end) {
                        Some(p) => {
                            out.pages.push(p);
                            out.tokens = *end;
                            out.hit_pages += 1;
                        }
                        None => failed = true,
                    }
                }
                RadixStep::Copy { srcs, start, end } => match self.alloc_page() {
                    Ok(dst) => {
                        for &(src, slot0, n) in srcs {
                            self.alloc.copy_slots(src, dst, slot0, n);
                            self.radix.credit_page(src);
                            self.share.slots_copied += n as u64;
                            self.release_page(src);
                        }
                        out.pages.push(dst);
                        out.tokens = *end;
                        // a *partial* copy page stays open; only when
                        // it is the prompt's final page does it
                        // suppress the seal-and-publish (and therefore
                        // the CoW) the flat tail lifecycle would impose
                        if start / tp == (prompt.len() - 1) / tp {
                            out.tail_copied = true;
                        }
                        if end - start == tp {
                            // assembled-page reuse: the copy covers its
                            // whole span, byte-complete — seal it and
                            // re-point the tree's fragmented coverage
                            // of the span at it, so the next exact
                            // repeat adopts one page by refcount
                            // instead of re-running copy_slots.  Source
                            // pages left with no sub-refs come back
                            // stranded (they were parked, zero-ref) and
                            // recycle to the free pool
                            self.alloc
                                .page_mut(dst)
                                .seal(keys.get(start / tp).copied());
                            for p in self.radix.repoint_span(&prompt[..*end], *start, dst)
                            {
                                self.alloc.free(p);
                                self.share.pages_evicted += 1;
                            }
                        }
                        self.share.tail_copies += 1;
                    }
                    Err(_) => {
                        failed = true;
                        for &(p, _, _) in srcs {
                            self.release_page(p);
                        }
                    }
                },
            }
        }
        out
    }

    /// Promote one cold page under the radix index: read + fully
    /// re-verify the record, install the bytes into a fresh sealed
    /// page, and publish its run back into the tree.  Any failure is a
    /// miss — the caller re-encodes, never adopts wrong bytes.
    fn promote_radix(
        &mut self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        prompt: &[i32],
        start: usize,
        end: usize,
    ) -> Option<PageId> {
        let run = &prompt[start..end];
        let (bytes, start_slot) = {
            let store = self.store.as_ref()?;
            let slot = store.lookup_start_slot(key, parent, run).unwrap_or(0);
            (store.read_page(key, parent, run)?, slot)
        };
        if bytes.len() != self.alloc.cfg().page_bytes() {
            return None;
        }
        let p = self.alloc_page().ok()?;
        self.alloc.page_mut(p).data.copy_from_slice(&bytes);
        self.alloc.page_mut(p).seal(Some(key));
        // losing the publish race to an existing covering run just
        // leaves this page as a private resident copy of the sequence
        let _ = self.radix.insert(&prompt[..end], start, p);
        self.share.pages_promoted += 1;
        // a record whose original node run began mid-page (a persisted
        // split point, padded to the page boundary at spill time)
        // recovered coverage the v1 spill path used to throw away
        if start_slot > 0 {
            self.share.subrun_promotions += 1;
        }
        Some(p)
    }

    /// Write-behind persistence of a parking page under the radix
    /// index.  The record's *edge* (parent key + covered token run) is
    /// derived from the page's tree path, so it is addressable by
    /// exactly the chain keys [`CacheManager::plan_radix`]'s store
    /// fallback computes — flat- and radix-written stores are
    /// interchangeable.
    ///
    /// A run that begins mid-page (a node published at a radix split
    /// point) lives on a *physically complete* page: its leading slots
    /// were slot-copied from verified source pages before the divergent
    /// suffix was appended, so the record pads the run leftward to the
    /// page boundary with the tree path's trailing prefix tokens.  The
    /// padded record stays addressable by the standard page-aligned
    /// chain keys — a warm boot recovers coverage the v1 spill path
    /// threw away — and the original split slot rides the v2 record
    /// extension as provenance (`ShareStats::subrun_promotions` counts
    /// its adoptions).
    fn spill_page_radix(&mut self, page: PageId) {
        let tp = self.alloc.cfg().tokens_per_page;
        let enqueued = {
            let Some(store) = self.store.as_ref() else { return };
            let Some((start, run, prefix)) = self.radix.page_run(page) else {
                return;
            };
            debug_assert_eq!(prefix.len(), start);
            let start_slot = (start % tp) as u32;
            let page_start = start - start % tp;
            let mut full_run = prefix[page_start..].to_vec();
            full_run.extend_from_slice(&run);
            let mut parent = None;
            for chunk in prefix[..page_start].chunks(tp) {
                parent = Some(chain_key(parent, chunk, self.fingerprint));
            }
            let key = chain_key(parent, &full_run, self.fingerprint);
            let score = self.radix.page_score(page).min(u32::MAX as u64) as u32;
            store.spill(
                key,
                parent,
                &full_run,
                &self.alloc.page(page).data,
                start_slot,
                score,
            )
        };
        if enqueued {
            self.share.pages_spilled += 1;
        }
        self.note_store_health();
    }

    /// Drop one ownership of `p`.  At zero refs an indexed page is
    /// parked in the zero-ref prefix cache (still resident, adoptable,
    /// evictable) and — when a persistent store is attached — handed to
    /// the write-behind spill thread, so a later eviction demotes it to
    /// the cold tier instead of destroying it.  Anything else returns
    /// to the free pool.
    fn release_page(&mut self, p: PageId) {
        if self.alloc.release(p) == 0 {
            match self.index_kind {
                PrefixIndexKind::Flat => {
                    let key = self.alloc.page(p).key();
                    match key {
                        Some(k) if self.prefix.is_indexed(k, p) => {
                            self.spill_page(k, p);
                            self.prefix.cache_zero_ref(p, k);
                        }
                        _ => self.alloc.free(p),
                    }
                }
                PrefixIndexKind::Radix => {
                    if self.radix.is_referenced(p) {
                        self.spill_page_radix(p);
                        self.radix.park(p);
                    } else {
                        self.alloc.free(p);
                    }
                }
            }
        }
    }

    /// Write-behind persistence of a parking page.  The store dedups
    /// (a key already durable or already queued is skipped), and the
    /// job owns a copy of the bytes, so eviction never has to wait for
    /// the disk.
    fn spill_page(&mut self, key: PrefixKey, page: PageId) {
        let enqueued = {
            let Some(store) = self.store.as_ref() else { return };
            let Some((_, parent, tokens, _)) = self.prefix.entry_meta(key) else {
                return;
            };
            // flat runs are always page-aligned; the retention score
            // rides along so the compactor can rank this record
            let score = self
                .prefix
                .score_of(key)
                .map(|s| s.min(u32::MAX as u64) as u32)
                .unwrap_or(0);
            store.spill(key, parent, tokens, &self.alloc.page(page).data, 0, score)
        };
        if enqueued {
            self.share.pages_spilled += 1;
        }
        self.note_store_health();
    }

    /// Allocate a page, demoting zero-ref prefix-cache entries (lowest
    /// reuse/depth retention score first — see
    /// [`PrefixIndex::evict_victim`] and [`RadixIndex::evict_victim`])
    /// under pool pressure.  Radix eviction is hierarchical and may
    /// cascade: dropping an interior run frees any parked pages its
    /// subtree stranded, all of which recycle here.  With a store
    /// attached the victims were spilled when they parked, so this
    /// recycles only the RAM copies.
    fn alloc_page(&mut self) -> Result<PageId> {
        loop {
            match self.alloc.alloc() {
                Ok(p) => return Ok(p),
                Err(e) => {
                    let freed = match self.index_kind {
                        PrefixIndexKind::Flat => {
                            self.prefix.evict_victim().map_or_else(Vec::new, |v| vec![v])
                        }
                        PrefixIndexKind::Radix => self.radix.evict_victim(),
                    };
                    if freed.is_empty() {
                        return Err(e);
                    }
                    for v in freed {
                        self.alloc.free(v);
                        self.share.pages_evicted += 1;
                    }
                }
            }
        }
    }

    /// Seal (and, for prompt pages, publish) every page whose content
    /// became final during an append that grew the sequence from
    /// `start_len` to its current length: pages that filled completely,
    /// plus the partial tail the moment the prompt completes mid-page.
    fn seal_after_append(&mut self, seq: SeqId, start_len: usize) {
        let tp = self.alloc.cfg().tokens_per_page;
        let (len, prompt_len) = {
            let s = self.seqs.get(&seq).unwrap();
            (s.len, s.prompt_len)
        };
        for pi in start_len / tp..len / tp {
            let (page_id, key, parent, run) = {
                let s = self.seqs.get(&seq).unwrap();
                let key = if self.prefix_sharing && (pi + 1) * tp <= prompt_len {
                    s.prompt_keys.get(pi).copied()
                } else {
                    None
                };
                let parent = if pi > 0 {
                    s.prompt_keys.get(pi - 1).copied()
                } else {
                    None
                };
                let run = key.map(|_| s.prompt[pi * tp..(pi + 1) * tp].to_vec());
                (s.pages[pi], key, parent, run)
            };
            if self.alloc.page(page_id).is_sealed() {
                continue; // adopted pages arrive sealed
            }
            self.alloc.page_mut(page_id).seal(key);
            if let (Some(k), Some(run)) = (key, run) {
                let published = match self.index_kind {
                    PrefixIndexKind::Flat => {
                        self.prefix.publish(k, page_id, parent, &run, pi as u32)
                    }
                    PrefixIndexKind::Radix => {
                        // publish the run under its token path; a page
                        // whose leading slots were slot-copied inserts
                        // only its divergent suffix (the copied part
                        // already resolves to the source page)
                        let prefix_run = {
                            let s = self.seqs.get(&seq).unwrap();
                            s.prompt[..(pi + 1) * tp].to_vec()
                        };
                        self.radix.insert(&prefix_run, pi * tp, page_id)
                    }
                };
                if published {
                    self.share.pages_published += 1;
                }
            }
        }
        if self.prefix_sharing && prompt_len > 0 && len == prompt_len && len % tp != 0 {
            let (page_id, tail_key, parent, run, tail_copied) = {
                let s = self.seqs.get(&seq).unwrap();
                (
                    *s.pages.last().unwrap(),
                    s.tail_key,
                    s.prompt_keys.last().copied(),
                    s.prompt[(prompt_len / tp) * tp..].to_vec(),
                    s.tail_copied,
                )
            };
            // a radix slot-copied tail stays *open*: decode appends
            // write in place, so there is no seal, no publish, and no
            // copy-on-write page per divergent-tail sequence — the
            // shared part of the run is already indexed on its source
            // page, which is where followers copy from
            let skip_seal = self.index_kind == PrefixIndexKind::Radix && tail_copied;
            if let Some(k) = tail_key {
                if !self.alloc.page(page_id).is_sealed() && !skip_seal {
                    self.alloc.page_mut(page_id).seal(Some(k));
                    let depth = (prompt_len / tp) as u32;
                    let published = match self.index_kind {
                        PrefixIndexKind::Flat => {
                            self.prefix.publish(k, page_id, parent, &run, depth)
                        }
                        PrefixIndexKind::Radix => {
                            let prefix_run = {
                                let s = self.seqs.get(&seq).unwrap();
                                s.prompt.clone()
                            };
                            self.radix.insert(&prefix_run, (prompt_len / tp) * tp, page_id)
                        }
                    };
                    if published {
                        self.share.pages_published += 1;
                    }
                }
            }
        }
    }

    /// Append one token's K/V: `k_t`/`v_t` are laid out `[layer][head][dh]`
    /// (the `k_new`/`v_new` outputs of the decode artifact for one batch
    /// lane).  A run of length 1 — see [`CacheManager::append_run`].
    pub fn append_token(&mut self, seq: SeqId, k_t: &[f32], v_t: &[f32]) -> Result<()> {
        self.append_run(seq, k_t, v_t, 1)
    }

    /// Append a run of `n_tokens` tokens' K/V in one batched encode per
    /// side: `k_run`/`v_run` are token-major `[t][layer][head][dh]`.
    /// Each side is a *single* `encode_batch` call over `n_tokens × L ×
    /// H` vectors into the persistent sink (so the SIMD tile kernels
    /// see the whole run), and the resulting records are fanned out to
    /// page slots in ascending slot order.  This is the batched prefill
    /// append: `Engine::step_prefill` stages a whole chunk per lane and
    /// appends it here instead of looping `append_token`.
    ///
    /// Pages are reserved up front, so failure (pool exhaustion or an
    /// unknown sequence) leaves the sequence unchanged.  If the
    /// sequence's tail page is sealed (an adopted shared prompt tail,
    /// or its own published one), it is copy-on-write replaced before
    /// any slot is written — sealed pages are immutable.
    pub fn append_run(
        &mut self,
        seq: SeqId,
        k_run: &[f32],
        v_run: &[f32],
        n_tokens: usize,
    ) -> Result<()> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = n_tokens * l * h * dh;
        if k_run.len() != expect || v_run.len() != expect {
            bail!(
                "append_run: expected {}x{}x{}x{} floats, got k={} v={}",
                n_tokens, l, h, dh, k_run.len(), v_run.len()
            );
        }
        if n_tokens == 0 {
            self.seqs.get(&seq).context("unknown sequence")?;
            return Ok(());
        }
        let tp = cfg.tokens_per_page;
        // reserve every page the run needs before touching anything
        let (start_len, have_pages) = {
            let s = self.seqs.get(&seq).context("unknown sequence")?;
            (s.len, s.pages.len())
        };
        // a partially-filled sealed tail must be CoW-copied before this
        // run appends into it (costs one extra fresh page)
        let cow_src = if start_len % tp != 0 {
            let last = *self.seqs.get(&seq).unwrap().pages.last().unwrap();
            debug_assert!(
                self.alloc.page(last).is_sealed() || self.alloc.refcount(last) == 1,
                "open tail must be exclusively owned"
            );
            self.alloc.page(last).is_sealed().then_some(last)
        } else {
            None
        };
        let need = (start_len + n_tokens).div_ceil(tp).saturating_sub(have_pages)
            + cow_src.is_some() as usize;
        let mut fresh: Vec<PageId> = Vec::with_capacity(need);
        for _ in 0..need {
            match self.alloc_page() {
                Ok(p) => fresh.push(p),
                Err(e) => {
                    for p in fresh {
                        let remaining = self.alloc.release(p);
                        debug_assert_eq!(remaining, 0, "fresh page had extra owners");
                        self.alloc.free(p);
                    }
                    return Err(e);
                }
            }
        }
        if let Some(old) = cow_src {
            let dst = fresh.pop().unwrap();
            self.alloc.copy_page(old, dst);
            *self.seqs.get_mut(&seq).unwrap().pages.last_mut().unwrap() = dst;
            self.release_page(old);
            self.share.cow_copies += 1;
        }
        self.seqs.get_mut(&seq).unwrap().pages.extend(fresh);

        for (is_v, src) in [(false, k_run), (true, v_run)] {
            self.stage1.encode_batch(src, n_tokens * l * h, &mut self.sink);
            // record (t, layer, head) is sink index (t·L + layer)·H + head
            // — walking tokens then layers then heads writes page slots
            // in ascending offset order
            for t in 0..n_tokens {
                let tok = start_len + t;
                let page_id = self.seqs.get(&seq).unwrap().pages[tok / tp];
                let slot = tok % tp;
                let page = self.alloc.page_mut(page_id);
                for layer in 0..l {
                    for head in 0..h {
                        page.slot_mut(&cfg, slot, layer, head, is_v)
                            .copy_from_slice(self.sink.encoded((t * l + layer) * h + head));
                    }
                }
            }
        }
        let s = self.seqs.get_mut(&seq).unwrap();
        s.len += n_tokens;
        if self.keep_shadow {
            s.shadow_k.extend_from_slice(k_run);
            s.shadow_v.extend_from_slice(v_run);
        }
        self.seal_after_append(seq, start_len);
        Ok(())
    }

    /// Reconstruct this sequence's cache into caller buffers shaped
    /// `[layer][head][t_max][dh]` (padded with zeros beyond `len`).
    /// This is the decode-side hot loop; `ws` persists decode scratch
    /// across calls.
    pub fn gather_ws(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_out.len() != l * h * t_max * dh || v_out.len() != l * h * t_max * dh {
            bail!("gather: output buffer shape mismatch");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = self.gather_strips(s, t_max, k_out, v_out, ws, |layer, head| {
            (layer * h + head) * t_max * dh
        });
        Ok(n)
    }

    /// [`CacheManager::gather_ws`] with IEEE binary16 output: each
    /// element is `f32_to_f16_bits` of what the f32 gather writes.
    pub fn gather_ws_f16(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [u16],
        v_out: &mut [u16],
        ws: &mut GatherWorkspace,
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_out.len() != l * h * t_max * dh || v_out.len() != l * h * t_max * dh {
            bail!("gather: output buffer shape mismatch");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = self.gather_strips(s, t_max, k_out, v_out, ws, |layer, head| {
            (layer * h + head) * t_max * dh
        });
        Ok(n)
    }

    /// [`CacheManager::gather_ws`] with a throwaway workspace (tests and
    /// one-off callers; the engine holds a persistent workspace).
    pub fn gather(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        self.gather_ws(seq, t_max, k_out, v_out, &mut GatherWorkspace::new())
    }

    /// Reconstruct directly into a batched `(L, B, H, T, dh)` buffer at
    /// batch lane `lane` — the layout the decode artifact consumes.
    /// Avoids an intermediate per-sequence copy on the serving hot path.
    pub fn gather_into_batch_ws(
        &self,
        seq: SeqId,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = l * batch * h * t_max * dh;
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_into_batch: buffer shape mismatch");
        }
        if lane >= batch {
            bail!("gather_into_batch: lane {lane} >= batch {batch}");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = self.gather_strips(s, t_max, k_out, v_out, ws, |layer, head| {
            (((layer * batch) + lane) * h + head) * t_max * dh
        });
        Ok(n)
    }

    /// [`CacheManager::gather_into_batch_ws`] with a throwaway workspace.
    pub fn gather_into_batch(
        &self,
        seq: SeqId,
        lane: usize,
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        self.gather_into_batch_ws(
            seq,
            lane,
            batch,
            t_max,
            k_out,
            v_out,
            &mut GatherWorkspace::new(),
        )
    }

    /// Reconstruct the caches of several sequences into disjoint lanes
    /// of one batched `(L, B, H, T, dh)` buffer pair in a *single*
    /// strip-parallel drain: the `(layer, head)` strip units of every
    /// listed lane feed one `scope_units` queue, so a fast lane's
    /// threads help finish a slow lane instead of idling at per-lane
    /// barriers (ROADMAP cross-lane item).  `lanes` pairs each sequence
    /// with its batch lane and must be strictly ascending by lane.
    /// Returns the reconstructed token count per listed lane.
    pub fn gather_lanes_into_batch_ws(
        &self,
        lanes: &[(SeqId, usize)],
        batch: usize,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        ws: &mut GatherWorkspace,
    ) -> Result<Vec<usize>> {
        self.gather_lanes_core(lanes, batch, t_max, k_out, v_out, ws)
    }

    /// [`CacheManager::gather_lanes_into_batch_ws`] with IEEE binary16
    /// output: each element is `f32_to_f16_bits` of the f32 gather's —
    /// half the write bandwidth for artifacts that consume FP16 KV.
    pub fn gather_lanes_into_batch_f16_ws(
        &self,
        lanes: &[(SeqId, usize)],
        batch: usize,
        t_max: usize,
        k_out: &mut [u16],
        v_out: &mut [u16],
        ws: &mut GatherWorkspace,
    ) -> Result<Vec<usize>> {
        self.gather_lanes_core(lanes, batch, t_max, k_out, v_out, ws)
    }

    fn gather_lanes_core<T: GatherElem>(
        &self,
        lanes: &[(SeqId, usize)],
        batch: usize,
        t_max: usize,
        k_out: &mut [T],
        v_out: &mut [T],
        ws: &mut GatherWorkspace,
    ) -> Result<Vec<usize>> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let expect = l * batch * h * t_max * dh;
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_lanes: buffer shape mismatch");
        }
        let mut seqs = Vec::with_capacity(lanes.len());
        let mut prev: Option<usize> = None;
        for &(seq, lane) in lanes {
            if lane >= batch {
                bail!("gather_lanes: lane {lane} >= batch {batch}");
            }
            if prev.is_some_and(|p| lane <= p) {
                bail!("gather_lanes: lanes must be strictly ascending");
            }
            prev = Some(lane);
            seqs.push(self.seqs.get(&seq).context("unknown sequence")?);
        }
        // iterate layer-major, then lane, then head: strip bases ascend
        // strictly, which carve_strips requires
        let mut jobs = Vec::with_capacity(l * lanes.len() * h);
        for layer in 0..l {
            for (i, &(_, lane)) in lanes.iter().enumerate() {
                for head in 0..h {
                    let base = (((layer * batch) + lane) * h + head) * t_max * dh;
                    jobs.push((seqs[i], layer, head, base));
                }
            }
        }
        self.gather_strips_multi(jobs, t_max, k_out, v_out, ws);
        Ok(seqs.iter().map(|s| s.len.min(t_max)).collect())
    }

    /// The single-sequence strip gather: build this sequence's
    /// `n_layers × n_heads` strip jobs located by `strip_base` and run
    /// them through the shared drain.
    fn gather_strips<T: GatherElem>(
        &self,
        s: &SeqCache,
        t_max: usize,
        k_out: &mut [T],
        v_out: &mut [T],
        ws: &mut GatherWorkspace,
        strip_base: impl Fn(usize, usize) -> usize,
    ) -> usize {
        let cfg = *self.alloc.cfg();
        let (l, h) = (cfg.n_layers, cfg.n_heads);
        let mut jobs = Vec::with_capacity(l * h);
        for layer in 0..l {
            for head in 0..h {
                jobs.push((s, layer, head, strip_base(layer, head)));
            }
        }
        self.gather_strips_multi(jobs, t_max, k_out, v_out, ws);
        s.len.min(t_max)
    }

    /// The shared batched gather core: carve `k_out`/`v_out` into the
    /// disjoint strips located by the (strictly ascending) job bases,
    /// zero each strip, then decode it page-run by page-run with
    /// strided batch decodes — in parallel across all jobs when the
    /// policy allows.  Jobs may reference different sequences (the
    /// cross-lane drain); when they do and [`CacheManager::gather_dedup`]
    /// is on, identical `(layer, head, page, slot-run)` strips across
    /// lanes decode once and fan out by copy.
    fn gather_strips_multi<T: GatherElem>(
        &self,
        jobs: Vec<(&SeqCache, usize, usize, usize)>,
        t_max: usize,
        k_out: &mut [T],
        v_out: &mut [T],
        ws: &mut GatherWorkspace,
    ) {
        let cfg = *self.alloc.cfg();
        let dh = cfg.d_head;
        let tp = cfg.tokens_per_page;
        let slot_bytes = cfg.slot_bytes();
        let strip_len = t_max * dh;
        ws.scratch.resize_with(jobs.len(), BatchScratch::new);
        ws.bases.clear();
        ws.bases.extend(jobs.iter().map(|&(_, _, _, base)| base));

        // Cross-lane dedup plan, built single-threaded before the drain:
        // lanes sharing prefix pages gather the same page runs into the
        // same strip offsets, so the first job touching a given
        // `(layer, head, page, t, run)` becomes the leader and every
        // later one skips the decode and copies the leader's rows
        // afterwards.  The decoded bytes are identical by construction
        // (same encoded column, same kernel), so the fan-out is
        // invisible to callers except through the dedup counters.
        let mut skips: Vec<Vec<usize>> = vec![Vec::new(); jobs.len()];
        let mut copies: Vec<(usize, usize, usize, usize)> = Vec::new();
        let distinct_seqs = {
            let mut ptrs: Vec<*const SeqCache> =
                jobs.iter().map(|&(s, _, _, _)| s as *const SeqCache).collect();
            ptrs.sort_unstable();
            ptrs.dedup();
            ptrs.len()
        };
        if self.gather_dedup && distinct_seqs > 1 {
            use std::collections::hash_map::Entry;
            use std::sync::atomic::Ordering;
            let mut leaders: HashMap<(usize, usize, PageId, usize, usize), usize> =
                HashMap::new();
            for (j, &(s, layer, head, _)) in jobs.iter().enumerate() {
                let n = s.len.min(t_max);
                let mut t = 0usize;
                while t < n {
                    let run = tp.min(n - t);
                    match leaders.entry((layer, head, s.pages[t / tp], t, run)) {
                        Entry::Occupied(e) => {
                            skips[j].push(t);
                            copies.push((*e.get(), j, t, run));
                            self.share.strips_deduped.fetch_add(1, Ordering::Relaxed);
                            self.share.bytes_saved.fetch_add(
                                (2 * run * dh * std::mem::size_of::<T>()) as u64,
                                Ordering::Relaxed,
                            );
                        }
                        Entry::Vacant(e) => {
                            e.insert(j);
                        }
                    }
                    t += run;
                }
            }
        }

        let total_vecs: usize =
            jobs.iter().map(|&(s, _, _, _)| s.len.min(t_max)).sum::<usize>() * 2;
        let k_strips = carve_strips(k_out, &ws.bases, strip_len);
        let v_strips = carve_strips(v_out, &ws.bases, strip_len);
        type Unit<'u, T> =
            (&'u SeqCache, usize, usize, &'u mut [T], &'u mut [T], &'u mut BatchScratch, &'u [usize]);
        let units: Vec<Unit<'_, T>> = jobs
            .into_iter()
            .zip(k_strips.into_iter().zip(v_strips))
            .zip(ws.scratch.iter_mut())
            .zip(skips.iter())
            .map(|((((s, layer, head, _), (ks, vs)), sc), skip)| {
                (s, layer, head, ks, vs, sc, skip.as_slice())
            })
            .collect();

        // scoped threads rather than the long-lived ThreadPool: the units
        // borrow the caller's output buffers, which `ThreadPool`'s
        // 'static jobs cannot; the spawn cost is gated on work size
        let threads = if total_vecs < MIN_PARALLEL_VECTORS {
            1
        } else {
            self.parallel.threads(units.len())
        };
        scope_units(units, threads, |(s, layer, head, k_strip, v_strip, scratch, skip)| {
            let n = s.len.min(t_max);
            k_strip.fill(T::ZERO);
            v_strip.fill(T::ZERO);
            let mut skip_at = 0usize;
            let mut t = 0usize;
            while t < n {
                let run = tp.min(n - t);
                if skip_at < skip.len() && skip[skip_at] == t {
                    // a leader strip decodes this run; copied in below
                    skip_at += 1;
                    t += run;
                    continue;
                }
                let page = self.alloc.page(s.pages[t / tp]);
                let (k_col, stride) = page.column(&cfg, layer, head, false);
                let (v_col, _) = page.column(&cfg, layer, head, true);
                debug_assert_eq!(stride, slot_bytes);
                T::decode_batch_strided(
                    &self.stage1,
                    k_col,
                    slot_bytes,
                    run,
                    &mut k_strip[t * dh..(t + run) * dh],
                    scratch,
                );
                T::decode_batch_strided(
                    &self.stage1,
                    v_col,
                    slot_bytes,
                    run,
                    &mut v_strip[t * dh..(t + run) * dh],
                    scratch,
                );
                t += run;
            }
        });

        // fan the skipped runs out of their decoded leaders; bases are
        // absolute offsets into the shared batch buffer, so this is a
        // plain in-buffer copy
        for &(src, dst, t, run) in &copies {
            let sb = ws.bases[src] + t * dh;
            let db = ws.bases[dst] + t * dh;
            k_out.copy_within(sb..sb + run * dh, db);
            v_out.copy_within(sb..sb + run * dh, db);
        }
    }

    /// The pre-batch per-vector gather (one `Stage1::decode` call per
    /// (token, layer, head) vector, allocating inside each call) —
    /// retained as the property-test oracle and the
    /// `gather_throughput` bench baseline.  Same output layout and
    /// zero-padding semantics as [`CacheManager::gather_ws`].
    pub fn gather_reference(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        if k_out.len() != l * h * t_max * dh || v_out.len() != l * h * t_max * dh {
            bail!("gather_reference: output buffer shape mismatch");
        }
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        k_out.fill(0.0);
        v_out.fill(0.0);
        let tp = cfg.tokens_per_page;
        for t in 0..n {
            let page = self.alloc.page(s.pages[t / tp]);
            let slot = t % tp;
            for layer in 0..l {
                for head in 0..h {
                    let dst = ((layer * h + head) * t_max + t) * dh;
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, false),
                        &mut k_out[dst..dst + dh],
                    );
                    self.stage1.decode(
                        page.slot(&cfg, slot, layer, head, true),
                        &mut v_out[dst..dst + dh],
                    );
                }
            }
        }
        Ok(n)
    }

    /// Shadow (uncompressed) cache in the same `[l][h][t][dh]` layout —
    /// only valid when `keep_shadow` was set before appends.
    pub fn gather_shadow(
        &self,
        seq: SeqId,
        t_max: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let cfg = *self.alloc.cfg();
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let s = self.seqs.get(&seq).context("unknown sequence")?;
        let n = s.len.min(t_max);
        k_out.fill(0.0);
        v_out.fill(0.0);
        for t in 0..n {
            for layer in 0..l {
                for head in 0..h {
                    let src = (t * l * h + layer * h + head) * dh;
                    let dst = ((layer * h + head) * t_max + t) * dh;
                    k_out[dst..dst + dh].copy_from_slice(&s.shadow_k[src..src + dh]);
                    v_out[dst..dst + dh].copy_from_slice(&s.shadow_v[src..src + dh]);
                }
            }
        }
        Ok(n)
    }

    /// compressed bytes per token slot (for metrics)
    pub fn slot_bytes(&self) -> (usize, usize) {
        let cfg = self.alloc.cfg();
        (cfg.slot_bytes(), cfg.slot_bytes_uncompressed())
    }
}

/// Element type the batched gather decodes into: `f32` (the reference
/// output) or IEEE binary16 bits in `u16` (`f32_to_f16_bits` of the f32
/// output, element for element — see
/// [`Stage1::decode_batch_strided_f16`]).
pub trait GatherElem: Copy + Send + Sync + 'static {
    const ZERO: Self;
    fn decode_batch_strided(
        stage1: &Stage1,
        data: &[u8],
        stride: usize,
        n_vecs: usize,
        out: &mut [Self],
        scratch: &mut BatchScratch,
    );
}

impl GatherElem for f32 {
    const ZERO: f32 = 0.0;
    fn decode_batch_strided(
        stage1: &Stage1,
        data: &[u8],
        stride: usize,
        n_vecs: usize,
        out: &mut [f32],
        scratch: &mut BatchScratch,
    ) {
        stage1.decode_batch_strided(data, stride, n_vecs, out, scratch);
    }
}

impl GatherElem for u16 {
    const ZERO: u16 = 0;
    fn decode_batch_strided(
        stage1: &Stage1,
        data: &[u8],
        stride: usize,
        n_vecs: usize,
        out: &mut [u16],
        scratch: &mut BatchScratch,
    ) {
        stage1.decode_batch_strided_f16(data, stride, n_vecs, out, scratch);
    }
}

/// Split `buf` into disjoint `strip_len`-sized mutable windows starting
/// at the (strictly ascending, non-overlapping) `bases`, skipping the
/// gaps between them.  Lets the strip-parallel gather hand each worker
/// an owned `&mut` window of a shared output buffer safely.
fn carve_strips<'a, T>(
    mut buf: &'a mut [T],
    bases: &[usize],
    strip_len: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(bases.len());
    let mut cursor = 0usize;
    for &base in bases {
        debug_assert!(base >= cursor, "strip bases must ascend without overlap");
        let tmp = buf;
        let (_gap, rest) = tmp.split_at_mut(base - cursor);
        let (strip, rest) = rest.split_at_mut(strip_len);
        out.push(strip);
        buf = rest;
        cursor = base + strip_len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Stage1, Stage1Config, Variant};
    use crate::util::prng::Rng;

    fn mk(max_pages: usize, bits: u8) -> CacheManager {
        let stage1 = Stage1::new(Stage1Config::new(Variant::IsoFull, 64, bits));
        let cfg = PageConfig {
            tokens_per_page: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 64,
            encoded_len: stage1.encoded_len(),
        };
        CacheManager::new(stage1, cfg, max_pages)
    }

    fn token(rng: &mut Rng, cfg: &PageConfig) -> (Vec<f32>, Vec<f32>) {
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        (rng.gaussian_vec_f32(n), rng.gaussian_vec_f32(n))
    }

    #[test]
    fn append_gather_roundtrip_quality() {
        let mut m = mk(64, 4);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(1);
        m.start_seq(1).unwrap();
        let mut truth_k = Vec::new();
        for _ in 0..10 {
            let (k, v) = token(&mut rng, &cfg);
            truth_k.push(k.clone());
            m.append_token(1, &k, &v).unwrap();
        }
        assert_eq!(m.seq_len(1), 10);
        let t_max = 16;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut k_out = vec![0.0f32; sz];
        let mut v_out = vec![0.0f32; sz];
        let n = m.gather(1, t_max, &mut k_out, &mut v_out).unwrap();
        assert_eq!(n, 10);
        // token 3, layer 1, head 0 reconstruction ≈ original
        let dh = cfg.d_head;
        let t = 3;
        let dst = ((1 * cfg.n_heads + 0) * t_max + t) * dh;
        let src = (1 * cfg.n_heads + 0) * dh;
        let rel = crate::metrics::rel_l2(&truth_k[t][src..src + dh], &k_out[dst..dst + dh]);
        assert!(rel < 0.25, "rel {rel}");
        // padding stays zero
        let pad = ((0 * cfg.n_heads) * t_max + 12) * dh;
        assert!(k_out[pad..pad + dh].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batched_gather_bit_exact_with_reference() {
        // the batch path (any threading policy) must reproduce the
        // per-vector reference path bit for bit
        for policy in [
            ParallelPolicy::Off,
            ParallelPolicy::Auto,
            ParallelPolicy::Fixed(3),
        ] {
            let mut m = mk(64, 3);
            m.parallel = policy;
            let cfg = m.page_cfg();
            let mut rng = Rng::new(7);
            m.start_seq(1).unwrap();
            // 64 tokens × 2L × 2H × 2 = 512 vectors: crosses
            // MIN_PARALLEL_VECTORS so the threaded path really runs
            for _ in 0..64 {
                let (k, v) = token(&mut rng, &cfg);
                m.append_token(1, &k, &v).unwrap();
            }
            let t_max = 68;
            let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
            let (mut ka, mut va) = (vec![0.0f32; sz], vec![0.0f32; sz]);
            let (mut kb, mut vb) = (vec![1.0f32; sz], vec![1.0f32; sz]);
            let mut ws = GatherWorkspace::new();
            let na = m.gather_reference(1, t_max, &mut ka, &mut va).unwrap();
            let nb = m.gather_ws(1, t_max, &mut kb, &mut vb, &mut ws).unwrap();
            assert_eq!(na, nb);
            assert_eq!(
                ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} K"
            );
            assert_eq!(
                va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} V"
            );
        }
    }

    #[test]
    fn batched_lane_gather_matches_single_gather() {
        let mut m = mk(64, 4);
        m.parallel = ParallelPolicy::Auto;
        let cfg = m.page_cfg();
        let mut rng = Rng::new(8);
        m.start_seq(1).unwrap();
        for _ in 0..18 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(1, &k, &v).unwrap();
        }
        let (t_max, batch, lane) = (20usize, 3usize, 1usize);
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let single = l * h * t_max * dh;
        let (mut k1, mut v1) = (vec![0.0f32; single], vec![0.0f32; single]);
        m.gather(1, t_max, &mut k1, &mut v1).unwrap();
        let wide = l * batch * h * t_max * dh;
        let (mut kb, mut vb) = (vec![9.0f32; wide], vec![9.0f32; wide]);
        let mut ws = GatherWorkspace::new();
        m.gather_into_batch_ws(1, lane, batch, t_max, &mut kb, &mut vb, &mut ws)
            .unwrap();
        for layer in 0..l {
            for head in 0..h {
                let a = (layer * h + head) * t_max * dh;
                let b = (((layer * batch) + lane) * h + head) * t_max * dh;
                assert_eq!(
                    &k1[a..a + t_max * dh],
                    &kb[b..b + t_max * dh],
                    "layer {layer} head {head}"
                );
                assert_eq!(&v1[a..a + t_max * dh], &vb[b..b + t_max * dh]);
            }
        }
        // other lanes untouched by the lane gather
        let other = (((0 * batch) + 0) * h + 0) * t_max * dh;
        assert!(kb[other..other + dh].iter().all(|&x| x == 9.0));
    }

    #[test]
    fn append_run_matches_append_token_loop() {
        // one chunk-append must leave pages bit-identical to the same
        // tokens appended one at a time (ragged page boundary included:
        // 3 tokens pre-seeded, then a 9-token run over 4-token pages)
        let (mut a, mut b) = (mk(64, 3), mk(64, 3));
        let cfg = a.page_cfg();
        let tok_n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        let mut rng = Rng::new(21);
        a.start_seq(1).unwrap();
        b.start_seq(1).unwrap();
        let seed: Vec<(Vec<f32>, Vec<f32>)> = (0..3).map(|_| token(&mut rng, &cfg)).collect();
        for (k, v) in &seed {
            a.append_token(1, k, v).unwrap();
            b.append_token(1, k, v).unwrap();
        }
        let run: Vec<(Vec<f32>, Vec<f32>)> = (0..9).map(|_| token(&mut rng, &cfg)).collect();
        let mut k_run = Vec::new();
        let mut v_run = Vec::new();
        for (k, v) in &run {
            k_run.extend_from_slice(k);
            v_run.extend_from_slice(v);
            b.append_token(1, k, v).unwrap();
        }
        assert_eq!(k_run.len(), 9 * tok_n);
        a.append_run(1, &k_run, &v_run, 9).unwrap();
        assert_eq!(a.seq_len(1), 12);
        assert_eq!(a.seq_len(1), b.seq_len(1));
        let t_max = 12;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut ka, mut va) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let (mut kb, mut vb) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        a.gather(1, t_max, &mut ka, &mut va).unwrap();
        b.gather(1, t_max, &mut kb, &mut vb).unwrap();
        assert_eq!(
            ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn append_run_failure_leaves_sequence_unchanged() {
        // pool of 2 pages × 4 tokens = 8; a 9-token run must fail and
        // roll back the pre-reserved pages
        let mut m = mk(2, 2);
        let cfg = m.page_cfg();
        let tok_n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        let mut rng = Rng::new(22);
        m.start_seq(1).unwrap();
        let k_run = rng.gaussian_vec_f32(9 * tok_n);
        let v_run = rng.gaussian_vec_f32(9 * tok_n);
        assert!(m.append_run(1, &k_run, &v_run, 9).is_err());
        assert_eq!(m.seq_len(1), 0);
        assert_eq!(m.pages_in_use(), 0, "reserved pages must be released");
        // an 8-token run then fits
        m.append_run(1, &k_run[..8 * tok_n], &v_run[..8 * tok_n], 8).unwrap();
        assert_eq!(m.seq_len(1), 8);
    }

    #[test]
    fn append_run_empty_and_shadow() {
        let mut m = mk(8, 4);
        m.keep_shadow = true;
        let cfg = m.page_cfg();
        let tok_n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        let mut rng = Rng::new(23);
        m.start_seq(1).unwrap();
        m.append_run(1, &[], &[], 0).unwrap();
        assert_eq!(m.seq_len(1), 0);
        assert!(m.append_run(99, &[], &[], 0).is_err());
        let k = rng.gaussian_vec_f32(2 * tok_n);
        let v = rng.gaussian_vec_f32(2 * tok_n);
        m.append_run(1, &k, &v, 2).unwrap();
        let t_max = 2;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut ks, mut vs) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        m.gather_shadow(1, t_max, &mut ks, &mut vs).unwrap();
        // token 1, layer 1, head 0 of the shadow equals the run input
        let dh = cfg.d_head;
        let src = (1 * cfg.n_layers * cfg.n_heads + 1 * cfg.n_heads) * dh;
        let dst = ((1 * cfg.n_heads) * t_max + 1) * dh;
        assert_eq!(&ks[dst..dst + dh], &k[src..src + dh]);
    }

    #[test]
    fn multi_lane_gather_matches_per_lane_gathers() {
        for policy in [ParallelPolicy::Off, ParallelPolicy::Auto] {
            let mut m = mk(64, 4);
            m.parallel = policy;
            let cfg = m.page_cfg();
            let mut rng = Rng::new(24);
            // three sequences of different lengths on lanes 0, 2, 3 of 4
            let lens = [5usize, 11, 64];
            let lanes = [0usize, 2, 3];
            for (i, &len) in lens.iter().enumerate() {
                m.start_seq(i as u64 + 1).unwrap();
                for _ in 0..len {
                    let (k, v) = token(&mut rng, &cfg);
                    m.append_token(i as u64 + 1, &k, &v).unwrap();
                }
            }
            let (t_max, batch) = (64usize, 4usize);
            let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
            let wide = l * batch * h * t_max * dh;
            let (mut ka, mut va) = (vec![7.0f32; wide], vec![7.0f32; wide]);
            let (mut kb, mut vb) = (vec![7.0f32; wide], vec![7.0f32; wide]);
            let mut ws = GatherWorkspace::new();
            // reference: one gather_into_batch per lane
            for (i, &lane) in lanes.iter().enumerate() {
                m.gather_into_batch_ws(i as u64 + 1, lane, batch, t_max, &mut ka, &mut va, &mut ws)
                    .unwrap();
            }
            // one cross-lane drain
            let pairs: Vec<(SeqId, usize)> =
                lanes.iter().enumerate().map(|(i, &lane)| (i as u64 + 1, lane)).collect();
            let ns = m
                .gather_lanes_into_batch_ws(&pairs, batch, t_max, &mut kb, &mut vb, &mut ws)
                .unwrap();
            assert_eq!(ns, lens.to_vec());
            assert_eq!(
                ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} K"
            );
            assert_eq!(
                va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} V"
            );
            // untouched lane 1 keeps its sentinel
            let lane1 = ((0 * batch + 1) * h) * t_max * dh;
            assert!(kb[lane1..lane1 + dh].iter().all(|&x| x == 7.0));
        }
    }

    #[test]
    fn multi_lane_gather_validates_lanes() {
        let mut m = mk(8, 2);
        m.start_seq(1).unwrap();
        m.start_seq(2).unwrap();
        let cfg = m.page_cfg();
        let sz = cfg.n_layers * 4 * cfg.n_heads * 8 * cfg.d_head;
        let (mut k, mut v) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        let mut ws = GatherWorkspace::new();
        // out-of-range lane
        assert!(m
            .gather_lanes_into_batch_ws(&[(1, 4)], 4, 8, &mut k, &mut v, &mut ws)
            .is_err());
        // non-ascending lanes
        assert!(m
            .gather_lanes_into_batch_ws(&[(1, 2), (2, 1)], 4, 8, &mut k, &mut v, &mut ws)
            .is_err());
        // unknown sequence
        assert!(m
            .gather_lanes_into_batch_ws(&[(9, 0)], 4, 8, &mut k, &mut v, &mut ws)
            .is_err());
        // empty lane list is a no-op
        let ns = m
            .gather_lanes_into_batch_ws(&[], 4, 8, &mut k, &mut v, &mut ws)
            .unwrap();
        assert!(ns.is_empty());
    }

    #[test]
    fn pages_allocated_lazily_and_released() {
        let mut m = mk(8, 2);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(2);
        m.start_seq(7).unwrap();
        assert_eq!(m.pages_in_use(), 0);
        for i in 0..9 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(7, &k, &v).unwrap();
            assert_eq!(m.pages_in_use(), i / 4 + 1);
        }
        m.drop_seq(7);
        assert_eq!(m.pages_in_use(), 0);
    }

    #[test]
    fn capacity_exhaustion_fails_cleanly() {
        let mut m = mk(1, 2);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(3);
        m.start_seq(1).unwrap();
        for _ in 0..4 {
            let (k, v) = token(&mut rng, &cfg);
            m.append_token(1, &k, &v).unwrap();
        }
        let (k, v) = token(&mut rng, &cfg);
        let err = m.append_token(1, &k, &v);
        assert!(err.is_err());
        // sequence state unchanged by the failed append
        assert_eq!(m.seq_len(1), 4);
    }

    #[test]
    fn admission_math() {
        let m = mk(4, 2);
        assert!(m.can_admit(16)); // 4 pages × 4 tokens
        assert!(!m.can_admit(17));
    }

    #[test]
    fn shadow_matches_truth_exactly() {
        let mut m = mk(16, 2);
        m.keep_shadow = true;
        let cfg = m.page_cfg();
        let mut rng = Rng::new(4);
        m.start_seq(1).unwrap();
        let (k, v) = token(&mut rng, &cfg);
        m.append_token(1, &k, &v).unwrap();
        let t_max = 4;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut k_out = vec![0.0f32; sz];
        let mut v_out = vec![0.0f32; sz];
        m.gather_shadow(1, t_max, &mut k_out, &mut v_out).unwrap();
        let dh = cfg.d_head;
        for layer in 0..cfg.n_layers {
            for head in 0..cfg.n_heads {
                let src = (layer * cfg.n_heads + head) * dh;
                let dst = ((layer * cfg.n_heads + head) * t_max) * dh;
                assert_eq!(&k_out[dst..dst + dh], &k[src..src + dh]);
                assert_eq!(&v_out[dst..dst + dh], &v[src..src + dh]);
            }
        }
    }

    #[test]
    fn unknown_seq_rejected() {
        let mut m = mk(4, 2);
        let cfg = m.page_cfg();
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        assert!(m.append_token(99, &vec![0.0; n], &vec![0.0; n]).is_err());
        let mut buf = vec![0.0f32; cfg.n_layers * cfg.n_heads * 4 * cfg.d_head];
        let mut buf2 = buf.clone();
        assert!(m.gather(99, 4, &mut buf, &mut buf2).is_err());
    }

    #[test]
    fn duplicate_seq_rejected() {
        let mut m = mk(4, 2);
        m.start_seq(1).unwrap();
        assert!(m.start_seq(1).is_err());
    }

    #[test]
    fn multiple_sequences_isolated() {
        let mut m = mk(32, 4);
        let cfg = m.page_cfg();
        let mut rng = Rng::new(5);
        m.start_seq(1).unwrap();
        m.start_seq(2).unwrap();
        let (k1, v1) = token(&mut rng, &cfg);
        let (k2, v2) = token(&mut rng, &cfg);
        m.append_token(1, &k1, &v1).unwrap();
        m.append_token(2, &k2, &v2).unwrap();
        let t_max = 4;
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let mut a = vec![0.0f32; sz];
        let mut b = vec![0.0f32; sz];
        let mut tmp = vec![0.0f32; sz];
        m.gather(1, t_max, &mut a, &mut tmp).unwrap();
        m.gather(2, t_max, &mut b, &mut tmp).unwrap();
        // different tokens → different reconstructions
        assert_ne!(a, b);
        m.drop_seq(1);
        // seq 2 still readable after seq 1 dropped
        assert!(m.gather(2, t_max, &mut b, &mut tmp).is_ok());
    }

    /// Deterministic per-token K/V (stands in for the model: same
    /// prefix → same vectors), so shared pages must be byte-identical
    /// to freshly encoded ones.
    fn token_stream(seed: u64, n: usize, cfg: &PageConfig) -> Vec<(Vec<f32>, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| token(&mut rng, cfg)).collect()
    }

    fn gather_pair(m: &CacheManager, seq: SeqId, t_max: usize) -> (Vec<f32>, Vec<f32>) {
        let cfg = m.page_cfg();
        let sz = cfg.n_layers * cfg.n_heads * t_max * cfg.d_head;
        let (mut k, mut v) = (vec![0.0f32; sz], vec![0.0f32; sz]);
        m.gather(seq, t_max, &mut k, &mut v).unwrap();
        (k, v)
    }

    #[test]
    fn prefix_sharing_adopts_pages_and_stays_bit_exact() {
        // tp = 4; prompt of 10 = 2 full pages + sealed tail of 2
        let mut m = mk(64, 4);
        m.prefix_sharing = true;
        let mut r = mk(64, 4); // unshared reference cache
        let cfg = m.page_cfg();
        let prompt: Vec<i32> = (0..10).map(|i| 100 + i).collect();
        let pv = token_stream(11, 10, &cfg);
        let dec1 = token_stream(12, 3, &cfg);
        let dec2 = token_stream(13, 3, &cfg);
        let run =
            |toks: &[(Vec<f32>, Vec<f32>)]| -> (Vec<f32>, Vec<f32>) {
                let mut k = Vec::new();
                let mut v = Vec::new();
                for (tk, tv) in toks {
                    k.extend_from_slice(tk);
                    v.extend_from_slice(tv);
                }
                (k, v)
            };
        let (pk, pvv) = run(&pv);

        // seq 1: cold — encodes everything, publishes 2 full pages + tail
        let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
        assert_eq!(reuse, PrefixReuse::default());
        m.append_run(1, &pk, &pvv, 10).unwrap();
        assert_eq!(m.prefix_index_len(), 3);
        r.start_seq_with_prompt(1, &prompt).unwrap();
        r.append_run(1, &pk, &pvv, 10).unwrap();
        assert_eq!(r.prefix_index_len(), 0, "sharing off publishes nothing");

        // seq 2: warm — adopts all three pages, prefill skips 10 tokens
        let reuse = m.start_seq_with_prompt(2, &prompt).unwrap();
        assert_eq!(reuse, PrefixReuse { pages: 3, tokens: 10 });
        assert_eq!(m.seq_len(2), 10);
        assert_eq!(m.shared_pages(), 3);
        r.start_seq(2).unwrap();
        r.append_run(2, &pk, &pvv, 10).unwrap();

        // decode appends: both tails CoW off the shared sealed tail
        for (d, seq, mgr) in [(&dec1, 1, true), (&dec2, 2, true), (&dec1, 1, false), (&dec2, 2, false)] {
            let target = if mgr { &mut m } else { &mut r };
            for (tk, tv) in d.iter() {
                target.append_token(seq, tk, tv).unwrap();
            }
        }
        assert_eq!(m.share.cow_copies, 2);
        assert_eq!(m.share.prefix_hit_pages, 3);
        assert_eq!(m.share.prefix_hit_tokens, 10);
        // dedup credit counts the 2 adopted *full* pages; the adopted
        // tail is excluded because its CoW replacement costs a page
        assert_eq!(m.share.bytes_deduped, 2 * cfg.page_bytes() as u64);

        // byte-exact: shared cache == unshared cache == per-vector oracle
        let t_max = 14;
        for seq in [1u64, 2] {
            let (mk_, mv_) = gather_pair(&m, seq, t_max);
            let (rk, rv) = gather_pair(&r, seq, t_max);
            assert_eq!(
                mk_.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                rk.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} K shared vs unshared"
            );
            assert_eq!(
                mv_.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                rv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} V shared vs unshared"
            );
            let sz = mk_.len();
            let (mut ok, mut ov) = (vec![0.0f32; sz], vec![0.0f32; sz]);
            m.gather_reference(seq, t_max, &mut ok, &mut ov).unwrap();
            assert_eq!(
                mk_.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                ok.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} K batched vs reference on shared pages"
            );
        }
        // page economics: 2 shared prompt pages + 1 shared-then-cached
        // tail + per-seq {CoW tail, 1 overflow page} = 7 resident, vs 8
        // for the unshared run
        assert_eq!(m.pages_in_use(), 7);
        assert_eq!(r.pages_in_use(), 8);

        // teardown: every ref returns; indexed pages stay warm
        m.drop_seq(1);
        m.drop_seq(2);
        assert_eq!(m.live_refs(), 0);
        assert_eq!(m.live_pages(), 0);
        assert_eq!(m.cached_pages(), 3);
        assert_eq!(m.pages_in_use(), 3);

        // seq 3 revives the whole prefix from the zero-ref cache
        let reuse = m.start_seq_with_prompt(3, &prompt).unwrap();
        assert_eq!(reuse, PrefixReuse { pages: 3, tokens: 10 });
        assert_eq!(m.cached_pages(), 0);
        let (mk_, _) = gather_pair(&m, 3, 10);
        let (rk, _) = gather_pair(&r, 2, 10);
        // prompt region identical to the unshared cache's
        assert_eq!(
            mk_.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            rk.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn cross_lane_drain_bit_exact_on_shared_pages() {
        // two sequences sharing adopted prompt pages, gathered through
        // the cross-lane drain, must match their per-lane gathers and
        // the per-vector reference bit for bit
        let mut m = mk(64, 4);
        m.prefix_sharing = true;
        m.parallel = ParallelPolicy::Auto;
        let cfg = m.page_cfg();
        let prompt: Vec<i32> = (0..10).collect();
        let pv = token_stream(71, 10, &cfg);
        let (mut pk, mut pvv) = (Vec::new(), Vec::new());
        for (k, v) in &pv {
            pk.extend_from_slice(k);
            pvv.extend_from_slice(v);
        }
        m.start_seq_with_prompt(1, &prompt).unwrap();
        m.append_run(1, &pk, &pvv, 10).unwrap();
        let reuse = m.start_seq_with_prompt(2, &prompt).unwrap();
        assert_eq!(reuse.pages, 3);
        // divergent decode tails
        for (seq, seed) in [(1u64, 72u64), (2, 73)] {
            for (k, v) in &token_stream(seed, 2, &cfg) {
                m.append_token(seq, k, v).unwrap();
            }
        }
        assert!(m.shared_pages() >= 2, "prompt pages still shared");
        let (t_max, batch) = (12usize, 3usize);
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let wide = l * batch * h * t_max * dh;
        let (mut ka, mut va) = (vec![5.0f32; wide], vec![5.0f32; wide]);
        let (mut kb, mut vb) = (vec![5.0f32; wide], vec![5.0f32; wide]);
        let mut ws = GatherWorkspace::new();
        // reference: per-lane batch gathers
        m.gather_into_batch_ws(1, 0, batch, t_max, &mut ka, &mut va, &mut ws)
            .unwrap();
        m.gather_into_batch_ws(2, 2, batch, t_max, &mut ka, &mut va, &mut ws)
            .unwrap();
        // one cross-lane drain over both shared-page sequences
        let ns = m
            .gather_lanes_into_batch_ws(&[(1, 0), (2, 2)], batch, t_max, &mut kb, &mut vb, &mut ws)
            .unwrap();
        assert_eq!(ns, vec![12, 12]);
        assert_eq!(
            ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gather_dedup_bit_exact_and_counts_shared_strips() {
        use std::sync::atomic::Ordering;
        // three lanes adopting the same 2-page prompt: with dedup on the
        // cross-lane drain must produce byte-identical output to dedup
        // off, decode each shared strip once, and say so in the counters
        for policy in [ParallelPolicy::Off, ParallelPolicy::Auto] {
            let mut m = mk(64, 4);
            m.prefix_sharing = true;
            m.parallel = policy;
            let cfg = m.page_cfg();
            let prompt: Vec<i32> = (0..8).collect();
            let pv = token_stream(81, 8, &cfg);
            let (pk, pvv) = flat_run(&pv);
            m.start_seq_with_prompt(1, &prompt).unwrap();
            m.append_run(1, &pk, &pvv, 8).unwrap();
            for seq in [2u64, 3] {
                let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
                assert_eq!(reuse.pages, 2);
            }
            // divergent decode tails of different lengths
            for (seq, n) in [(1u64, 3usize), (2, 1), (3, 2)] {
                for (k, v) in &token_stream(90 + seq, n, &cfg) {
                    m.append_token(seq, k, v).unwrap();
                }
            }
            let (t_max, batch) = (11usize, 3usize);
            let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
            let wide = l * batch * h * t_max * dh;
            let (mut ka, mut va) = (vec![5.0f32; wide], vec![5.0f32; wide]);
            let (mut kb, mut vb) = (vec![5.0f32; wide], vec![5.0f32; wide]);
            let mut ws = GatherWorkspace::new();
            let pairs: Vec<(SeqId, usize)> = vec![(1, 0), (2, 1), (3, 2)];
            m.gather_dedup = false;
            m.gather_lanes_into_batch_ws(&pairs, batch, t_max, &mut ka, &mut va, &mut ws)
                .unwrap();
            assert_eq!(m.share.strips_deduped.load(Ordering::Relaxed), 0);
            m.gather_dedup = true;
            m.gather_lanes_into_batch_ws(&pairs, batch, t_max, &mut kb, &mut vb, &mut ws)
                .unwrap();
            assert_eq!(
                ka.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                kb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} K"
            );
            assert_eq!(
                va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{policy:?} V"
            );
            // both shared pages repeat on 2 follower lanes × 2 layers ×
            // 2 heads = 8 skipped runs per page, 16 total
            assert_eq!(m.share.strips_deduped.load(Ordering::Relaxed), 16);
            let tp = cfg.tokens_per_page;
            assert_eq!(
                m.share.bytes_saved.load(Ordering::Relaxed),
                (16 * 2 * tp * dh * std::mem::size_of::<f32>()) as u64
            );
        }
    }

    #[test]
    fn gather_f16_is_converted_f32_gather() {
        // every f16 gather element must be exactly f32_to_f16_bits of
        // the f32 gather's, on both the single-sequence and the
        // cross-lane (dedup'd) paths
        use crate::util::f16::f32_to_f16_bits;
        let mut m = mk(64, 4);
        m.prefix_sharing = true;
        let cfg = m.page_cfg();
        let prompt: Vec<i32> = (0..6).collect();
        let pv = token_stream(83, 6, &cfg);
        let (pk, pvv) = flat_run(&pv);
        m.start_seq_with_prompt(1, &prompt).unwrap();
        m.append_run(1, &pk, &pvv, 6).unwrap();
        m.start_seq_with_prompt(2, &prompt).unwrap();
        for (seq, seed) in [(1u64, 84u64), (2, 85)] {
            for (k, v) in &token_stream(seed, 2, &cfg) {
                m.append_token(seq, k, v).unwrap();
            }
        }
        let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head);
        let t_max = 8usize;
        let narrow = l * h * t_max * dh;
        let mut ws = GatherWorkspace::new();
        let (mut kf, mut vf) = (vec![0.0f32; narrow], vec![0.0f32; narrow]);
        let (mut kh, mut vh) = (vec![9u16; narrow], vec![9u16; narrow]);
        m.gather_ws(1, t_max, &mut kf, &mut vf, &mut ws).unwrap();
        m.gather_ws_f16(1, t_max, &mut kh, &mut vh, &mut ws).unwrap();
        assert_eq!(
            kh,
            kf.iter().map(|&x| f32_to_f16_bits(x)).collect::<Vec<_>>()
        );
        assert_eq!(
            vh,
            vf.iter().map(|&x| f32_to_f16_bits(x)).collect::<Vec<_>>()
        );
        let batch = 2usize;
        let wide = narrow * batch;
        let (mut kf, mut vf) = (vec![0.0f32; wide], vec![0.0f32; wide]);
        let (mut kh, mut vh) = (vec![9u16; wide], vec![9u16; wide]);
        let pairs: Vec<(SeqId, usize)> = vec![(1, 0), (2, 1)];
        m.gather_lanes_into_batch_ws(&pairs, batch, t_max, &mut kf, &mut vf, &mut ws)
            .unwrap();
        let ns = m
            .gather_lanes_into_batch_f16_ws(&pairs, batch, t_max, &mut kh, &mut vh, &mut ws)
            .unwrap();
        assert_eq!(ns, vec![8, 8]);
        assert_eq!(
            kh,
            kf.iter().map(|&x| f32_to_f16_bits(x)).collect::<Vec<_>>()
        );
        assert_eq!(
            vh,
            vf.iter().map(|&x| f32_to_f16_bits(x)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn partial_prefix_hit_adopts_leading_pages_only() {
        let mut m = mk(64, 3);
        m.prefix_sharing = true;
        let cfg = m.page_cfg();
        let prompt_a: Vec<i32> = (0..8).collect(); // 2 full pages
        let pv = token_stream(31, 8, &cfg);
        m.start_seq_with_prompt(1, &prompt_a).unwrap();
        for (k, v) in &pv {
            m.append_token(1, k, v).unwrap();
        }
        assert_eq!(m.prefix_index_len(), 2);
        // same first page, divergent second page → adopt only page 0
        let mut prompt_b = prompt_a.clone();
        prompt_b[5] = 999;
        let reuse = m.start_seq_with_prompt(2, &prompt_b).unwrap();
        assert_eq!(reuse, PrefixReuse { pages: 1, tokens: 4 });
        // longer prompt with matching start → both full pages, no tail
        let mut prompt_c = prompt_a.clone();
        prompt_c.extend_from_slice(&[7, 7, 7]);
        let reuse = m.start_seq_with_prompt(3, &prompt_c).unwrap();
        assert_eq!(reuse, PrefixReuse { pages: 2, tokens: 8 });
        // and a shorter prompt that ends mid-page misses (its tail key
        // covers tokens 4..6, which nobody published)
        let reuse = m.start_seq_with_prompt(4, &prompt_a[..6]).unwrap();
        assert_eq!(reuse, PrefixReuse { pages: 1, tokens: 4 });
    }

    #[test]
    fn prefix_admission_math_counts_reuse() {
        // pool of 4 pages, tp = 4
        let mut m = mk(4, 2);
        m.prefix_sharing = true;
        let cfg = m.page_cfg();
        let prompt: Vec<i32> = (0..8).collect();
        let pv = token_stream(41, 8, &cfg);
        let (mut pk, mut pvv) = (Vec::new(), Vec::new());
        for (k, v) in &pv {
            pk.extend_from_slice(k);
            pvv.extend_from_slice(v);
        }
        m.start_seq_with_prompt(1, &prompt).unwrap();
        m.append_run(1, &pk, &pvv, 8).unwrap();
        // 2 of 4 pages used; a 12-token request needs 3 pages raw...
        assert!(!m.can_admit(12));
        // ...but only 1 after adopting the 2 published prompt pages
        assert!(m.can_admit_prompt(&prompt, 12));
        let reuse = m.start_seq_with_prompt(2, &prompt).unwrap();
        assert_eq!(reuse.pages, 2);
        // growing seq 2 to 12 tokens allocates exactly 1 fresh page
        let dec = token_stream(42, 4, &cfg);
        for (k, v) in &dec {
            m.append_token(2, k, v).unwrap();
        }
        assert_eq!(m.pages_in_use(), 3);
        assert_eq!(m.shared_pages(), 2);
    }

    #[test]
    fn zero_ref_pages_evicted_lru_under_pressure() {
        let mut m = mk(2, 2);
        m.prefix_sharing = true;
        let cfg = m.page_cfg();
        let prompt: Vec<i32> = (0..8).collect();
        let pv = token_stream(51, 8, &cfg);
        m.start_seq_with_prompt(1, &prompt).unwrap();
        for (k, v) in &pv {
            m.append_token(1, k, v).unwrap();
        }
        m.drop_seq(1);
        assert_eq!(m.cached_pages(), 2);
        assert_eq!(m.available_pages(), 2, "cached pages are evictable headroom");
        assert!(m.can_admit(8));
        // a fresh unrelated sequence must evict the cached pages
        m.start_seq(2).unwrap();
        let fresh = token_stream(52, 8, &cfg);
        for (k, v) in &fresh {
            m.append_token(2, k, v).unwrap();
        }
        assert_eq!(m.share.pages_evicted, 2);
        assert_eq!(m.prefix_index_len(), 0);
        assert_eq!(m.cached_pages(), 0);
        m.drop_seq(2);
        assert_eq!(m.live_refs(), 0);
    }

    #[test]
    fn sharing_off_is_seed_behavior() {
        // start_seq_with_prompt with sharing off = plain start_seq:
        // nothing published, nothing adopted, pages freed on drop
        let mut m = mk(8, 2);
        let cfg = m.page_cfg();
        let prompt: Vec<i32> = (0..8).collect();
        let pv = token_stream(61, 8, &cfg);
        let reuse = m.start_seq_with_prompt(1, &prompt).unwrap();
        assert_eq!(reuse, PrefixReuse::default());
        for (k, v) in &pv {
            m.append_token(1, k, v).unwrap();
        }
        assert_eq!(m.prefix_index_len(), 0);
        let reuse = m.start_seq_with_prompt(2, &prompt).unwrap();
        assert_eq!(reuse, PrefixReuse::default());
        m.drop_seq(1);
        m.drop_seq(2);
        assert_eq!(m.pages_in_use(), 0);
        assert_eq!(m.share, crate::metrics::ShareStats::default());
    }

    /// Flatten a token stream into one run for append_run.
    fn flat_run(toks: &[(Vec<f32>, Vec<f32>)]) -> (Vec<f32>, Vec<f32>) {
        let (mut k, mut v) = (Vec::new(), Vec::new());
        for (tk, tv) in toks {
            k.extend_from_slice(tk);
            v.extend_from_slice(tv);
        }
        (k, v)
    }

    #[test]
    fn radix_sub_page_tail_copy_is_bit_exact_and_saves_pages() {
        // tp = 4; 4 clients share 10 of 11 prompt tokens (2 full pages
        // + 2 of 3 tail slots), then each decodes 2 tokens.  The radix
        // index copies the 2 shared tail slots and re-encodes only the
        // divergent one; the copied tail stays open, so divergent
        // clients skip the seal→CoW dance entirely and the cache ends
        // strictly below the flat index's page count — with every
        // gather byte-identical to the unshared reference.
        let mk_shared = |kind: PrefixIndexKind| {
            let mut m = mk(64, 4);
            m.prefix_sharing = true;
            m.index_kind = kind;
            m
        };
        let mut rx = mk_shared(PrefixIndexKind::Radix);
        let mut fx = mk_shared(PrefixIndexKind::Flat);
        let mut un = mk(64, 4); // unshared reference
        let cfg = rx.page_cfg();
        let clients = 4u64;
        let shared = token_stream(31, 10, &cfg);
        for c in 0..clients {
            let seq = c + 1;
            let mut prompt: Vec<i32> = (0..10).collect();
            prompt.push(900 + c as i32);
            let tail = token_stream(40 + c, 1, &cfg);
            let (sk, sv) = flat_run(&shared);
            let (tk, tv) = flat_run(&tail);
            for (m, is_radix) in [(&mut rx, true), (&mut fx, false)] {
                let reuse = m.start_seq_with_prompt(seq, &prompt).unwrap();
                if c == 0 {
                    assert_eq!(reuse, PrefixReuse::default(), "first client is cold");
                } else if is_radix {
                    assert_eq!(
                        reuse,
                        PrefixReuse { pages: 2, tokens: 10 },
                        "radix covers the shared tail slots too"
                    );
                } else {
                    assert_eq!(
                        reuse,
                        PrefixReuse { pages: 2, tokens: 8 },
                        "flat stops at the page boundary"
                    );
                }
                let skip = reuse.tokens;
                if skip < 10 {
                    m.append_run(seq, &sk[skip * cfg.n_layers * cfg.n_heads * cfg.d_head..],
                        &sv[skip * cfg.n_layers * cfg.n_heads * cfg.d_head..], 10 - skip)
                        .unwrap();
                }
                m.append_run(seq, &tk, &tv, 1).unwrap();
                assert_eq!(m.seq_len(seq), 11);
            }
            un.start_seq(seq).unwrap();
            let (sk, sv) = flat_run(&shared);
            un.append_run(seq, &sk, &sv, 10).unwrap();
            un.append_run(seq, &tk, &tv, 1).unwrap();
        }
        // sub-page accounting: 3 followers × 2 copied slots
        assert_eq!(rx.share.slots_copied, 6);
        assert_eq!(rx.share.tail_copies, 3);
        assert_eq!(fx.share.slots_copied, 0);
        // decode: 2 tokens per client (crosses into an overflow page)
        for c in 0..clients {
            let seq = c + 1;
            let dec = token_stream(70 + c, 2, &cfg);
            for (tk, tv) in &dec {
                rx.append_token(seq, tk, tv).unwrap();
                fx.append_token(seq, tk, tv).unwrap();
                un.append_token(seq, tk, tv).unwrap();
            }
        }
        // CoW economics: only the cold client's published tail CoWs
        // under radix; every client CoWs under flat
        assert_eq!(rx.share.cow_copies, 1);
        assert_eq!(fx.share.cow_copies, 4);
        // page economics: radix = 2 shared + cold client {parked tail,
        // CoW, overflow} + 3 × {open copy page, overflow};
        // flat = 2 shared + 4 × {parked tail, CoW page, overflow}
        assert_eq!(rx.pages_in_use(), 2 + 3 + 3 * 2);
        assert_eq!(fx.pages_in_use(), 2 + 4 * 3);
        assert!(rx.pages_in_use() < fx.pages_in_use());
        // byte-identity everywhere
        let t_max = 13;
        for c in 0..clients {
            let seq = c + 1;
            let (rk, rv) = gather_pair(&rx, seq, t_max);
            let (fk, fv) = gather_pair(&fx, seq, t_max);
            let (uk, uv) = gather_pair(&un, seq, t_max);
            assert_eq!(
                rk.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                uk.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} K radix vs unshared"
            );
            assert_eq!(
                rv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                uv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} V radix vs unshared"
            );
            assert_eq!(
                fk.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                uk.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} K flat vs unshared"
            );
            assert_eq!(
                fv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                uv.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "seq {seq} V flat vs unshared"
            );
        }
        // teardown: every ref returns on both shared caches
        for c in 0..clients {
            rx.drop_seq(c + 1);
            fx.drop_seq(c + 1);
        }
        assert_eq!(rx.live_refs(), 0);
        assert_eq!(rx.live_pages(), 0);
        assert_eq!(fx.live_refs(), 0);
    }

    #[test]
    fn radix_strict_prefix_adopts_the_longer_tail_page() {
        // a shorter prompt that ends mid-page adopts the longer
        // prompt's sealed tail page whole and reads only its leading
        // slots — a match the flat index cannot produce at all
        let mut m = mk(64, 4);
        m.prefix_sharing = true;
        m.index_kind = PrefixIndexKind::Radix;
        let mut un = mk(64, 4);
        let cfg = m.page_cfg();
        let prompt_a: Vec<i32> = (0..11).collect();
        let pv = token_stream(61, 11, &cfg);
        let (pk, pvv) = flat_run(&pv);
        m.start_seq_with_prompt(1, &prompt_a).unwrap();
        m.append_run(1, &pk, &pvv, 11).unwrap();
        // prompt B = the first 9 tokens of A: 2 full pages + 1 tail
        // token, all resident — zero allocation, zero re-encode
        let before = m.pages_in_use();
        let reuse = m.start_seq_with_prompt(2, &prompt_a[..9]).unwrap();
        assert_eq!(reuse, PrefixReuse { pages: 3, tokens: 9 });
        assert_eq!(m.seq_len(2), 9);
        assert_eq!(m.pages_in_use(), before, "whole-page adoption allocates nothing");
        assert_eq!(m.shared_pages(), 3);
        un.start_seq(2).unwrap();
        let n = cfg.n_layers * cfg.n_heads * cfg.d_head;
        un.append_run(2, &pk[..9 * n], &pvv[..9 * n], 9).unwrap();
        // decode: the adopted sealed tail CoWs exactly like a flat one
        let dec = token_stream(62, 2, &cfg);
        for (tk, tv) in &dec {
            m.append_token(2, tk, tv).unwrap();
            un.append_token(2, tk, tv).unwrap();
        }
        assert_eq!(m.share.cow_copies, 1);
        let (mk_, mv_) = gather_pair(&m, 2, 11);
        let (uk, uv) = gather_pair(&un, 2, 11);
        assert_eq!(
            mk_.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            uk.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            mv_.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            uv.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        m.drop_seq(1);
        m.drop_seq(2);
        assert_eq!(m.live_refs(), 0);
        assert_eq!(m.live_pages(), 0);
    }

    #[test]
    fn carve_strips_tiles_and_skips_gaps() {
        let mut buf = vec![0.0f32; 40];
        let strips = carve_strips(&mut buf, &[5, 15, 30], 5);
        assert_eq!(strips.len(), 3);
        for (i, s) in strips.into_iter().enumerate() {
            s.fill((i + 1) as f32);
        }
        assert_eq!(&buf[5..10], &[1.0; 5]);
        assert_eq!(&buf[15..20], &[2.0; 5]);
        assert_eq!(&buf[30..35], &[3.0; 5]);
        // gaps untouched
        assert!(buf[0..5].iter().all(|&x| x == 0.0));
        assert!(buf[10..15].iter().all(|&x| x == 0.0));
        assert!(buf[20..30].iter().all(|&x| x == 0.0));
        assert!(buf[35..].iter().all(|&x| x == 0.0));
    }
}
