//! Paged, compressed KV cache (vLLM-style block tables over pooled pages
//! whose contents are IsoQuant stage-1 encodings).

pub mod allocator;
pub mod manager;
pub mod page;

pub use allocator::{PageAllocator, PageId};
pub use manager::{CacheManager, GatherWorkspace, SeqId};
pub use page::{Page, PageConfig};
