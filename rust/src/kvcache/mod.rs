//! Paged, compressed KV cache (vLLM-style block tables over pooled pages
//! whose contents are IsoQuant stage-1 encodings) with **refcounted
//! prefix sharing**.
//!
//! Because stage-1 encoding is deterministic given its config, a full
//! page is an immutable byte block whose contents are a pure function of
//! the token ids it covers (and every token before them).  That makes
//! pages content-addressable: sequences that start with the same prompt
//! prefix can share the same physical pages, with zero re-encode cost
//! and byte-identical gathers.
//!
//! # Ownership & sharing invariants
//!
//! * **Sealed pages are immutable.**  A page seals when it fills, or
//!   when a prompt completes mid-page (the sealed *partial tail*).
//!   Sealed prompt pages carry a [`page::PrefixKey`] — the chained hash
//!   of the token ids they cover plus the stage-1 config fingerprint —
//!   and are published to the [`prefix::PrefixIndex`].
//! * **Open pages are exclusively owned.**  An open (unsealed) page
//!   always has refcount 1.  Only the *tail* page is ever written
//!   again — appending to a sequence whose tail is sealed
//!   copy-on-write replaces it first ([`CacheManager::append_run`]).
//!   (Under the radix index a sequence may also hold open *interior*
//!   pages: fully-assembled slot-range copies, complete and never
//!   rewritten or published.)
//! * **The index holds no refs.**  [`prefix::PrefixIndex`] entries are
//!   hints, and lookups are token-verified (a hash collision reads as a
//!   miss, never as another prompt's pages): adoption at admission
//!   ([`CacheManager::start_seq_with_prompt`])
//!   takes the refcount 0→1 or n→n+1; when the last owner releases an
//!   indexed page it parks as a *zero-ref cached* page — still resident
//!   and adoptable, and evicted LRU-first under pool pressure.
//! * **Gathers are read-only** and therefore identical on shared and
//!   exclusive pages; every gather path must stay bit-exact vs
//!   [`CacheManager::gather_reference`].
//!
//! Admission is prefix-aware end to end: [`CacheManager::can_admit_prompt`]
//! counts only the *new* pages a request needs after index reuse, so a
//! burst of same-prompt requests admits far more lanes than raw
//! length-based math would.
//!
//! # Index backends (`[cache] prefix_index = flat|radix`)
//!
//! Two interchangeable index structures resolve prompt prefixes to
//! cached pages (selected by [`CacheManager::index_kind`]):
//!
//! * **flat** ([`prefix::PrefixIndex`], the default) — whole-page
//!   chain-hash lookups; exactly the PR 3/4 behavior.
//! * **radix** ([`radix::RadixIndex`]) — a token-level radix tree
//!   (vLLM/SGLang style): longest-common-prefix walks match at *token*
//!   granularity, insertion splits nodes at the divergence token, and a
//!   sub-page match becomes a **slot-range copy-on-write** — two
//!   prompts sharing 15 of 16 tail tokens share those 15 slots' bytes
//!   and encode work, re-encoding only the divergent suffix.  Copied
//!   tails stay *open*, so divergent-tail sequences also skip the
//!   seal→CoW dance and hold one page where the flat lifecycle holds
//!   two.  Eviction is hierarchical (leaves before the interior runs
//!   every descendant needs), and both backends share the same
//!   persistent-store record format — a store written under one index
//!   rehydrates under the other.

//! # Tiered residency (hot → warm → cold)
//!
//! With a persistent [`store::PageStore`] attached, a sealed prompt
//! page moves through three tiers instead of two:
//!
//! * **hot** — owned by ≥ 1 live sequence (never evicted);
//! * **warm** — zero-ref but resident, parked in the prefix index; the
//!   moment a page parks it is also *spilled* (write-behind) to the
//!   store, so pool pressure can demote it to…
//! * **cold** — on disk only: the weighted eviction
//!   ([`prefix::PrefixIndex::evict_victim`]) recycles the RAM copy,
//!   but the verified on-disk record keeps the content resolvable.  A
//!   prefix-index miss consults the store before re-encoding and
//!   *promotes* the page back (fresh allocation + full
//!   CRC/fingerprint/token re-verification); a promotion failure of
//!   any kind is a miss, never wrong bytes.
//!
//! On boot the store rescans its segments and rebuilds the cold
//! directory, so a restarted server adopts yesterday's system prompts
//! without re-encoding them (`[cache] persist_dir`).  With no store
//! attached (the default), nothing touches the filesystem and the
//! two-tier behavior is unchanged.

pub mod allocator;
pub mod manager;
pub mod page;
pub mod prefix;
pub mod radix;
pub mod store;

pub use allocator::{PageAllocator, PageId};
pub use manager::{CacheManager, GatherElem, GatherWorkspace, PrefixReuse, SeqId};
pub use page::{chain_key, Page, PageConfig, PrefixKey};
pub use prefix::{PrefixIndex, PrefixIndexKind};
pub use radix::RadixIndex;
pub use store::{FaultPlan, FaultyIo, PageStore, SegmentIo, StoreConfig, StoreStats};
