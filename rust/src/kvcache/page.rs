//! Compressed KV page: the unit of cache allocation.
//!
//! A page holds `tokens_per_page` token slots; each slot stores, for
//! every (layer, head), the stage-1 encoding of the K and V head vectors
//! (norm + packed codes, see `quant::pipeline::Stage1::encode`).  Pages
//! are fixed-size byte arrays so the allocator can pool them.

/// Geometry of the cached model + compression (fixed at engine boot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageConfig {
    pub tokens_per_page: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// bytes per encoded head vector (`Stage1::encoded_len`)
    pub encoded_len: usize,
}

impl PageConfig {
    /// bytes per token slot: L × H × 2 (K and V) encoded vectors
    pub fn slot_bytes(&self) -> usize {
        self.n_layers * self.n_heads * 2 * self.encoded_len
    }

    pub fn page_bytes(&self) -> usize {
        self.tokens_per_page * self.slot_bytes()
    }

    /// byte offset of the (slot, layer, head, is_v) encoded vector
    #[inline]
    pub fn offset(&self, slot: usize, layer: usize, head: usize, is_v: bool) -> usize {
        debug_assert!(slot < self.tokens_per_page);
        debug_assert!(layer < self.n_layers);
        debug_assert!(head < self.n_heads);
        ((slot * self.n_layers + layer) * self.n_heads + head) * 2 * self.encoded_len
            + if is_v { self.encoded_len } else { 0 }
    }

    /// uncompressed bytes per token slot (f32 K+V across layers/heads) —
    /// used for the compression-ratio counter
    pub fn slot_bytes_uncompressed(&self) -> usize {
        self.n_layers * self.n_heads * 2 * self.d_head * 4
    }
}

/// One fixed-size compressed page.
#[derive(Clone, Debug)]
pub struct Page {
    pub data: Vec<u8>,
}

impl Page {
    pub fn new(cfg: &PageConfig) -> Page {
        Page {
            data: vec![0u8; cfg.page_bytes()],
        }
    }

    pub fn slot_mut(&mut self, cfg: &PageConfig, slot: usize, layer: usize, head: usize, is_v: bool) -> &mut [u8] {
        let off = cfg.offset(slot, layer, head, is_v);
        &mut self.data[off..off + cfg.encoded_len]
    }

    pub fn slot(&self, cfg: &PageConfig, slot: usize, layer: usize, head: usize, is_v: bool) -> &[u8] {
        let off = cfg.offset(slot, layer, head, is_v);
        &self.data[off..off + cfg.encoded_len]
    }

    /// The (layer, head, K|V) *column* of this page: one encoded record
    /// per token slot, strided by [`PageConfig::slot_bytes`].  Returns
    /// `(bytes, stride)` in the exact shape
    /// `Stage1::decode_batch_strided` consumes — slot `t`'s record lives
    /// at `bytes[t * stride..t * stride + encoded_len]`.
    pub fn column(&self, cfg: &PageConfig, layer: usize, head: usize, is_v: bool) -> (&[u8], usize) {
        let off = cfg.offset(0, layer, head, is_v);
        (&self.data[off..], cfg.slot_bytes())
    }

    /// Zero the page (reuse hygiene — stale codes must not leak between
    /// sequences).
    pub fn clear(&mut self) {
        self.data.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PageConfig {
        PageConfig {
            tokens_per_page: 16,
            n_layers: 2,
            n_heads: 4,
            d_head: 64,
            encoded_len: 36, // e.g. 4-byte norm + 128 codes at 2 bits
        }
    }

    #[test]
    fn geometry() {
        let c = cfg();
        assert_eq!(c.slot_bytes(), 2 * 4 * 2 * 36);
        assert_eq!(c.page_bytes(), 16 * c.slot_bytes());
    }

    #[test]
    fn offsets_disjoint_and_in_bounds() {
        let c = cfg();
        let mut seen = std::collections::HashSet::new();
        for slot in 0..c.tokens_per_page {
            for l in 0..c.n_layers {
                for h in 0..c.n_heads {
                    for is_v in [false, true] {
                        let off = c.offset(slot, l, h, is_v);
                        assert!(off + c.encoded_len <= c.page_bytes());
                        assert!(seen.insert(off), "offset {off} reused");
                    }
                }
            }
        }
        // offsets tile the page exactly
        assert_eq!(seen.len() * c.encoded_len, c.page_bytes());
    }

    #[test]
    fn column_is_the_strided_slot_run() {
        let c = cfg();
        let mut p = Page::new(&c);
        for slot in 0..c.tokens_per_page {
            p.slot_mut(&c, slot, 1, 2, false).fill(slot as u8);
        }
        let (bytes, stride) = p.column(&c, 1, 2, false);
        assert_eq!(stride, c.slot_bytes());
        for slot in 0..c.tokens_per_page {
            assert_eq!(
                &bytes[slot * stride..slot * stride + c.encoded_len],
                p.slot(&c, slot, 1, 2, false)
            );
        }
    }

    #[test]
    fn slot_roundtrip() {
        let c = cfg();
        let mut p = Page::new(&c);
        p.slot_mut(&c, 3, 1, 2, true).copy_from_slice(&[7u8; 36]);
        assert_eq!(p.slot(&c, 3, 1, 2, true), &[7u8; 36]);
        assert_eq!(p.slot(&c, 3, 1, 2, false), &[0u8; 36]);
        p.clear();
        assert_eq!(p.slot(&c, 3, 1, 2, true), &[0u8; 36]);
    }
}
