//! Compressed KV page: the unit of cache allocation.
//!
//! A page holds `tokens_per_page` token slots; each slot stores, for
//! every (layer, head), the stage-1 encoding of the K and V head vectors
//! (norm + packed codes, see `quant::pipeline::Stage1::encode`).  Pages
//! are fixed-size byte arrays so the allocator can pool them.
//!
//! Pages are **open** while a sequence is still writing slots and become
//! **sealed** once their content is final (all slots filled, or a prompt
//! ended mid-page).  Sealed pages are immutable, which makes them
//! content-addressable: a sealed page whose slots encode a known run of
//! prompt tokens carries a [`PrefixKey`] — the chained hash of every
//! token id it covers plus the stage-1 config fingerprint — and can be
//! shared byte-for-byte between sequences (see `kvcache::prefix`).

/// Geometry of the cached model + compression (fixed at engine boot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageConfig {
    pub tokens_per_page: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// bytes per encoded head vector (`Stage1::encoded_len`)
    pub encoded_len: usize,
}

impl PageConfig {
    /// bytes per token slot: L × H × 2 (K and V) encoded vectors
    pub fn slot_bytes(&self) -> usize {
        self.n_layers * self.n_heads * 2 * self.encoded_len
    }

    pub fn page_bytes(&self) -> usize {
        self.tokens_per_page * self.slot_bytes()
    }

    /// byte offset of the (slot, layer, head, is_v) encoded vector
    #[inline]
    pub fn offset(&self, slot: usize, layer: usize, head: usize, is_v: bool) -> usize {
        debug_assert!(slot < self.tokens_per_page);
        debug_assert!(layer < self.n_layers);
        debug_assert!(head < self.n_heads);
        ((slot * self.n_layers + layer) * self.n_heads + head) * 2 * self.encoded_len
            + if is_v { self.encoded_len } else { 0 }
    }

    /// uncompressed bytes per token slot (f32 K+V across layers/heads) —
    /// used for the compression-ratio counter
    pub fn slot_bytes_uncompressed(&self) -> usize {
        self.n_layers * self.n_heads * 2 * self.d_head * 4
    }

    /// Byte range of the contiguous slot run `[slot0, slot0 + n)`.
    ///
    /// Slots are laid out slot-major (see [`PageConfig::offset`]), so a
    /// run of token slots is one contiguous byte window — which is what
    /// makes the radix index's *slot-range copy-on-write* a single
    /// `memcpy`: token position `t` always lives at slot
    /// `t % tokens_per_page`, so the same range means the same token
    /// positions in every page, and stage-1 encoding is deterministic,
    /// so copied slot bytes are identical to freshly re-encoded ones.
    pub fn slot_span(&self, slot0: usize, n: usize) -> std::ops::Range<usize> {
        debug_assert!(slot0 + n <= self.tokens_per_page);
        let sb = self.slot_bytes();
        slot0 * sb..(slot0 + n) * sb
    }
}

/// Content identity of a sealed prompt page: the chained hash of the
/// token ids the page (and every page before it) covers, mixed with the
/// stage-1 config fingerprint.  Equal keys ⇒ byte-identical page
/// contents (stage-1 encoding is deterministic given config + inputs),
/// which is what makes whole-page sharing pure bookkeeping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PrefixKey(pub u64);

/// Extend a prefix chain over the next run of token ids.  `parent` is
/// the key of the preceding full page (`None` for the first page);
/// `fingerprint` pins the stage-1 config + page geometry so caches with
/// different encodings never collide.  FNV-1a over (parent, fingerprint,
/// run length, token ids).
pub fn chain_key(parent: Option<PrefixKey>, tokens: &[i32], fingerprint: u64) -> PrefixKey {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    let mut h = OFFSET;
    h = fnv_u64(h, parent.map(|k| k.0).unwrap_or(0x9e37_79b9));
    h = fnv_u64(h, parent.is_some() as u64);
    h = fnv_u64(h, fingerprint);
    h = fnv_u64(h, tokens.len() as u64);
    for &t in tokens {
        h = fnv_u64(h, t as u32 as u64);
    }
    PrefixKey(h)
}

#[inline]
fn fnv_u64(mut h: u64, v: u64) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    for b in v.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    h
}

/// One fixed-size compressed page.
#[derive(Clone, Debug)]
pub struct Page {
    pub data: Vec<u8>,
    /// sealed pages are immutable (their bytes are final); only an open
    /// page may have slots written
    sealed: bool,
    /// content key, present only on sealed pages that encode a pure
    /// prompt prefix (the shareable ones)
    key: Option<PrefixKey>,
}

impl Page {
    pub fn new(cfg: &PageConfig) -> Page {
        Page {
            data: vec![0u8; cfg.page_bytes()],
            sealed: false,
            key: None,
        }
    }

    pub fn is_sealed(&self) -> bool {
        self.sealed
    }

    pub fn key(&self) -> Option<PrefixKey> {
        self.key
    }

    /// Freeze the page.  `key` is `Some` only for prompt-prefix pages
    /// that are candidates for sharing via the prefix index.
    pub fn seal(&mut self, key: Option<PrefixKey>) {
        debug_assert!(!self.sealed, "sealing an already-sealed page");
        self.sealed = true;
        self.key = key;
    }

    pub fn slot_mut(&mut self, cfg: &PageConfig, slot: usize, layer: usize, head: usize, is_v: bool) -> &mut [u8] {
        let off = cfg.offset(slot, layer, head, is_v);
        &mut self.data[off..off + cfg.encoded_len]
    }

    pub fn slot(&self, cfg: &PageConfig, slot: usize, layer: usize, head: usize, is_v: bool) -> &[u8] {
        let off = cfg.offset(slot, layer, head, is_v);
        &self.data[off..off + cfg.encoded_len]
    }

    /// The (layer, head, K|V) *column* of this page: one encoded record
    /// per token slot, strided by [`PageConfig::slot_bytes`].  Returns
    /// `(bytes, stride)` in the exact shape
    /// `Stage1::decode_batch_strided` consumes — slot `t`'s record lives
    /// at `bytes[t * stride..t * stride + encoded_len]`.
    pub fn column(&self, cfg: &PageConfig, layer: usize, head: usize, is_v: bool) -> (&[u8], usize) {
        let off = cfg.offset(0, layer, head, is_v);
        (&self.data[off..], cfg.slot_bytes())
    }

    /// Zero the page and reopen it (reuse hygiene — stale codes and a
    /// stale seal/key must not leak between sequences).
    pub fn clear(&mut self) {
        self.data.fill(0);
        self.sealed = false;
        self.key = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PageConfig {
        PageConfig {
            tokens_per_page: 16,
            n_layers: 2,
            n_heads: 4,
            d_head: 64,
            encoded_len: 36, // e.g. 4-byte norm + 128 codes at 2 bits
        }
    }

    #[test]
    fn geometry() {
        let c = cfg();
        assert_eq!(c.slot_bytes(), 2 * 4 * 2 * 36);
        assert_eq!(c.page_bytes(), 16 * c.slot_bytes());
    }

    #[test]
    fn offsets_disjoint_and_in_bounds() {
        let c = cfg();
        let mut seen = std::collections::HashSet::new();
        for slot in 0..c.tokens_per_page {
            for l in 0..c.n_layers {
                for h in 0..c.n_heads {
                    for is_v in [false, true] {
                        let off = c.offset(slot, l, h, is_v);
                        assert!(off + c.encoded_len <= c.page_bytes());
                        assert!(seen.insert(off), "offset {off} reused");
                    }
                }
            }
        }
        // offsets tile the page exactly
        assert_eq!(seen.len() * c.encoded_len, c.page_bytes());
    }

    #[test]
    fn slot_span_is_contiguous_and_slot_major() {
        let c = cfg();
        // the span of slots [3, 7) is exactly slots 3..7's offsets
        let span = c.slot_span(3, 4);
        assert_eq!(span.start, c.offset(3, 0, 0, false));
        assert_eq!(span.end, c.offset(7, 0, 0, false));
        assert_eq!(span.len(), 4 * c.slot_bytes());
        assert_eq!(c.slot_span(0, c.tokens_per_page), 0..c.page_bytes());
    }

    #[test]
    fn column_is_the_strided_slot_run() {
        let c = cfg();
        let mut p = Page::new(&c);
        for slot in 0..c.tokens_per_page {
            p.slot_mut(&c, slot, 1, 2, false).fill(slot as u8);
        }
        let (bytes, stride) = p.column(&c, 1, 2, false);
        assert_eq!(stride, c.slot_bytes());
        for slot in 0..c.tokens_per_page {
            assert_eq!(
                &bytes[slot * stride..slot * stride + c.encoded_len],
                p.slot(&c, slot, 1, 2, false)
            );
        }
    }

    #[test]
    fn seal_and_clear_lifecycle() {
        let c = cfg();
        let mut p = Page::new(&c);
        assert!(!p.is_sealed());
        assert!(p.key().is_none());
        let k = chain_key(None, &[1, 2, 3], 42);
        p.seal(Some(k));
        assert!(p.is_sealed());
        assert_eq!(p.key(), Some(k));
        p.clear();
        assert!(!p.is_sealed(), "clear must reopen the page");
        assert!(p.key().is_none(), "clear must drop the stale key");
    }

    #[test]
    fn chain_key_discriminates() {
        let fp = 0xF00D;
        let a = chain_key(None, &[1, 2, 3], fp);
        // same tokens, different parent / fingerprint / length → new key
        assert_ne!(a, chain_key(Some(a), &[1, 2, 3], fp));
        assert_ne!(a, chain_key(None, &[1, 2, 3], fp + 1));
        assert_ne!(a, chain_key(None, &[1, 2], fp));
        assert_ne!(a, chain_key(None, &[1, 2, 4], fp));
        // deterministic
        assert_eq!(a, chain_key(None, &[1, 2, 3], fp));
        // chaining is order-sensitive
        let ab = chain_key(Some(chain_key(None, &[1], fp)), &[2], fp);
        let ba = chain_key(Some(chain_key(None, &[2], fp)), &[1], fp);
        assert_ne!(ab, ba);
    }

    #[test]
    fn slot_roundtrip() {
        let c = cfg();
        let mut p = Page::new(&c);
        p.slot_mut(&c, 3, 1, 2, true).copy_from_slice(&[7u8; 36]);
        assert_eq!(p.slot(&c, 3, 1, 2, true), &[7u8; 36]);
        assert_eq!(p.slot(&c, 3, 1, 2, false), &[0u8; 36]);
        p.clear();
        assert_eq!(p.slot(&c, 3, 1, 2, true), &[0u8; 36]);
    }
}
