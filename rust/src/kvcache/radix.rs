//! Token-level radix tree over sealed prompt pages — the `radix` prefix
//! index (`[cache] prefix_index = radix`).
//!
//! Where the flat [`super::prefix::PrefixIndex`] maps whole-page chain
//! hashes to pages (and therefore cannot see a match shorter than a
//! page), this index stores the *token runs themselves* as a radix tree
//! in the style of vLLM/SGLang prefix caches:
//!
//! ```text
//!             root
//!              │ "the quick brown fox jumped over the lazy dog and "
//!              │                  one run-length node → pages [4, 5, 6]
//!              ├──────────────┐
//!   "kept running"       "fell asleep"       split at the divergence token:
//!   (page 7, 0..12)      (page 9, 0..11)     two prompts share the parent run
//! ```
//!
//! * Each **node** owns a run of token ids that may span several pages:
//!   the run carries one page sub-reference per page position it
//!   touches (`pages[i]` backs page position `start/tp + i`).  Token
//!   position `t` of the prompt always lives at slot `t % tokens_per_page`
//!   of its page, so slot ranges of different prompts line up and can
//!   be copied between pages verbatim.  Publishing consecutive pages of
//!   one prompt extends the node in place, collapsing a P-page stem
//!   into a single node and shrinking the LCP walk constant from P
//!   child hops to one token comparison loop.
//! * **Lookup** ([`RadixIndex::match_prefix`]) walks the
//!   longest-common-prefix of a prompt and returns the covered
//!   `(page, slot range)` segments — one per page piece, so the
//!   manager's adoption planner sees the same shape regardless of how
//!   runs are batched into nodes.  A match can end in the middle of a
//!   page and in the middle of a node (no mutation on lookup).
//! * **Insertion** ([`RadixIndex::insert`]) splits a node at the
//!   divergence token, so two prompts sharing 15 of 16 tail tokens end
//!   up as a shared 15-token parent with two 1-token children.  When
//!   the split lands mid-page the two halves *share* the boundary page
//!   (distinct slot ranges of one page).  The cache manager turns such
//!   a partial match into a *slot-range copy-on-write*
//!   (`CacheManager::start_seq_with_prompt`).
//! * **Re-pointing** ([`RadixIndex::repoint_span`]) swaps every sub-ref
//!   covering one whole page span to a freshly assembled page: after a
//!   CoW copy gathered the span's pieces into one page, exact repeats
//!   should adopt that page outright instead of re-copying the pieces.
//! * **Eviction** ([`RadixIndex::evict_victim`]) is hierarchical: the
//!   parked page with the lowest retention score
//!   `(reuse + 1) / (depth + 1)` goes first (ties: least recently
//!   parked), where `depth` is the page position of the *sub-ref*, so
//!   the tail pages of a long run still evict before its head.  Losing
//!   a node's leading page drops the node and its subtree; losing a
//!   trailing page merely truncates the run at the lost page (the head
//!   keeps matching).  Parked pages stranded by either cascade are
//!   freed in the same call.
//!
//! Like the flat index, this structure holds **no page refcounts** and
//! serves only verified data: a node stores the exact token ids it
//! covers, so matching is literal comparison — there is no hash to
//! collide.  Zero-ref pages park here (evictable, re-adoptable) exactly
//! as they do in the flat index; the manager's hot→warm→cold tiering
//! and the persistent store are index-agnostic (see
//! `CacheManager::fingerprint` and `kvcache::store`).

use std::collections::{BTreeMap, HashMap};

use super::allocator::PageId;
use super::prefix::SCORE_SCALE;

pub type NodeId = u32;

/// One radix node: a token run backed by one page sub-reference per
/// page position the run touches.
#[derive(Debug)]
struct Node {
    /// the token ids this node covers (may span page boundaries)
    tokens: Vec<i32>,
    /// absolute prompt position of `tokens[0]`
    start: usize,
    /// `pages[i]` holds the run's K/V for page position `start/tp + i`;
    /// the first and last entries may cover partial pages
    pages: Vec<PageId>,
    parent: Option<NodeId>,
    /// children keyed by the first token of their run
    children: HashMap<i32, NodeId>,
    /// adoptions credited to this node's pages since publish (the
    /// dominant retention-score term)
    reuse: u32,
}

/// One contiguous match segment returned by [`RadixIndex::match_prefix`]:
/// prompt tokens `[start, start + len)` are held by `page` at slots
/// `[slot0, slot0 + len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Seg {
    pub page: PageId,
    pub slot0: usize,
    pub len: usize,
    /// absolute prompt position of the segment's first token
    pub start: usize,
}

/// The token-level prefix index.  See the module docs for semantics.
#[derive(Debug, Default)]
pub struct RadixIndex {
    tp: usize,
    /// node slab; `None` = freed id
    nodes: Vec<Option<Node>>,
    free_ids: Vec<NodeId>,
    /// top-level runs keyed by their first token
    roots: HashMap<i32, NodeId>,
    /// page → nodes referencing (slot ranges of) it
    by_page: HashMap<PageId, Vec<NodeId>>,
    /// zero-ref indexed pages parked for eviction: page → queue slot
    parked: HashMap<PageId, (u64, u64)>,
    /// eviction order over the parked set: (score, park stamp) → page
    queue: BTreeMap<(u64, u64), PageId>,
    /// monotonic stamp source for the park-time tiebreak
    clock: u64,
    /// cap on `pages.len()` per node; 0 = unlimited.  `1` reproduces the
    /// v1 one-node-per-page shape (state-machine suite and benches
    /// compare the two shapes through this knob).
    max_run_pages: usize,
}

impl RadixIndex {
    pub fn new(tokens_per_page: usize) -> RadixIndex {
        RadixIndex {
            tp: tokens_per_page.max(1),
            ..RadixIndex::default()
        }
    }

    /// Cap node runs at `n` pages (0 = unlimited).  `1` reproduces the
    /// v1 one-node-per-page tree shape; only future inserts and merges
    /// are affected.
    pub fn set_max_run_pages(&mut self, n: usize) {
        self.max_run_pages = n;
    }

    /// Number of indexed pages (pages referenced by at least one node).
    pub fn len(&self) -> usize {
        self.by_page.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_page.is_empty()
    }

    /// Zero-ref (evictable) indexed pages.
    pub fn cached_len(&self) -> usize {
        self.parked.len()
    }

    /// Live node count (tests and stats).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    /// Whether any node references `page` (the radix analogue of the
    /// flat index's `is_indexed`).
    pub fn is_referenced(&self, page: PageId) -> bool {
        self.by_page.contains_key(&page)
    }

    fn node(&self, id: NodeId) -> &Node {
        self.nodes[id as usize].as_ref().expect("live node")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes[id as usize].as_mut().expect("live node")
    }

    fn alloc_node(&mut self, node: Node) -> NodeId {
        match self.free_ids.pop() {
            Some(id) => {
                self.nodes[id as usize] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as NodeId
            }
        }
    }

    /// Walk the longest common prefix of `prompt` through the tree.
    /// Returns the contiguous covered segments (token positions
    /// `[0, matched)`) and `matched` itself.  A run-length node emits
    /// one segment per page piece, so callers see the same shape as a
    /// one-node-per-page tree.  A match may end mid-node; nothing is
    /// mutated (splits happen only on insert).
    pub fn match_prefix(&self, prompt: &[i32]) -> (Vec<Seg>, usize) {
        let mut segs: Vec<Seg> = Vec::new();
        let mut pos = 0usize;
        let mut cur = prompt.first().and_then(|t| self.roots.get(t).copied());
        while let Some(id) = cur {
            let n = self.node(id);
            debug_assert_eq!(n.start, pos, "node position must equal walk position");
            let k = n
                .tokens
                .iter()
                .zip(&prompt[pos..])
                .take_while(|(a, b)| a == b)
                .count();
            let mut at = pos;
            while at < pos + k {
                let piece_end = (pos + k).min((at / self.tp + 1) * self.tp);
                segs.push(Seg {
                    page: n.pages[at / self.tp - n.start / self.tp],
                    slot0: at % self.tp,
                    len: piece_end - at,
                    start: at,
                });
                at = piece_end;
            }
            pos += k;
            if k < n.tokens.len() || pos >= prompt.len() {
                break;
            }
            cur = n.children.get(&prompt[pos]).copied();
        }
        (segs, pos)
    }

    /// Publish the run `prefix[start..]` (one page's worth of a prompt,
    /// `prefix` being the prompt's first `end` tokens) as backed by
    /// `page`.  The walk to position `start` must already be covered by
    /// the tree; if the whole run is already covered the existing nodes
    /// win (first-publisher-wins, like the flat index) and `false` is
    /// returned.  Splits the node at the divergence token when the run
    /// forks off mid-node.  A page-aligned run attaching to the end of
    /// a childless node *extends that node in place* (subject to
    /// [`RadixIndex::set_max_run_pages`]) instead of allocating a
    /// child, so sequentially published stems collapse into run-length
    /// nodes.  Returns `true` iff a node now references `page`.
    pub fn insert(&mut self, prefix: &[i32], start: usize, page: PageId) -> bool {
        let end = prefix.len();
        if start >= end {
            return false;
        }
        debug_assert_eq!(
            start / self.tp,
            (end - 1) / self.tp,
            "a published run must not cross a page boundary"
        );
        let mut pos = 0usize;
        let mut parent: Option<NodeId> = None;
        let mut cur = prefix.first().and_then(|t| self.roots.get(t).copied());
        while let Some(id) = cur {
            let (k, run_len) = {
                let n = self.node(id);
                let k = n
                    .tokens
                    .iter()
                    .zip(&prefix[pos..])
                    .take_while(|(a, b)| a == b)
                    .count();
                (k, n.tokens.len())
            };
            pos += k;
            if pos >= end {
                return false; // run already fully covered
            }
            if k == run_len {
                parent = Some(id);
                cur = self.node(id).children.get(&prefix[pos]).copied();
            } else {
                // diverges mid-node (k >= 1: roots/children are keyed by
                // their first token, so a found node always matches it)
                if pos < start {
                    return false; // ancestors of the run are missing
                }
                self.split(id, k);
                parent = Some(id);
                cur = None;
                break;
            }
        }
        if pos < start {
            return false; // ancestors of the run are missing
        }
        debug_assert!(cur.is_none());
        if pos == start && start % self.tp == 0 {
            if let Some(p) = parent {
                let can_extend = {
                    let n = self.node(p);
                    n.children.is_empty()
                        && n.start + n.tokens.len() == pos
                        && (self.max_run_pages == 0 || n.pages.len() < self.max_run_pages)
                };
                if can_extend {
                    let n = self.node_mut(p);
                    n.tokens.extend_from_slice(&prefix[pos..end]);
                    n.pages.push(page);
                    self.by_page.entry(page).or_default().push(p);
                    return true;
                }
            }
        }
        let nid = self.alloc_node(Node {
            tokens: prefix[pos..end].to_vec(),
            start: pos,
            pages: vec![page],
            parent,
            children: HashMap::new(),
            reuse: 0,
        });
        match parent {
            Some(p) => {
                self.node_mut(p).children.insert(prefix[pos], nid);
            }
            None => {
                self.roots.insert(prefix[pos], nid);
            }
        }
        self.by_page.entry(page).or_default().push(nid);
        true
    }

    /// Split node `id` after its first `k` tokens: the node keeps the
    /// head run, a new child takes the tail and inherits the children.
    /// Sub-refs past the cut move to the child; when the cut lands
    /// mid-page both halves share the boundary page (distinct slot
    /// ranges).  Reuse is inherited by both halves — the split is a
    /// representation change, not an adoption.
    fn split(&mut self, id: NodeId, k: usize) {
        debug_assert!(k >= 1);
        let (rest, start, tail_pages, reuse, children, shared_boundary) = {
            let tp = self.tp;
            let n = self.node_mut(id);
            debug_assert!(k < n.tokens.len());
            let rest = n.tokens.split_off(k);
            let fpp = n.start / tp;
            let head_last = (n.start + k - 1) / tp - fpp;
            let tail_first = (n.start + k) / tp - fpp;
            let tail_pages: Vec<PageId> = n.pages[tail_first..].to_vec();
            let shared_boundary = tail_first == head_last;
            n.pages.truncate(head_last + 1);
            (
                rest,
                n.start + k,
                tail_pages,
                n.reuse,
                std::mem::take(&mut n.children),
                shared_boundary,
            )
        };
        let first = rest[0];
        let child = self.alloc_node(Node {
            tokens: rest,
            start,
            pages: tail_pages,
            parent: Some(id),
            children,
            reuse,
        });
        let grand: Vec<NodeId> = self.node(child).children.values().copied().collect();
        for g in grand {
            self.node_mut(g).parent = Some(child);
        }
        self.node_mut(id).children.insert(first, child);
        let cpages: Vec<PageId> = self.node(child).pages.clone();
        for (i, pg) in cpages.iter().enumerate() {
            let list = self.by_page.entry(*pg).or_default();
            if !(i == 0 && shared_boundary) {
                // a page wholly in the tail changes owner: head → child
                list.retain(|&x| x != id);
            }
            list.push(child);
        }
    }

    /// Credit one adoption to every node referencing `page` (their
    /// reuse count is the dominant retention-score term).  Kept apart
    /// from [`RadixIndex::unpark`] so a pinned-then-abandoned walk does
    /// not inflate scores — the same split as the flat index.
    pub fn credit_page(&mut self, page: PageId) {
        if let Some(ids) = self.by_page.get(&page).cloned() {
            for id in ids {
                let n = self.node_mut(id);
                n.reuse = n.reuse.saturating_add(1);
            }
        }
    }

    /// Remove `page` from the evictable set (it is about to gain an
    /// owner, or must be protected while one is being arranged).
    pub fn unpark(&mut self, page: PageId) {
        if let Some(slot) = self.parked.remove(&page) {
            self.queue.remove(&slot);
        }
    }

    /// Park a zero-ref indexed page as cached/evictable, scored now
    /// from its nodes' current reuse counts (reuse only changes while
    /// adopted, i.e. while not parked).
    pub fn park(&mut self, page: PageId) {
        debug_assert!(self.is_referenced(page), "parking an unindexed page");
        let score = self.page_score(page);
        self.clock += 1;
        let slot = (score, self.clock);
        if let Some(old) = self.parked.insert(page, slot) {
            self.queue.remove(&old);
        }
        self.queue.insert(slot, page);
    }

    /// A page's retention score `(reuse + 1) / (depth + 1)` in
    /// [`SCORE_SCALE`] fixed point: the best score over the sub-refs
    /// holding it, where depth is the sub-ref's page position (a page
    /// serving a hot interior run must outlive its coldest leaf split).
    /// The store's segment compactor uses the same number to decide
    /// which spilled records are worth rescuing from a dying segment.
    pub fn page_score(&self, page: PageId) -> u64 {
        self.by_page
            .get(&page)
            .map(|ids| {
                ids.iter()
                    .map(|&id| {
                        let n = self.node(id);
                        let fpp = n.start / self.tp;
                        let pi = n.pages.iter().position(|&p| p == page).unwrap_or(0);
                        (n.reuse as u64 + 1) * SCORE_SCALE / ((fpp + pi) as u64 + 1)
                    })
                    .max()
                    .unwrap_or(0)
            })
            .unwrap_or(0)
    }

    /// Evict the lowest-scored parked page.  A node holding the victim
    /// as its *leading* page is dropped with its whole subtree
    /// (descendants of a dropped run can never be matched again); a
    /// node holding it as a *trailing* page is truncated at the victim
    /// so its head keeps matching.  Parked pages stranded by either
    /// cascade are freed too.  Returns every page the caller should
    /// recycle (victim first); empty when nothing is parked.
    pub fn evict_victim(&mut self) -> Vec<PageId> {
        let Some((_, page)) = self.queue.pop_first() else {
            return Vec::new();
        };
        self.parked.remove(&page);
        let mut freed = vec![page];
        if let Some(ids) = self.by_page.remove(&page) {
            for id in ids {
                if self.nodes[id as usize].is_none() {
                    continue; // already removed through an earlier cascade
                }
                match self.node(id).pages.iter().position(|&p| p == page) {
                    Some(0) | None => self.remove_subtree(id, &mut freed),
                    Some(pi) => self.truncate_node(id, pi, &mut freed),
                }
            }
        }
        freed
    }

    /// Drop the tail of `id`'s run from sub-ref `pi` on (its backing
    /// page is gone): the retained head keeps matching, while the
    /// trailing sub-refs and every child become unreachable.  Stranded
    /// parked pages go onto `freed`.
    fn truncate_node(&mut self, id: NodeId, pi: usize, freed: &mut Vec<PageId>) {
        debug_assert!(pi >= 1);
        let (dropped, children) = {
            let tp = self.tp;
            let n = self.node_mut(id);
            let keep = (n.start / tp + pi) * tp - n.start;
            debug_assert!(keep >= 1);
            n.tokens.truncate(keep);
            let dropped = n.pages.split_off(pi);
            (dropped, std::mem::take(&mut n.children))
        };
        // dropped[0] is the victim itself — the caller already removed
        // its by_page entry and pushed it onto the freed list
        for pg in dropped.into_iter().skip(1) {
            if let Some(list) = self.by_page.get_mut(&pg) {
                list.retain(|&x| x != id);
                if list.is_empty() {
                    self.by_page.remove(&pg);
                    if let Some(slot) = self.parked.remove(&pg) {
                        self.queue.remove(&slot);
                        freed.push(pg);
                    }
                }
            }
        }
        for c in children.into_values() {
            self.remove_subtree(c, freed);
        }
    }

    /// Remove `id` and its whole subtree, releasing page references.
    /// Any page whose last reference disappears while parked is pushed
    /// onto `freed` (it is unreachable for future matches).
    fn remove_subtree(&mut self, id: NodeId, freed: &mut Vec<PageId>) {
        if self.nodes[id as usize].is_none() {
            return; // already removed through an ancestor
        }
        // detach the subtree root from its parent (or the root table)
        let (parent, first) = {
            let n = self.node(id);
            (n.parent, n.tokens[0])
        };
        match parent {
            Some(p) if self.nodes[p as usize].is_some() => {
                self.node_mut(p).children.remove(&first);
            }
            Some(_) => {}
            None => {
                self.roots.remove(&first);
            }
        }
        let mut stack = vec![id];
        while let Some(i) = stack.pop() {
            let Some(n) = self.nodes[i as usize].take() else {
                continue;
            };
            self.free_ids.push(i);
            stack.extend(n.children.values().copied());
            for pg in &n.pages {
                if let Some(list) = self.by_page.get_mut(pg) {
                    list.retain(|&x| x != i);
                    if list.is_empty() {
                        self.by_page.remove(pg);
                        if let Some(slot) = self.parked.remove(pg) {
                            self.queue.remove(&slot);
                            freed.push(*pg);
                        }
                    }
                }
            }
        }
        // a parent left with a lone contiguous child collapses back into
        // one node (undo of a split whose other branch is gone)
        if let Some(p) = parent {
            self.try_merge(p);
        }
    }

    /// Merge `id` with its only child when the two runs are contiguous —
    /// the inverse of [`RadixIndex::split`].  A mid-page join requires
    /// both halves to sit on the same boundary page; a page-aligned
    /// join concatenates the sub-ref lists (respecting
    /// [`RadixIndex::set_max_run_pages`]).
    fn try_merge(&mut self, id: NodeId) {
        if self.nodes[id as usize].is_none() {
            return;
        }
        let (child_id, shared_boundary) = {
            let n = self.node(id);
            if n.children.len() != 1 {
                return;
            }
            let &c = n.children.values().next().unwrap();
            let cn = self.node(c);
            let end = n.start + n.tokens.len();
            if cn.start != end {
                return;
            }
            if end % self.tp != 0 {
                // mid-page join: only the undo of a split qualifies
                if cn.pages[0] != *n.pages.last().expect("non-empty run") {
                    return;
                }
                (c, true)
            } else {
                if self.max_run_pages != 0
                    && n.pages.len() + cn.pages.len() > self.max_run_pages
                {
                    return;
                }
                (c, false)
            }
        };
        let (cpages, ctokens, cchildren, creuse) = {
            let c = self.nodes[child_id as usize].take().expect("live child");
            self.free_ids.push(child_id);
            (c.pages, c.tokens, c.children, c.reuse)
        };
        for (i, pg) in cpages.iter().enumerate() {
            if let Some(list) = self.by_page.get_mut(pg) {
                list.retain(|&x| x != child_id);
                if !(i == 0 && shared_boundary) {
                    list.push(id);
                }
            }
        }
        {
            let n = self.node_mut(id);
            n.tokens.extend(ctokens);
            n.reuse = n.reuse.max(creuse);
            n.children = cchildren;
            let skip = if shared_boundary { 1 } else { 0 };
            n.pages.extend_from_slice(&cpages[skip..]);
        }
        let grand: Vec<NodeId> = self.node(id).children.values().copied().collect();
        for g in grand {
            self.node_mut(g).parent = Some(id);
        }
    }

    /// Re-point every sub-ref covering the whole page span
    /// `[start, start + tp)` of `prompt` at `page`.  The manager calls
    /// this after a slot-range CoW assembled a byte-identical copy of
    /// the span into `page`, so exact repeats of the prompt adopt the
    /// assembled page outright (a whole-page refcount hit) instead of
    /// re-running the same `copy_slots` fan-in.  No-op (empty return,
    /// no mutation) unless the tree's resident pieces cover the span
    /// exactly.  Returns the pages stranded by the switch — last
    /// reference gone while parked — which the caller must recycle.
    pub fn repoint_span(&mut self, prompt: &[i32], start: usize, page: PageId) -> Vec<PageId> {
        debug_assert_eq!(start % self.tp, 0, "repoint targets one whole page span");
        let span_end = start + self.tp;
        if prompt.len() < span_end {
            return Vec::new();
        }
        // walk the prompt collecting (node, sub-ref) pairs whose piece
        // lies inside the span; bail unless the span is fully covered
        let mut targets: Vec<(NodeId, usize)> = Vec::new();
        let mut covered = 0usize;
        let mut pos = 0usize;
        let mut cur = prompt.first().and_then(|t| self.roots.get(t).copied());
        while let Some(id) = cur {
            let n = self.node(id);
            let k = n
                .tokens
                .iter()
                .zip(&prompt[pos..])
                .take_while(|(a, b)| a == b)
                .count();
            let lo = pos.max(start);
            let hi = (pos + k).min(span_end);
            if lo < hi {
                targets.push((id, lo / self.tp - n.start / self.tp));
                covered += hi - lo;
            }
            pos += k;
            if k < n.tokens.len() || pos >= span_end {
                break;
            }
            cur = n.children.get(&prompt[pos]).copied();
        }
        if covered < self.tp {
            return Vec::new();
        }
        let mut stranded = Vec::new();
        for (id, pi) in targets {
            let old = self.node(id).pages[pi];
            if old == page {
                continue;
            }
            self.node_mut(id).pages[pi] = page;
            let list = self.by_page.entry(page).or_default();
            if !list.contains(&id) {
                list.push(id);
            }
            if let Some(list) = self.by_page.get_mut(&old) {
                list.retain(|&x| x != id);
                if list.is_empty() {
                    self.by_page.remove(&old);
                    if let Some(slot) = self.parked.remove(&old) {
                        self.queue.remove(&slot);
                        stranded.push(old);
                    }
                }
            }
        }
        stranded
    }

    /// The contiguous token run `page` holds and the full prompt prefix
    /// in front of it: `(start, run, prefix_tokens)` where the page
    /// covers prompt positions `[start, start + run.len())` and
    /// `prefix_tokens` are positions `[0, start)` collected from the
    /// holding nodes' head slices and ancestor chain.  This is what the
    /// persistence layer needs to serialize a parked page as an
    /// edge-aware store record (`parent key` over the prefix + the
    /// covered run) without re-deriving the chain.  With run-length
    /// nodes a page usually covers a *sub-run* of its node; the run may
    /// also start mid-page (a split point), which the store records as
    /// a sub-run extension.  `None` when the page is unindexed or its
    /// references are not one contiguous run.
    pub fn page_run(&self, page: PageId) -> Option<(usize, Vec<i32>, Vec<i32>)> {
        let ids = self.by_page.get(&page)?;
        // each holding node contributes the sub-span its sub-ref backs
        let mut pieces: Vec<(usize, usize, NodeId)> = Vec::new();
        for &i in ids {
            let n = self.node(i);
            let pi = n.pages.iter().position(|&p| p == page)?;
            let pp = n.start / self.tp + pi;
            let lo = n.start.max(pp * self.tp);
            let hi = (n.start + n.tokens.len()).min((pp + 1) * self.tp);
            pieces.push((lo, hi, i));
        }
        pieces.sort_by_key(|&(lo, _, _)| lo);
        let start = pieces[0].0;
        let mut run = Vec::new();
        let mut pos = start;
        for &(lo, hi, i) in &pieces {
            if lo != pos {
                return None; // non-contiguous references
            }
            let n = self.node(i);
            run.extend_from_slice(&n.tokens[lo - n.start..hi - n.start]);
            pos = hi;
        }
        // the prefix: the first holding node's own head slice plus its
        // ancestor chain
        let n0 = self.node(pieces[0].2);
        let mut parts: Vec<&[i32]> = vec![&n0.tokens[..start - n0.start]];
        let mut cur = n0.parent;
        while let Some(p) = cur {
            let n = self.node(p);
            parts.push(&n.tokens);
            cur = n.parent;
        }
        let mut prefix = Vec::with_capacity(start);
        for part in parts.into_iter().rev() {
            prefix.extend_from_slice(part);
        }
        if prefix.len() != start {
            return None; // defensive: broken ancestor chain
        }
        Some((start, run, prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// tp = 4 throughout; helper to build a run insert.
    fn idx() -> RadixIndex {
        RadixIndex::new(4)
    }

    /// the v1 tree shape: one node per page
    fn idx_v1() -> RadixIndex {
        let mut r = RadixIndex::new(4);
        r.set_max_run_pages(1);
        r
    }

    #[test]
    fn insert_and_match_whole_pages() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..8).collect();
        assert!(r.insert(&prompt[..4], 0, 10));
        assert!(r.insert(&prompt[..8], 4, 11));
        assert_eq!(r.len(), 2);
        assert_eq!(r.node_count(), 1, "a sequentially published stem is one node");
        let (segs, matched) = r.match_prefix(&prompt);
        assert_eq!(matched, 8);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 4, start: 0 },
                Seg { page: 11, slot0: 0, len: 4, start: 4 },
            ]
        );
        // a shorter prompt matches mid-node without mutation
        let (segs, matched) = r.match_prefix(&prompt[..6]);
        assert_eq!(matched, 6);
        assert_eq!(segs[1], Seg { page: 11, slot0: 0, len: 2, start: 4 });
        assert_eq!(r.node_count(), 1, "lookup must not split");
        // re-publishing covered content loses (first publisher wins)
        assert!(!r.insert(&prompt[..8], 4, 99));
        let (segs, _) = r.match_prefix(&prompt);
        assert_eq!(segs[1].page, 11);
    }

    #[test]
    fn v1_shape_keeps_one_node_per_page() {
        let mut r = idx_v1();
        let prompt: Vec<i32> = (0..8).collect();
        assert!(r.insert(&prompt[..4], 0, 10));
        assert!(r.insert(&prompt[..8], 4, 11));
        assert_eq!(r.node_count(), 2, "max_run_pages = 1 disables extension");
        let (segs, matched) = r.match_prefix(&prompt);
        assert_eq!(matched, 8);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].page, 10);
        assert_eq!(segs[1].page, 11);
    }

    #[test]
    fn a_sixteen_page_stem_is_one_node() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..64).collect();
        for p in 0..16 {
            assert!(r.insert(&prompt[..(p + 1) * 4], p * 4, 100 + p as PageId));
        }
        assert_eq!(r.node_count(), 1);
        assert_eq!(r.len(), 16);
        let (segs, matched) = r.match_prefix(&prompt);
        assert_eq!(matched, 64);
        assert_eq!(segs.len(), 16, "one segment per page piece");
        for (i, s) in segs.iter().enumerate() {
            assert_eq!(*s, Seg { page: 100 + i as PageId, slot0: 0, len: 4, start: i * 4 });
        }
    }

    #[test]
    fn insert_splits_at_the_divergence_token() {
        let mut r = idx();
        // page 10 covers tokens [0,1,2,3]; a second prompt shares 3 of 4
        let a: Vec<i32> = vec![5, 6, 7, 8];
        let b: Vec<i32> = vec![5, 6, 7, 9];
        assert!(r.insert(&a, 0, 10));
        assert!(r.insert(&b, 0, 20));
        // the shared head stays on page 10; both tails are 1-token
        // children at slot 3
        assert_eq!(r.node_count(), 3);
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 4);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 3, start: 0 },
                Seg { page: 10, slot0: 3, len: 1, start: 3 },
            ]
        );
        let (segs, matched) = r.match_prefix(&b);
        assert_eq!(matched, 4);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 3, start: 0 },
                Seg { page: 20, slot0: 3, len: 1, start: 3 },
            ]
        );
        // a third prompt diverging at token 0 becomes a new root
        let c: Vec<i32> = vec![1, 2, 3, 4];
        assert!(r.insert(&c, 0, 30));
        assert_eq!(r.match_prefix(&c).1, 4);
        assert_eq!(r.match_prefix(&[9, 9]).1, 0);
    }

    #[test]
    fn splitting_a_run_length_node_moves_the_tail_pages() {
        let mut r = idx();
        let a: Vec<i32> = (0..12).collect();
        r.insert(&a[..4], 0, 10);
        r.insert(&a[..8], 4, 11);
        r.insert(&a[..12], 8, 12);
        assert_eq!(r.node_count(), 1);
        // fork at token 6 (mid page 1): head [0..6) keeps pages 10+11,
        // tail [6..12) starts on the shared boundary page 11
        let mut b = a.clone();
        b[6] = 99;
        r.insert(&b[..8], 4, 20);
        assert_eq!(r.node_count(), 3);
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 12);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 4, start: 0 },
                Seg { page: 11, slot0: 0, len: 2, start: 4 },
                Seg { page: 11, slot0: 2, len: 2, start: 6 },
                Seg { page: 12, slot0: 0, len: 4, start: 8 },
            ]
        );
        let (segs, matched) = r.match_prefix(&b);
        assert_eq!(matched, 8);
        assert_eq!(segs.last().unwrap(), &Seg { page: 20, slot0: 2, len: 2, start: 6 });
        // page 11 is now shared by the head and the tail halves
        assert!(r.is_referenced(11));
        assert_eq!(r.page_run(11), Some((4, a[4..8].to_vec(), a[..4].to_vec())));
    }

    #[test]
    fn insert_requires_covered_ancestors() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..8).collect();
        // page 2's run cannot attach before page 1's run exists
        assert!(!r.insert(&prompt[..8], 4, 11));
        assert!(r.insert(&prompt[..4], 0, 10));
        assert!(r.insert(&prompt[..8], 4, 11));
        // a run attaching past a mid-node divergence is rejected too
        let mut fork = prompt.clone();
        fork[2] = 99;
        assert!(!r.insert(&fork[..8], 4, 12));
    }

    #[test]
    fn eviction_prefers_leaves_and_cascades() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..12).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        r.insert(&prompt[..12], 8, 12);
        assert_eq!(r.node_count(), 1);
        // park root-first: depth weighting must still evict the tail of
        // the run first, truncating rather than dropping the node
        r.park(10);
        r.park(11);
        r.park(12);
        assert_eq!(r.cached_len(), 3);
        assert_eq!(r.evict_victim(), vec![12], "deepest sub-ref goes first");
        assert_eq!(r.node_count(), 1, "losing a trailing page truncates");
        assert_eq!(r.match_prefix(&prompt).1, 8, "the head keeps matching");
        assert_eq!(r.evict_victim(), vec![11]);
        assert_eq!(r.evict_victim(), vec![10], "head page goes last");
        assert!(r.evict_victim().is_empty());
        assert_eq!(r.len(), 0);
        assert_eq!(r.node_count(), 0);
    }

    #[test]
    fn evicting_an_interior_page_frees_its_stranded_subtree() {
        let mut r = idx_v1();
        let prompt: Vec<i32> = (0..8).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        // only the interior page is parked; the leaf page is parked too
        // but with lots of reuse so the root is the victim
        r.credit_page(11);
        r.credit_page(11);
        r.credit_page(11);
        r.credit_page(11);
        r.park(10);
        r.park(11);
        // root's score (reuse 0, depth 0) = 1.0 < leaf's (reuse 4,
        // depth 1) = 2.5 → root evicts first and strands the leaf
        let freed = r.evict_victim();
        assert_eq!(freed, vec![10, 11], "cascade frees the stranded leaf");
        assert_eq!(r.len(), 0);
        assert_eq!(r.cached_len(), 0);
        assert_eq!(r.node_count(), 0);
    }

    #[test]
    fn losing_the_leading_page_of_a_run_drops_the_whole_node() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..8).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        assert_eq!(r.node_count(), 1);
        r.credit_page(11);
        r.credit_page(11);
        r.credit_page(11);
        r.credit_page(11);
        r.park(10);
        r.park(11);
        // page 10 backs the run's head: its sub-ref scores at depth 0
        // with the node-wide reuse, so park order decides via the
        // snapshot scores — page 10 parked at (5/1), page 11 at (5/2),
        // so the *tail* evicts first here; evicting the head page then
        // drops the node and strands nothing
        assert_eq!(r.evict_victim(), vec![11]);
        assert_eq!(r.node_count(), 1);
        assert_eq!(r.evict_victim(), vec![10]);
        assert_eq!(r.node_count(), 0);
        assert_eq!(r.len(), 0);
    }

    #[test]
    fn reuse_outweighs_depth() {
        let mut r = idx_v1();
        // one shallow cold page and one deep hot page
        let a: Vec<i32> = (0..4).collect();
        let b: Vec<i32> = (100..112).collect();
        r.insert(&a, 0, 10); // depth 0, cold
        r.insert(&b[..4], 0, 20);
        r.insert(&b[..8], 4, 21);
        r.insert(&b[..12], 8, 22); // depth 2
        for _ in 0..9 {
            r.credit_page(22); // hot leaf: (9+1)/(2+1) > (0+1)/(0+1)
        }
        r.park(10);
        r.park(22);
        assert_eq!(r.evict_victim(), vec![10], "cold root evicts before hot leaf");
    }

    #[test]
    fn sibling_eviction_merges_the_split_back() {
        let mut r = idx();
        let a: Vec<i32> = vec![5, 6, 7, 8];
        let b: Vec<i32> = vec![5, 6, 7, 9];
        r.insert(&a, 0, 10);
        r.insert(&b, 0, 20); // splits page 10's node at token 3
        assert_eq!(r.node_count(), 3);
        r.park(20);
        assert_eq!(r.evict_victim(), vec![20]);
        // page 10's head + tail halves merged back into one node
        assert_eq!(r.node_count(), 1);
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 4);
        assert_eq!(segs, vec![Seg { page: 10, slot0: 0, len: 4, start: 0 }]);
        assert_eq!(r.page_run(10), Some((0, a.clone(), vec![])));
    }

    #[test]
    fn sibling_eviction_merges_across_pages() {
        let mut r = idx();
        let a: Vec<i32> = (0..8).collect();
        let mut b = a.clone();
        b[4] = 99;
        r.insert(&a[..4], 0, 10);
        r.insert(&a[..8], 4, 11); // extends: one node, pages [10, 11]
        r.insert(&b[..8], 4, 20); // page-aligned fork: child under the run
        assert_eq!(r.node_count(), 3, "fork splits the run at the page boundary");
        r.park(20);
        assert_eq!(r.evict_victim(), vec![20]);
        // the page-aligned halves merge back into one run-length node
        assert_eq!(r.node_count(), 1);
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 8);
        assert_eq!(segs.len(), 2);
        assert_eq!(r.page_run(11), Some((4, a[4..8].to_vec(), a[..4].to_vec())));
    }

    #[test]
    fn unpark_protects_and_park_rescores() {
        let mut r = idx();
        let a: Vec<i32> = (0..4).collect();
        r.insert(&a, 0, 10);
        r.park(10);
        assert_eq!(r.cached_len(), 1);
        r.unpark(10);
        assert_eq!(r.cached_len(), 0);
        assert!(r.evict_victim().is_empty(), "unparked pages are protected");
        assert!(r.is_referenced(10), "unpark keeps the index entry");
        r.credit_page(10);
        r.park(10);
        assert_eq!(r.evict_victim(), vec![10]);
    }

    #[test]
    fn page_run_reports_the_chain_link() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..10).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        r.insert(&prompt[..10], 8, 12); // partial tail run, same node
        assert_eq!(r.node_count(), 1);
        assert_eq!(r.page_run(10), Some((0, prompt[..4].to_vec(), vec![])));
        assert_eq!(
            r.page_run(11),
            Some((4, prompt[4..8].to_vec(), prompt[..4].to_vec()))
        );
        assert_eq!(
            r.page_run(12),
            Some((8, prompt[8..10].to_vec(), prompt[..8].to_vec()))
        );
        assert_eq!(r.page_run(99), None);
        // a split page still reports one contiguous run
        let mut fork = prompt[..10].to_vec();
        fork[9] = 99;
        r.insert(&fork[..10], 8, 13);
        assert_eq!(r.page_run(12), Some((8, prompt[8..10].to_vec(), prompt[..8].to_vec())));
    }

    #[test]
    fn page_run_reports_a_mid_page_split_point() {
        // a run starting mid-page (slot 3) must round-trip through
        // page_run so the store can persist it as a sub-run record
        let mut r = idx();
        let a: Vec<i32> = vec![1, 2, 3, 4, 10, 11, 12, 13];
        let mut b = a.clone();
        b[7] = 99;
        r.insert(&a[..4], 0, 50);
        r.insert(&a[..8], 4, 51);
        r.insert(&b[..8], 7, 60); // CoW tail for the divergent prompt
        // page 60 covers positions [7, 8) — a sub-run starting at slot 3
        assert_eq!(r.page_run(60), Some((7, b[7..8].to_vec(), b[..7].to_vec())));
    }

    #[test]
    fn mid_page_divergence_segments_share_the_page() {
        // the 15-of-16 case from the module docs, at tp = 4: prompts
        // sharing 3 of 4 tail tokens must come back as one shared
        // 3-slot segment plus per-prompt 1-slot segments
        let mut r = idx();
        let a: Vec<i32> = vec![1, 2, 3, 4, 10, 11, 12, 13];
        let mut b = a.clone();
        b[7] = 99;
        r.insert(&a[..4], 0, 50);
        r.insert(&a[..8], 4, 51);
        let (segs, matched) = r.match_prefix(&b);
        assert_eq!(matched, 7, "LCP ends at the divergence token");
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[1], Seg { page: 51, slot0: 0, len: 3, start: 4 });
    }

    #[test]
    fn repoint_span_switches_fragmented_coverage_to_one_page() {
        let mut r = idx();
        let a: Vec<i32> = vec![1, 2, 3, 4, 10, 11, 12, 13];
        let mut b = a.clone();
        b[7] = 99;
        r.insert(&a[..4], 0, 50);
        r.insert(&a[..8], 4, 51);
        r.insert(&b[..8], 7, 60); // b's page 1 is split across 51 + 60
        let (segs, _) = r.match_prefix(&b);
        assert_eq!(segs.len(), 3, "fragmented span before repoint");
        // the manager assembled page 70 = slots 0..3 of 51 + slot 3 of 60
        let stranded = r.repoint_span(&b, 4, 70);
        assert!(stranded.is_empty(), "51 and 60 keep other references");
        let (segs, matched) = r.match_prefix(&b);
        assert_eq!(matched, 8);
        assert_eq!(
            &segs[1..],
            &[Seg { page: 70, slot0: 0, len: 3, start: 4 }, Seg { page: 70, slot0: 3, len: 1, start: 7 }],
            "the whole span now sits on the assembled page"
        );
        // a's walk is also served by 70 for the shared [4,7) piece —
        // byte-identical by construction — while its tail stays on 51
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 8);
        assert_eq!(segs[1].page, 70);
        assert_eq!(segs[2], Seg { page: 51, slot0: 3, len: 1, start: 7 });
        assert!(r.is_referenced(51), "51 still backs a's tail slot");
        // repointing a's span too drops 51's last reference; it was
        // never parked, so nothing is stranded
        let stranded = r.repoint_span(&a, 4, 71);
        assert_eq!(stranded, Vec::<PageId>::new());
        assert!(!r.is_referenced(51));
    }

    #[test]
    fn repoint_span_refuses_partial_coverage_and_frees_stranded_pages() {
        let mut r = idx();
        let a: Vec<i32> = (0..8).collect();
        r.insert(&a[..4], 0, 10);
        r.insert(&a[..6], 4, 11);
        // span [4, 8) is only covered up to 6 → refuse
        assert!(r.repoint_span(&a, 4, 70).is_empty());
        let (segs, _) = r.match_prefix(&a[..6]);
        assert_eq!(segs[1].page, 11, "no mutation on refusal");
        // cover the span fully, park 11, then repoint: 11 is stranded
        assert!(r.insert(&a[..8], 6, 12));
        r.park(11);
        let stranded = r.repoint_span(&a, 4, 70);
        assert_eq!(stranded, vec![11], "parked page with no refs left is freed");
        assert!(!r.is_referenced(11));
        assert!(r.is_referenced(70));
        assert_eq!(r.cached_len(), 0);
    }
}
