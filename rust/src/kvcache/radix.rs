//! Token-level radix tree over sealed prompt pages — the `radix` prefix
//! index (`[cache] prefix_index = radix`).
//!
//! Where the flat [`super::prefix::PrefixIndex`] maps whole-page chain
//! hashes to pages (and therefore cannot see a match shorter than a
//! page), this index stores the *token runs themselves* as a radix tree
//! in the style of vLLM/SGLang prefix caches:
//!
//! ```text
//!             root
//!              │ "the quick brown fox "      node run → (page 4, slots 0..16)
//!              ├──────────────┐
//!   "jumps over"       "walks under"         split at the divergence token:
//!   (page 7, 0..10)    (page 9, 0..11)       two prompts share the parent run
//! ```
//!
//! * Each **node** owns a run of token ids that never crosses a page
//!   boundary, plus the page (and slot range inside it) holding that
//!   run's stage-1 encoded K/V.  Token position `t` of the prompt always
//!   lives at slot `t % tokens_per_page` of its page, so slot ranges of
//!   different prompts line up and can be copied between pages verbatim.
//! * **Lookup** ([`RadixIndex::match_prefix`]) walks the
//!   longest-common-prefix of a prompt and returns the covered
//!   `(page, slot range)` segments — a match can end in the middle of a
//!   page (the flat index can only answer per whole page) and in the
//!   middle of a node (no mutation on lookup).
//! * **Insertion** ([`RadixIndex::insert`]) splits a node at the
//!   divergence token, so two prompts sharing 15 of 16 tail tokens end
//!   up as a shared 15-token parent with two 1-token children.  The
//!   cache manager turns such a partial match into a *slot-range
//!   copy-on-write*: it copies the 15 shared slots out of the indexed
//!   page and re-encodes only the divergent suffix
//!   (`CacheManager::start_seq_with_prompt`).
//! * **Eviction** ([`RadixIndex::evict_victim`]) is hierarchical: the
//!   parked page with the lowest retention score
//!   `(reuse + 1) / (depth + 1)` goes first (ties: least recently
//!   parked), which makes leaves evict before the interior runs every
//!   descendant depends on.  Evicting a page drops every node that
//!   references it *and their subtrees* — a child whose ancestor run is
//!   gone can never be matched again, so any parked pages stranded by
//!   the cascade are freed in the same call.
//!
//! Like the flat index, this structure holds **no page refcounts** and
//! serves only verified data: a node stores the exact token ids it
//! covers, so matching is literal comparison — there is no hash to
//! collide.  Zero-ref pages park here (evictable, re-adoptable) exactly
//! as they do in the flat index; the manager's hot→warm→cold tiering
//! and the persistent store are index-agnostic (see
//! `CacheManager::fingerprint` and `kvcache::store`).

use std::collections::{BTreeMap, HashMap};

use super::allocator::PageId;

/// Fixed-point scale of the retention score (keeps the reuse/depth
/// ratio meaningful in integer math); matches the flat index.
const SCORE_SCALE: u64 = 1 << 16;

pub type NodeId = u32;

/// One radix node: a token run backed by a slot range of one page.
#[derive(Debug)]
struct Node {
    /// the token ids this node covers (never crosses a page boundary)
    tokens: Vec<i32>,
    /// absolute prompt position of `tokens[0]`; the run occupies slots
    /// `start % tokens_per_page ..` of `page`
    start: usize,
    /// page holding this run's encoded K/V
    page: PageId,
    parent: Option<NodeId>,
    /// children keyed by the first token of their run
    children: HashMap<i32, NodeId>,
    /// adoptions credited to this node's page since publish (the
    /// dominant retention-score term)
    reuse: u32,
}

impl Node {
    /// Retention weight: bigger = keep longer.  `depth` is the page
    /// position (`start / tokens_per_page`) so scores are comparable
    /// with the flat index's.
    fn score(&self, tp: usize) -> u64 {
        (self.reuse as u64 + 1) * SCORE_SCALE / ((self.start / tp) as u64 + 1)
    }
}

/// One contiguous match segment returned by [`RadixIndex::match_prefix`]:
/// prompt tokens `[start, start + len)` are held by `page` at slots
/// `[slot0, slot0 + len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Seg {
    pub page: PageId,
    pub slot0: usize,
    pub len: usize,
    /// absolute prompt position of the segment's first token
    pub start: usize,
}

/// The token-level prefix index.  See the module docs for semantics.
#[derive(Debug, Default)]
pub struct RadixIndex {
    tp: usize,
    /// node slab; `None` = freed id
    nodes: Vec<Option<Node>>,
    free_ids: Vec<NodeId>,
    /// top-level runs keyed by their first token
    roots: HashMap<i32, NodeId>,
    /// page → nodes referencing (slot ranges of) it
    by_page: HashMap<PageId, Vec<NodeId>>,
    /// zero-ref indexed pages parked for eviction: page → queue slot
    parked: HashMap<PageId, (u64, u64)>,
    /// eviction order over the parked set: (score, park stamp) → page
    queue: BTreeMap<(u64, u64), PageId>,
    /// monotonic stamp source for the park-time tiebreak
    clock: u64,
}

impl RadixIndex {
    pub fn new(tokens_per_page: usize) -> RadixIndex {
        RadixIndex {
            tp: tokens_per_page.max(1),
            ..RadixIndex::default()
        }
    }

    /// Number of indexed pages (pages referenced by at least one node).
    pub fn len(&self) -> usize {
        self.by_page.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_page.is_empty()
    }

    /// Zero-ref (evictable) indexed pages.
    pub fn cached_len(&self) -> usize {
        self.parked.len()
    }

    /// Live node count (tests and stats).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    /// Whether any node references `page` (the radix analogue of the
    /// flat index's `is_indexed`).
    pub fn is_referenced(&self, page: PageId) -> bool {
        self.by_page.contains_key(&page)
    }

    fn node(&self, id: NodeId) -> &Node {
        self.nodes[id as usize].as_ref().expect("live node")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes[id as usize].as_mut().expect("live node")
    }

    fn alloc_node(&mut self, node: Node) -> NodeId {
        match self.free_ids.pop() {
            Some(id) => {
                self.nodes[id as usize] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as NodeId
            }
        }
    }

    /// Walk the longest common prefix of `prompt` through the tree.
    /// Returns the contiguous covered segments (token positions
    /// `[0, matched)`) and `matched` itself.  A match may end mid-node;
    /// nothing is mutated (splits happen only on insert).
    pub fn match_prefix(&self, prompt: &[i32]) -> (Vec<Seg>, usize) {
        let mut segs: Vec<Seg> = Vec::new();
        let mut pos = 0usize;
        let mut cur = prompt.first().and_then(|t| self.roots.get(t).copied());
        while let Some(id) = cur {
            let n = self.node(id);
            debug_assert_eq!(n.start, pos, "node position must equal walk position");
            let k = n
                .tokens
                .iter()
                .zip(&prompt[pos..])
                .take_while(|(a, b)| a == b)
                .count();
            if k > 0 {
                segs.push(Seg {
                    page: n.page,
                    slot0: n.start % self.tp,
                    len: k,
                    start: pos,
                });
                pos += k;
            }
            if k < n.tokens.len() || pos >= prompt.len() {
                break;
            }
            cur = n.children.get(&prompt[pos]).copied();
        }
        (segs, pos)
    }

    /// Publish the run `prefix[start..]` (one page's worth of a prompt,
    /// `prefix` being the prompt's first `end` tokens) as backed by
    /// `page`.  The walk to position `start` must already be covered by
    /// the tree; if the whole run is already covered the existing nodes
    /// win (first-publisher-wins, like the flat index) and `false` is
    /// returned.  Splits the node at the divergence token when the run
    /// forks off mid-node.  Returns `true` iff a new node now
    /// references `page`.
    pub fn insert(&mut self, prefix: &[i32], start: usize, page: PageId) -> bool {
        let end = prefix.len();
        if start >= end {
            return false;
        }
        debug_assert_eq!(
            start / self.tp,
            (end - 1) / self.tp,
            "a published run must not cross a page boundary"
        );
        let mut pos = 0usize;
        let mut parent: Option<NodeId> = None;
        let mut cur = prefix.first().and_then(|t| self.roots.get(t).copied());
        while let Some(id) = cur {
            let (k, run_len) = {
                let n = self.node(id);
                let k = n
                    .tokens
                    .iter()
                    .zip(&prefix[pos..])
                    .take_while(|(a, b)| a == b)
                    .count();
                (k, n.tokens.len())
            };
            pos += k;
            if pos >= end {
                return false; // run already fully covered
            }
            if k == run_len {
                parent = Some(id);
                cur = self.node(id).children.get(&prefix[pos]).copied();
            } else {
                // diverges mid-node (k >= 1: roots/children are keyed by
                // their first token, so a found node always matches it)
                if pos < start {
                    return false; // ancestors of the run are missing
                }
                self.split(id, k);
                parent = Some(id);
                cur = None;
                break;
            }
        }
        if pos < start {
            return false; // ancestors of the run are missing
        }
        debug_assert!(cur.is_none());
        let nid = self.alloc_node(Node {
            tokens: prefix[pos..end].to_vec(),
            start: pos,
            page,
            parent,
            children: HashMap::new(),
            reuse: 0,
        });
        match parent {
            Some(p) => {
                self.node_mut(p).children.insert(prefix[pos], nid);
            }
            None => {
                self.roots.insert(prefix[pos], nid);
            }
        }
        self.by_page.entry(page).or_default().push(nid);
        true
    }

    /// Split node `id` after its first `k` tokens: the node keeps the
    /// head run, a new child (same page, shifted slot range) takes the
    /// tail and inherits the children.  Reuse is inherited by both
    /// halves — the split is a representation change, not an adoption.
    fn split(&mut self, id: NodeId, k: usize) {
        debug_assert!(k >= 1);
        let (rest, start, page, reuse, children) = {
            let n = self.node_mut(id);
            debug_assert!(k < n.tokens.len());
            let rest = n.tokens.split_off(k);
            (
                rest,
                n.start + k,
                n.page,
                n.reuse,
                std::mem::take(&mut n.children),
            )
        };
        let first = rest[0];
        let child = self.alloc_node(Node {
            tokens: rest,
            start,
            page,
            parent: Some(id),
            children,
            reuse,
        });
        let grand: Vec<NodeId> = self.node(child).children.values().copied().collect();
        for g in grand {
            self.node_mut(g).parent = Some(child);
        }
        self.node_mut(id).children.insert(first, child);
        self.by_page.entry(page).or_default().push(child);
    }

    /// Credit one adoption to every node referencing `page` (their
    /// reuse count is the dominant retention-score term).  Kept apart
    /// from [`RadixIndex::unpark`] so a pinned-then-abandoned walk does
    /// not inflate scores — the same split as the flat index.
    pub fn credit_page(&mut self, page: PageId) {
        if let Some(ids) = self.by_page.get(&page).cloned() {
            for id in ids {
                let n = self.node_mut(id);
                n.reuse = n.reuse.saturating_add(1);
            }
        }
    }

    /// Remove `page` from the evictable set (it is about to gain an
    /// owner, or must be protected while one is being arranged).
    pub fn unpark(&mut self, page: PageId) {
        if let Some(slot) = self.parked.remove(&page) {
            self.queue.remove(&slot);
        }
    }

    /// Park a zero-ref indexed page as cached/evictable, scored now
    /// from its nodes' current reuse counts (reuse only changes while
    /// adopted, i.e. while not parked).
    pub fn park(&mut self, page: PageId) {
        debug_assert!(self.is_referenced(page), "parking an unindexed page");
        let score = self.page_score(page);
        self.clock += 1;
        let slot = (score, self.clock);
        if let Some(old) = self.parked.insert(page, slot) {
            self.queue.remove(&old);
        }
        self.queue.insert(slot, page);
    }

    /// A page's retention score: the best score over its nodes (a page
    /// serving a hot interior run must outlive its coldest leaf split).
    fn page_score(&self, page: PageId) -> u64 {
        self.by_page
            .get(&page)
            .map(|ids| {
                ids.iter()
                    .map(|&id| self.node(id).score(self.tp))
                    .max()
                    .unwrap_or(0)
            })
            .unwrap_or(0)
    }

    /// Evict the lowest-scored parked page and drop every node that
    /// references it, cascading through their subtrees (descendants of
    /// a dropped run can never be matched again).  Parked pages
    /// stranded by the cascade are freed too.  Returns every page the
    /// caller should recycle (victim first); empty when nothing is
    /// parked.
    pub fn evict_victim(&mut self) -> Vec<PageId> {
        let Some((_, page)) = self.queue.pop_first() else {
            return Vec::new();
        };
        self.parked.remove(&page);
        let mut freed = vec![page];
        if let Some(ids) = self.by_page.remove(&page) {
            for id in ids {
                self.remove_subtree(id, &mut freed);
            }
        }
        freed
    }

    /// Remove `id` and its whole subtree, releasing page references.
    /// Any page whose last reference disappears while parked is pushed
    /// onto `freed` (it is unreachable for future matches).
    fn remove_subtree(&mut self, id: NodeId, freed: &mut Vec<PageId>) {
        if self.nodes[id as usize].is_none() {
            return; // already removed through an ancestor
        }
        // detach the subtree root from its parent (or the root table)
        let (parent, first) = {
            let n = self.node(id);
            (n.parent, n.tokens[0])
        };
        match parent {
            Some(p) if self.nodes[p as usize].is_some() => {
                self.node_mut(p).children.remove(&first);
            }
            Some(_) => {}
            None => {
                self.roots.remove(&first);
            }
        }
        let mut stack = vec![id];
        while let Some(i) = stack.pop() {
            let Some(n) = self.nodes[i as usize].take() else {
                continue;
            };
            self.free_ids.push(i);
            stack.extend(n.children.values().copied());
            if let Some(list) = self.by_page.get_mut(&n.page) {
                list.retain(|&x| x != i);
                if list.is_empty() {
                    self.by_page.remove(&n.page);
                    if let Some(slot) = self.parked.remove(&n.page) {
                        self.queue.remove(&slot);
                        freed.push(n.page);
                    }
                }
            }
        }
        // a parent left with a lone same-page child collapses back into
        // one node (undo of a split whose other branch is gone)
        if let Some(p) = parent {
            self.try_merge(p);
        }
    }

    /// Merge `id` with its only child when both halves live on the same
    /// page and cover contiguous tokens — the inverse of
    /// [`RadixIndex::split`].
    fn try_merge(&mut self, id: NodeId) {
        if self.nodes[id as usize].is_none() {
            return;
        }
        let child_id = {
            let n = self.node(id);
            if n.children.len() != 1 {
                return;
            }
            let &c = n.children.values().next().unwrap();
            let cn = self.node(c);
            if cn.page != n.page || cn.start != n.start + n.tokens.len() {
                return;
            }
            c
        };
        let (page, ctokens, cchildren, creuse) = {
            let c = self.nodes[child_id as usize].take().expect("live child");
            self.free_ids.push(child_id);
            (c.page, c.tokens, c.children, c.reuse)
        };
        if let Some(list) = self.by_page.get_mut(&page) {
            list.retain(|&x| x != child_id);
        }
        {
            let n = self.node_mut(id);
            n.tokens.extend(ctokens);
            n.reuse = n.reuse.max(creuse);
            n.children = cchildren;
        }
        let grand: Vec<NodeId> = self.node(id).children.values().copied().collect();
        for g in grand {
            self.node_mut(g).parent = Some(id);
        }
    }

    /// The contiguous token run `page` holds and the full prompt prefix
    /// in front of it: `(start, run, prefix_tokens)` where the page
    /// covers prompt positions `[start, start + run.len())` and
    /// `prefix_tokens` are positions `[0, start)` collected from the
    /// ancestor chain.  This is what the persistence layer needs to
    /// serialize a parked page as an edge-aware store record
    /// (`parent key` over the prefix + the covered run) without
    /// re-deriving the chain.  `None` when the page is unindexed or its
    /// references are not one contiguous run.
    pub fn page_run(&self, page: PageId) -> Option<(usize, Vec<i32>, Vec<i32>)> {
        let ids = self.by_page.get(&page)?;
        let mut nodes: Vec<&Node> = ids.iter().map(|&i| self.node(i)).collect();
        nodes.sort_by_key(|n| n.start);
        let start = nodes[0].start;
        let mut run = Vec::new();
        let mut pos = start;
        for n in &nodes {
            if n.start != pos {
                return None; // non-contiguous references
            }
            run.extend_from_slice(&n.tokens);
            pos += n.tokens.len();
        }
        let mut parts: Vec<&[i32]> = Vec::new();
        let mut cur = nodes[0].parent;
        while let Some(p) = cur {
            let n = self.node(p);
            parts.push(&n.tokens);
            cur = n.parent;
        }
        let mut prefix = Vec::with_capacity(start);
        for part in parts.into_iter().rev() {
            prefix.extend_from_slice(part);
        }
        if prefix.len() != start {
            return None; // defensive: broken ancestor chain
        }
        Some((start, run, prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// tp = 4 throughout; helper to build a run insert.
    fn idx() -> RadixIndex {
        RadixIndex::new(4)
    }

    #[test]
    fn insert_and_match_whole_pages() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..8).collect();
        assert!(r.insert(&prompt[..4], 0, 10));
        assert!(r.insert(&prompt[..8], 4, 11));
        assert_eq!(r.len(), 2);
        assert_eq!(r.node_count(), 2);
        let (segs, matched) = r.match_prefix(&prompt);
        assert_eq!(matched, 8);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 4, start: 0 },
                Seg { page: 11, slot0: 0, len: 4, start: 4 },
            ]
        );
        // a shorter prompt matches mid-node without mutation
        let (segs, matched) = r.match_prefix(&prompt[..6]);
        assert_eq!(matched, 6);
        assert_eq!(segs[1], Seg { page: 11, slot0: 0, len: 2, start: 4 });
        assert_eq!(r.node_count(), 2, "lookup must not split");
        // re-publishing covered content loses (first publisher wins)
        assert!(!r.insert(&prompt[..8], 4, 99));
        let (segs, _) = r.match_prefix(&prompt);
        assert_eq!(segs[1].page, 11);
    }

    #[test]
    fn insert_splits_at_the_divergence_token() {
        let mut r = idx();
        // page 10 covers tokens [0,1,2,3]; a second prompt shares 3 of 4
        let a: Vec<i32> = vec![5, 6, 7, 8];
        let b: Vec<i32> = vec![5, 6, 7, 9];
        assert!(r.insert(&a, 0, 10));
        assert!(r.insert(&b, 0, 20));
        // the shared head stays on page 10; both tails are 1-token
        // children at slot 3
        assert_eq!(r.node_count(), 3);
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 4);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 3, start: 0 },
                Seg { page: 10, slot0: 3, len: 1, start: 3 },
            ]
        );
        let (segs, matched) = r.match_prefix(&b);
        assert_eq!(matched, 4);
        assert_eq!(
            segs,
            vec![
                Seg { page: 10, slot0: 0, len: 3, start: 0 },
                Seg { page: 20, slot0: 3, len: 1, start: 3 },
            ]
        );
        // a third prompt diverging at token 0 becomes a new root
        let c: Vec<i32> = vec![1, 2, 3, 4];
        assert!(r.insert(&c, 0, 30));
        assert_eq!(r.match_prefix(&c).1, 4);
        assert_eq!(r.match_prefix(&[9, 9]).1, 0);
    }

    #[test]
    fn insert_requires_covered_ancestors() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..8).collect();
        // page 2's run cannot attach before page 1's run exists
        assert!(!r.insert(&prompt[..8], 4, 11));
        assert!(r.insert(&prompt[..4], 0, 10));
        assert!(r.insert(&prompt[..8], 4, 11));
        // a run attaching past a mid-node divergence is rejected too
        let mut fork = prompt.clone();
        fork[2] = 99;
        assert!(!r.insert(&fork[..8], 4, 12));
    }

    #[test]
    fn eviction_prefers_leaves_and_cascades() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..12).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        r.insert(&prompt[..12], 8, 12);
        // park root-first: depth weighting must still evict the leaf
        r.park(10);
        r.park(11);
        r.park(12);
        assert_eq!(r.cached_len(), 3);
        assert_eq!(r.evict_victim(), vec![12], "leaf goes first");
        assert_eq!(r.evict_victim(), vec![11]);
        assert_eq!(r.evict_victim(), vec![10], "root goes last");
        assert!(r.evict_victim().is_empty());
        assert_eq!(r.len(), 0);
        assert_eq!(r.node_count(), 0);
    }

    #[test]
    fn evicting_an_interior_page_frees_its_stranded_subtree() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..8).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        // only the interior page is parked; the leaf page is parked too
        // but with lots of reuse so the root is the victim
        r.credit_page(11);
        r.credit_page(11);
        r.credit_page(11);
        r.credit_page(11);
        r.park(10);
        r.park(11);
        // root's score (reuse 0, depth 0) = 1.0 < leaf's (reuse 4,
        // depth 1) = 2.5 → root evicts first and strands the leaf
        let freed = r.evict_victim();
        assert_eq!(freed, vec![10, 11], "cascade frees the stranded leaf");
        assert_eq!(r.len(), 0);
        assert_eq!(r.cached_len(), 0);
        assert_eq!(r.node_count(), 0);
    }

    #[test]
    fn reuse_outweighs_depth() {
        let mut r = idx();
        // two independent roots at different depths... same depth here,
        // so build one shallow cold page and one deep hot page
        let a: Vec<i32> = (0..4).collect();
        let b: Vec<i32> = (100..112).collect();
        r.insert(&a, 0, 10); // depth 0, cold
        r.insert(&b[..4], 0, 20);
        r.insert(&b[..8], 4, 21);
        r.insert(&b[..12], 8, 22); // depth 2
        for _ in 0..9 {
            r.credit_page(22); // hot leaf: (9+1)/(2+1) > (0+1)/(0+1)
        }
        r.park(10);
        r.park(22);
        assert_eq!(r.evict_victim(), vec![10], "cold root evicts before hot leaf");
    }

    #[test]
    fn sibling_eviction_merges_the_split_back() {
        let mut r = idx();
        let a: Vec<i32> = vec![5, 6, 7, 8];
        let b: Vec<i32> = vec![5, 6, 7, 9];
        r.insert(&a, 0, 10);
        r.insert(&b, 0, 20); // splits page 10's node at token 3
        assert_eq!(r.node_count(), 3);
        r.park(20);
        assert_eq!(r.evict_victim(), vec![20]);
        // page 10's head + tail halves merged back into one node
        assert_eq!(r.node_count(), 1);
        let (segs, matched) = r.match_prefix(&a);
        assert_eq!(matched, 4);
        assert_eq!(segs, vec![Seg { page: 10, slot0: 0, len: 4, start: 0 }]);
        assert_eq!(r.page_run(10), Some((0, a.clone(), vec![])));
    }

    #[test]
    fn unpark_protects_and_park_rescores() {
        let mut r = idx();
        let a: Vec<i32> = (0..4).collect();
        r.insert(&a, 0, 10);
        r.park(10);
        assert_eq!(r.cached_len(), 1);
        r.unpark(10);
        assert_eq!(r.cached_len(), 0);
        assert!(r.evict_victim().is_empty(), "unparked pages are protected");
        assert!(r.is_referenced(10), "unpark keeps the index entry");
        r.credit_page(10);
        r.park(10);
        assert_eq!(r.evict_victim(), vec![10]);
    }

    #[test]
    fn page_run_reports_the_chain_link() {
        let mut r = idx();
        let prompt: Vec<i32> = (0..10).collect();
        r.insert(&prompt[..4], 0, 10);
        r.insert(&prompt[..8], 4, 11);
        r.insert(&prompt[..10], 8, 12); // partial tail run
        assert_eq!(r.page_run(10), Some((0, prompt[..4].to_vec(), vec![])));
        assert_eq!(
            r.page_run(11),
            Some((4, prompt[4..8].to_vec(), prompt[..4].to_vec()))
        );
        assert_eq!(
            r.page_run(12),
            Some((8, prompt[8..10].to_vec(), prompt[..8].to_vec()))
        );
        assert_eq!(r.page_run(99), None);
        // a split page still reports one contiguous run
        let mut fork = prompt[..10].to_vec();
        fork[9] = 99;
        r.insert(&fork[..10], 8, 13);
        assert_eq!(r.page_run(12), Some((8, prompt[8..10].to_vec(), prompt[..8].to_vec())));
    }

    #[test]
    fn mid_page_divergence_segments_share_the_page() {
        // the 15-of-16 case from the module docs, at tp = 4: prompts
        // sharing 3 of 4 tail tokens must come back as one shared
        // 3-slot segment plus per-prompt 1-slot segments
        let mut r = idx();
        let a: Vec<i32> = vec![1, 2, 3, 4, 10, 11, 12, 13];
        let mut b = a.clone();
        b[7] = 99;
        r.insert(&a[..4], 0, 50);
        r.insert(&a[..8], 4, 51);
        let (segs, matched) = r.match_prefix(&b);
        assert_eq!(matched, 7, "LCP ends at the divergence token");
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[1], Seg { page: 51, slot0: 0, len: 3, start: 4 });
    }
}
