//! Pooled page allocator with a hard capacity — the backpressure point
//! of the serving engine (a full pool rejects admission rather than
//! OOMing mid-decode).
//!
//! Pages are **refcounted** so sealed prefix pages can be shared between
//! sequences: `alloc` hands out a page with refcount 1, [`PageAllocator::retain`]
//! adds an owner, [`PageAllocator::release`] is a pure decref (it does
//! *not* recycle the page), and [`PageAllocator::free`] returns a
//! zero-ref page to the pool.  The split lets the cache manager keep
//! zero-ref *indexed* pages resident (evictable prefix cache) instead of
//! recycling them immediately.

use anyhow::{bail, Result};

use super::page::{Page, PageConfig};

pub type PageId = u32;

#[derive(Debug)]
pub struct PageAllocator {
    cfg: PageConfig,
    pages: Vec<Page>,
    /// parallel to `pages`: current owner count (0 = free-listed or
    /// resident in the zero-ref prefix cache)
    refs: Vec<u32>,
    free: Vec<PageId>,
    max_pages: usize,
    /// most pages ever simultaneously resident (serve stats line)
    high_water: usize,
}

impl PageAllocator {
    pub fn new(cfg: PageConfig, max_pages: usize) -> PageAllocator {
        PageAllocator {
            cfg,
            pages: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            max_pages,
            high_water: 0,
        }
    }

    pub fn cfg(&self) -> &PageConfig {
        &self.cfg
    }

    /// Pages resident outside the free list (includes zero-ref pages the
    /// prefix cache is keeping warm).
    pub fn allocated(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    pub fn capacity(&self) -> usize {
        self.max_pages
    }

    pub fn free_count(&self) -> usize {
        self.max_pages - self.allocated()
    }

    /// Whether `n` more pages can be allocated (admission control).
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free_count() >= n
    }

    /// Allocate an open page with refcount 1.
    pub fn alloc(&mut self) -> Result<PageId> {
        let id = if let Some(id) = self.free.pop() {
            debug_assert_eq!(self.refs[id as usize], 0, "free-listed page had owners");
            self.pages[id as usize].clear();
            self.refs[id as usize] = 1;
            id
        } else {
            if self.pages.len() >= self.max_pages {
                bail!(
                    "KV page pool exhausted ({} pages in use)",
                    self.pages.len()
                );
            }
            self.pages.push(Page::new(&self.cfg));
            self.refs.push(1);
            (self.pages.len() - 1) as PageId
        };
        self.high_water = self.high_water.max(self.allocated());
        Ok(id)
    }

    /// Add an owner to a resident page (prefix-index adoption; a 0→1
    /// transition revives a page from the zero-ref cache).
    pub fn retain(&mut self, id: PageId) {
        debug_assert!((id as usize) < self.pages.len());
        debug_assert!(
            !self.free.contains(&id),
            "retain of free-listed page {id}"
        );
        self.refs[id as usize] += 1;
    }

    /// Drop one owner; returns the remaining refcount.  The page is NOT
    /// recycled — at zero the caller decides between [`PageAllocator::free`]
    /// (recycle) and keeping it resident as a zero-ref prefix page.
    pub fn release(&mut self, id: PageId) -> u32 {
        debug_assert!((id as usize) < self.pages.len());
        debug_assert!(
            self.refs[id as usize] > 0,
            "double free: release of zero-ref page {id}"
        );
        self.refs[id as usize] -= 1;
        self.refs[id as usize]
    }

    /// Return a zero-ref page to the free pool.
    pub fn free(&mut self, id: PageId) {
        debug_assert!((id as usize) < self.pages.len());
        debug_assert_eq!(
            self.refs[id as usize], 0,
            "freeing page {id} that still has owners"
        );
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
    }

    pub fn refcount(&self, id: PageId) -> u32 {
        self.refs[id as usize]
    }

    pub fn page(&self, id: PageId) -> &Page {
        &self.pages[id as usize]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut Page {
        &mut self.pages[id as usize]
    }

    /// Copy `src`'s bytes into `dst` (copy-on-write of a shared tail).
    /// Seal state is NOT copied: the destination stays open.
    pub fn copy_page(&mut self, src: PageId, dst: PageId) {
        assert_ne!(src, dst, "copy_page onto itself");
        let (s, d) = (src as usize, dst as usize);
        let (lo, hi) = self.pages.split_at_mut(s.max(d));
        if s < d {
            hi[0].data.copy_from_slice(&lo[s].data);
        } else {
            lo[d].data.copy_from_slice(&hi[0].data);
        }
    }

    /// Copy the slot run `[slot0, slot0 + n)` from `src` into the same
    /// slots of `dst` (the radix index's sub-page copy-on-write: a new
    /// sequence adopts the shared leading slots of a sealed page and
    /// re-encodes only its divergent suffix).  The destination must be
    /// open; token position ↔ slot alignment is the caller's contract
    /// (see [`super::page::PageConfig::slot_span`]).
    pub fn copy_slots(&mut self, src: PageId, dst: PageId, slot0: usize, n: usize) {
        assert_ne!(src, dst, "copy_slots onto itself");
        debug_assert!(
            !self.pages[dst as usize].is_sealed(),
            "copy_slots into a sealed page"
        );
        let span = self.cfg.slot_span(slot0, n);
        let (s, d) = (src as usize, dst as usize);
        let (lo, hi) = self.pages.split_at_mut(s.max(d));
        if s < d {
            hi[0].data[span.clone()].copy_from_slice(&lo[s].data[span]);
        } else {
            lo[d].data[span.clone()].copy_from_slice(&hi[0].data[span]);
        }
    }

    /// Bytes currently resident (all touched pages, free or not).
    pub fn resident_bytes(&self) -> usize {
        self.pages.len() * self.cfg.page_bytes()
    }

    // -- stats for the serve stats line --------------------------------

    /// Most pages ever simultaneously resident.
    pub fn high_water_pages(&self) -> usize {
        self.high_water
    }

    /// Pages owned by 2+ sequences (shared prefix residency).
    pub fn shared_pages(&self) -> usize {
        self.refs.iter().filter(|&&r| r >= 2).count()
    }

    /// Pages owned by exactly one sequence.
    pub fn exclusive_pages(&self) -> usize {
        self.refs.iter().filter(|&&r| r == 1).count()
    }

    /// Total owner count across all pages (0 ⇔ no sequence holds any
    /// page — the leak check of the property tests).
    pub fn live_refs(&self) -> u64 {
        self.refs.iter().map(|&r| r as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(max: usize) -> PageAllocator {
        PageAllocator::new(
            PageConfig {
                tokens_per_page: 4,
                n_layers: 1,
                n_heads: 1,
                d_head: 8,
                encoded_len: 8,
            },
            max,
        )
    }

    #[test]
    fn alloc_release_reuse() {
        let mut a = mk(2);
        let p0 = a.alloc().unwrap();
        let p1 = a.alloc().unwrap();
        assert_eq!(a.allocated(), 2);
        assert!(a.alloc().is_err(), "pool must enforce capacity");
        assert_eq!(a.release(p0), 0);
        a.free(p0);
        assert_eq!(a.allocated(), 1);
        let p2 = a.alloc().unwrap();
        assert_eq!(p2, p0, "freed page is reused");
        let _ = p1;
    }

    #[test]
    fn reused_pages_are_cleared() {
        let mut a = mk(1);
        let p = a.alloc().unwrap();
        a.page_mut(p).data.fill(0xAB);
        a.page_mut(p).seal(None);
        assert_eq!(a.release(p), 0);
        a.free(p);
        let p2 = a.alloc().unwrap();
        assert!(a.page(p2).data.iter().all(|&b| b == 0));
        assert!(!a.page(p2).is_sealed(), "reuse must reopen the page");
    }

    #[test]
    fn can_alloc_accounting() {
        let mut a = mk(3);
        assert!(a.can_alloc(3));
        let _p = a.alloc().unwrap();
        assert!(a.can_alloc(2));
        assert!(!a.can_alloc(3));
    }

    #[test]
    fn refcounts_and_stats() {
        let mut a = mk(4);
        let p0 = a.alloc().unwrap();
        let p1 = a.alloc().unwrap();
        assert_eq!(a.refcount(p0), 1);
        a.retain(p0); // second owner
        assert_eq!(a.refcount(p0), 2);
        assert_eq!(a.shared_pages(), 1);
        assert_eq!(a.exclusive_pages(), 1);
        assert_eq!(a.live_refs(), 3);
        assert_eq!(a.release(p0), 1, "release is a pure decref");
        assert_eq!(a.allocated(), 2, "page stays resident while owned");
        assert_eq!(a.release(p0), 0);
        a.free(p0);
        assert_eq!(a.release(p1), 0);
        a.free(p1);
        assert_eq!(a.live_refs(), 0);
        assert_eq!(a.high_water_pages(), 2);
    }

    #[test]
    fn zero_ref_page_stays_resident_until_freed() {
        let mut a = mk(2);
        let p0 = a.alloc().unwrap();
        a.page_mut(p0).data.fill(0x5A);
        assert_eq!(a.release(p0), 0);
        // not freed: bytes survive and the pool slot stays occupied
        assert_eq!(a.allocated(), 1);
        assert!(a.page(p0).data.iter().all(|&b| b == 0x5A));
        // revive: 0 → 1
        a.retain(p0);
        assert_eq!(a.refcount(p0), 1);
        assert_eq!(a.release(p0), 0);
        a.free(p0);
        assert_eq!(a.allocated(), 0);
    }

    #[test]
    fn copy_page_copies_bytes_not_seal() {
        let mut a = mk(3);
        let src = a.alloc().unwrap();
        let dst = a.alloc().unwrap();
        a.page_mut(src).data.fill(0x7E);
        a.page_mut(src).seal(None);
        a.copy_page(src, dst);
        assert!(a.page(dst).data.iter().all(|&b| b == 0x7E));
        assert!(!a.page(dst).is_sealed(), "CoW copy must stay open");
        // and the reverse direction
        let third = a.alloc().unwrap();
        a.page_mut(third).data.fill(0x11);
        a.copy_page(third, src);
        assert!(a.page(src).data.iter().all(|&b| b == 0x11));
    }

    #[test]
    fn copy_slots_copies_only_the_span() {
        let mut a = mk(2);
        let src = a.alloc().unwrap();
        let dst = a.alloc().unwrap();
        a.page_mut(src).data.fill(0x5C);
        a.copy_slots(src, dst, 1, 2);
        let sb = a.cfg().slot_bytes();
        let d = &a.page(dst).data;
        assert!(d[..sb].iter().all(|&b| b == 0), "slot 0 untouched");
        assert!(d[sb..3 * sb].iter().all(|&b| b == 0x5C), "slots 1..3 copied");
        assert!(d[3 * sb..].iter().all(|&b| b == 0), "slot 3 untouched");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double free")]
    fn double_release_asserts() {
        let mut a = mk(1);
        let p = a.alloc().unwrap();
        a.release(p);
        a.release(p); // refcount already 0 → debug assert
    }
}
