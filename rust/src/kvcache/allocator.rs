//! Pooled page allocator with a hard capacity — the backpressure point
//! of the serving engine (a full pool rejects admission rather than
//! OOMing mid-decode).

use anyhow::{bail, Result};

use super::page::{Page, PageConfig};

pub type PageId = u32;

#[derive(Debug)]
pub struct PageAllocator {
    cfg: PageConfig,
    pages: Vec<Page>,
    free: Vec<PageId>,
    max_pages: usize,
}

impl PageAllocator {
    pub fn new(cfg: PageConfig, max_pages: usize) -> PageAllocator {
        PageAllocator {
            cfg,
            pages: Vec::new(),
            free: Vec::new(),
            max_pages,
        }
    }

    pub fn cfg(&self) -> &PageConfig {
        &self.cfg
    }

    pub fn allocated(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    pub fn capacity(&self) -> usize {
        self.max_pages
    }

    pub fn free_count(&self) -> usize {
        self.max_pages - self.allocated()
    }

    /// Whether `n` more pages can be allocated (admission control).
    pub fn can_alloc(&self, n: usize) -> bool {
        self.free_count() >= n
    }

    pub fn alloc(&mut self) -> Result<PageId> {
        if let Some(id) = self.free.pop() {
            self.pages[id as usize].clear();
            return Ok(id);
        }
        if self.pages.len() >= self.max_pages {
            bail!(
                "KV page pool exhausted ({} pages in use)",
                self.pages.len()
            );
        }
        self.pages.push(Page::new(&self.cfg));
        Ok((self.pages.len() - 1) as PageId)
    }

    pub fn release(&mut self, id: PageId) {
        debug_assert!((id as usize) < self.pages.len());
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
    }

    pub fn page(&self, id: PageId) -> &Page {
        &self.pages[id as usize]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut Page {
        &mut self.pages[id as usize]
    }

    /// Bytes currently resident (all touched pages, free or not).
    pub fn resident_bytes(&self) -> usize {
        self.pages.len() * self.cfg.page_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(max: usize) -> PageAllocator {
        PageAllocator::new(
            PageConfig {
                tokens_per_page: 4,
                n_layers: 1,
                n_heads: 1,
                d_head: 8,
                encoded_len: 8,
            },
            max,
        )
    }

    #[test]
    fn alloc_release_reuse() {
        let mut a = mk(2);
        let p0 = a.alloc().unwrap();
        let p1 = a.alloc().unwrap();
        assert_eq!(a.allocated(), 2);
        assert!(a.alloc().is_err(), "pool must enforce capacity");
        a.release(p0);
        assert_eq!(a.allocated(), 1);
        let p2 = a.alloc().unwrap();
        assert_eq!(p2, p0, "freed page is reused");
        let _ = p1;
    }

    #[test]
    fn reused_pages_are_cleared() {
        let mut a = mk(1);
        let p = a.alloc().unwrap();
        a.page_mut(p).data.fill(0xAB);
        a.release(p);
        let p2 = a.alloc().unwrap();
        assert!(a.page(p2).data.iter().all(|&b| b == 0));
    }

    #[test]
    fn can_alloc_accounting() {
        let mut a = mk(3);
        assert!(a.can_alloc(3));
        let _p = a.alloc().unwrap();
        assert!(a.can_alloc(2));
        assert!(!a.can_alloc(3));
    }
}
