//! Flat prefix index: content-addressed lookup of sealed prompt pages.
//!
//! Maps [`PrefixKey`]s (chained hashes of prompt token runs, see
//! `kvcache::page::chain_key`) to sealed [`PageId`]s so a new sequence
//! whose prompt starts with an already-cached prefix can adopt whole
//! pages instead of re-encoding them.
//!
//! This is the *flat* of the two index backends selected by
//! `[cache] prefix_index` (see [`PrefixIndexKind`]): it matches whole
//! pages only — a prompt sharing 15 of a page's 16 tokens shares
//! nothing here.  The token-level [`super::radix::RadixIndex`] closes
//! that gap with longest-common-prefix walks and sub-page slot-range
//! reuse; this flat index remains the default and the reference
//! behavior.
//!
//! A key match alone is not trusted: token ids are client-controlled
//! and a 64-bit hash can collide, so every entry stores the exact token
//! run it covers plus its parent key, and [`PrefixIndex::lookup`]
//! verifies both before serving a page.  Walking the chain therefore
//! re-verifies the full prefix token-by-token, never by hash equality
//! alone.
//!
//! Ownership rules (see the `kvcache` module docs for the full
//! invariant set):
//!
//! * the index itself holds **no refcounts** — an entry is a hint, not
//!   an owner;
//! * when the last owning sequence releases an indexed page, the cache
//!   manager parks it here as a **zero-ref cached** page: still
//!   resident, adoptable, and evictable;
//! * under pool pressure the manager evicts zero-ref entries in
//!   **weighted** order ([`PrefixIndex::evict_victim`], O(log n)): the
//!   victim is the parked page with the lowest retention score
//!   `(reuse + 1) / (depth + 1)` — so root pages (every descendant
//!   needs them) and frequently re-adopted pages outlive deep,
//!   never-reused leaves; ties fall back to least-recently-parked.
//!   Eviction removes the index entry and lets the page be recycled.
//!   Pages with live owners are never evicted.  When a persistent
//!   store is attached, pages are spilled at park time, so this same
//!   ordering is the RAM→disk *demotion* ordering.

use std::collections::{BTreeMap, HashMap};

use super::allocator::PageId;
use super::page::PrefixKey;

/// Which prefix-index structure the cache manager runs
/// (`[cache] prefix_index = flat|radix`).
///
/// * [`PrefixIndexKind::Flat`] — the PR 3/4 content-addressed
///   whole-page index ([`PrefixIndex`]); the default, and bit-for-bit
///   the previous behavior.
/// * [`PrefixIndexKind::Radix`] — the token-level radix tree
///   ([`super::radix::RadixIndex`]): longest-common-prefix lookups,
///   node splits at the divergence token, sub-page slot-range
///   copy-on-write, and hierarchical (leaves-first) eviction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum PrefixIndexKind {
    #[default]
    Flat,
    Radix,
}

impl PrefixIndexKind {
    /// Parse a `[cache] prefix_index` / `--prefix-index` value.
    pub fn parse(s: &str) -> Option<PrefixIndexKind> {
        match s {
            "flat" => Some(PrefixIndexKind::Flat),
            "radix" => Some(PrefixIndexKind::Radix),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrefixIndexKind::Flat => "flat",
            PrefixIndexKind::Radix => "radix",
        }
    }
}

/// Fixed-point scale of the retention score (keeps the reuse/depth
/// ratio meaningful in integer math).  Shared by both index backends
/// and by the store's segment compactor, whose
/// `[cache] compact_threshold` knob is expressed in the same
/// `(reuse + 1) / (depth + 1)` units.
pub const SCORE_SCALE: u64 = 1 << 16;

/// One published prefix page: the page plus the exact chain link it
/// claims to encode (verified on every lookup).
#[derive(Debug)]
struct IndexEntry {
    page: PageId,
    parent: Option<PrefixKey>,
    tokens: Vec<i32>,
    /// chain position: 0 for the root page of a prompt, +1 per page
    depth: u32,
    /// how many times a sequence adopted this page since publish
    reuse: u32,
}

impl IndexEntry {
    /// Retention weight: bigger = keep longer.  Reuse dominates (a
    /// hot leaf outlives a never-used root); at equal reuse, shallower
    /// pages win because every descendant's chain walks through them.
    fn score(&self) -> u64 {
        (self.reuse as u64 + 1) * SCORE_SCALE / (self.depth as u64 + 1)
    }
}

#[derive(Debug, Default)]
pub struct PrefixIndex {
    /// content key → sealed page holding that prefix run
    map: HashMap<PrefixKey, IndexEntry>,
    /// zero-ref indexed pages: page → (its key, its queue slot); only
    /// these are evictable
    cached: HashMap<PageId, (PrefixKey, (u64, u64))>,
    /// eviction order over the zero-ref set: (score, park stamp) →
    /// page — the first entry is the next victim
    queue: BTreeMap<(u64, u64), PageId>,
    /// monotonic stamp source for the park-time tiebreak
    clock: u64,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Number of indexed prefix pages.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Zero-ref (evictable) indexed pages.
    pub fn cached_len(&self) -> usize {
        self.cached.len()
    }

    /// Verified lookup: the entry must exist under `key` AND cover
    /// exactly `tokens` with the same `parent` link.  The token check
    /// makes a hash collision yield a miss, not another request's KV.
    pub fn lookup(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: &[i32],
    ) -> Option<PageId> {
        let e = self.map.get(&key)?;
        (e.parent == parent && e.tokens == tokens).then_some(e.page)
    }

    /// Whether `key` maps to exactly `page` (a page can carry a key yet
    /// have lost the publish race to another page with the same
    /// content).
    pub fn is_indexed(&self, key: PrefixKey, page: PageId) -> bool {
        self.map.get(&key).map(|e| e.page) == Some(page)
    }

    /// The chain link recorded for `key`: (page, parent, token run,
    /// depth).  The persistence layer uses this to serialize a parked
    /// page without re-deriving its chain.
    pub fn entry_meta(&self, key: PrefixKey) -> Option<(PageId, Option<PrefixKey>, &[i32], u32)> {
        self.map
            .get(&key)
            .map(|e| (e.page, e.parent, e.tokens.as_slice(), e.depth))
    }

    /// The current retention score of the entry under `key`, in
    /// [`SCORE_SCALE`] fixed point.  Spilled with the record so the
    /// store's segment compactor can rank live records by the same
    /// `(reuse + 1) / (depth + 1)` weight the in-RAM eviction uses.
    pub fn score_of(&self, key: PrefixKey) -> Option<u64> {
        self.map.get(&key).map(|e| e.score())
    }

    /// Publish a sealed page under its content key, recording the token
    /// run, parent link, and chain depth for lookup verification and
    /// eviction weighting.  First publisher wins: if the key is already
    /// mapped (another sequence sealed the same content first) the
    /// entry is left untouched and `false` is returned — the caller's
    /// page simply stays private.
    pub fn publish(
        &mut self,
        key: PrefixKey,
        page: PageId,
        parent: Option<PrefixKey>,
        tokens: &[i32],
        depth: u32,
    ) -> bool {
        use std::collections::hash_map::Entry;
        match self.map.entry(key) {
            Entry::Occupied(_) => false,
            Entry::Vacant(v) => {
                v.insert(IndexEntry {
                    page,
                    parent,
                    tokens: tokens.to_vec(),
                    depth,
                    reuse: 0,
                });
                true
            }
        }
    }

    /// Remove a page from the evictable set (it is about to gain an
    /// owner, or must be protected while one is being arranged).
    /// Carries no reuse credit — see [`PrefixIndex::credit_reuse`].
    pub fn unpark(&mut self, page: PageId) {
        if let Some((_, slot)) = self.cached.remove(&page) {
            self.queue.remove(&slot);
        }
    }

    /// Credit one adoption to the entry under `key`: its reuse count —
    /// the dominant term of the retention score — grows.  Kept separate
    /// from [`PrefixIndex::unpark`] so a walk that pins pages and then
    /// fails (releasing them unused) does not inflate their scores.
    pub fn credit_reuse(&mut self, key: PrefixKey, page: PageId) {
        if let Some(e) = self.map.get_mut(&key) {
            if e.page == page {
                e.reuse = e.reuse.saturating_add(1);
            }
        }
    }

    /// Park a zero-ref indexed page as cached/evictable.  `key` must be
    /// the key the index maps to this page.  The eviction slot is
    /// scored now, from the entry's current reuse count (reuse only
    /// changes while adopted, i.e. while not parked).
    pub fn cache_zero_ref(&mut self, page: PageId, key: PrefixKey) {
        debug_assert!(self.is_indexed(key, page));
        let score = self.map.get(&key).map(|e| e.score()).unwrap_or(0);
        self.clock += 1;
        let slot = (score, self.clock);
        self.cached.insert(page, (key, slot));
        self.queue.insert(slot, page);
    }

    /// Evict the lowest-scored zero-ref page (ties: least recently
    /// parked): removes the cached entry and the index mapping,
    /// returning the page for the caller to recycle.  `None` when
    /// nothing is evictable.
    pub fn evict_victim(&mut self) -> Option<PageId> {
        let (_, page) = self.queue.pop_first()?;
        let (key, _) = self.cached.remove(&page).expect("queue/cached out of sync");
        let removed = self.map.remove(&key).map(|e| e.page);
        debug_assert_eq!(removed, Some(page));
        Some(page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::page::chain_key;

    fn key(i: u64) -> PrefixKey {
        chain_key(None, &[i as i32], 7)
    }

    fn toks(i: u64) -> Vec<i32> {
        vec![i as i32]
    }

    #[test]
    fn publish_lookup_first_wins() {
        let mut idx = PrefixIndex::new();
        assert!(idx.lookup(key(1), None, &toks(1)).is_none());
        assert!(idx.publish(key(1), 10, None, &toks(1), 0));
        assert_eq!(idx.lookup(key(1), None, &toks(1)), Some(10));
        // second publisher of the same content loses
        assert!(!idx.publish(key(1), 11, None, &toks(1), 0));
        assert_eq!(idx.lookup(key(1), None, &toks(1)), Some(10));
        assert!(idx.is_indexed(key(1), 10));
        assert!(!idx.is_indexed(key(1), 11));
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn lookup_verifies_tokens_and_parent_not_just_hash() {
        let mut idx = PrefixIndex::new();
        idx.publish(key(1), 10, None, &toks(1), 0);
        // same key, wrong tokens (simulated collision) → miss
        assert_eq!(idx.lookup(key(1), None, &toks(2)), None);
        // same key + tokens, wrong parent link → miss
        assert_eq!(idx.lookup(key(1), Some(key(9)), &toks(1)), None);
        // exact match → hit
        assert_eq!(idx.lookup(key(1), None, &toks(1)), Some(10));
    }

    #[test]
    fn entry_meta_exposes_the_chain_link() {
        let mut idx = PrefixIndex::new();
        idx.publish(key(2), 4, Some(key(1)), &toks(2), 3);
        let (page, parent, tokens, depth) = idx.entry_meta(key(2)).unwrap();
        assert_eq!(page, 4);
        assert_eq!(parent, Some(key(1)));
        assert_eq!(tokens, &toks(2)[..]);
        assert_eq!(depth, 3);
        assert!(idx.entry_meta(key(9)).is_none());
    }

    #[test]
    fn equal_scores_evict_in_park_order() {
        let mut idx = PrefixIndex::new();
        for i in 0..3u64 {
            idx.publish(key(i), i as PageId, None, &toks(i), 0);
        }
        assert_eq!(idx.cached_len(), 0);
        // same depth, same reuse → pure LRU tiebreak: park 1, 0, 2
        idx.cache_zero_ref(1, key(1));
        idx.cache_zero_ref(0, key(0));
        idx.cache_zero_ref(2, key(2));
        assert_eq!(idx.cached_len(), 3);
        assert_eq!(idx.evict_victim(), Some(1));
        assert_eq!(idx.evict_victim(), Some(0));
        assert_eq!(idx.evict_victim(), Some(2));
        assert_eq!(idx.evict_victim(), None);
        // evicted entries are gone from the map too
        assert!(idx.lookup(key(0), None, &toks(0)).is_none());
        assert_eq!(idx.len(), 0);
    }

    #[test]
    fn deep_pages_evict_before_roots() {
        let mut idx = PrefixIndex::new();
        // a 3-page chain parked root-first (LRU alone would evict the
        // root first — the depth weight must override it)
        for depth in 0..3u32 {
            idx.publish(key(depth as u64), depth as PageId, None, &toks(depth as u64), depth);
            idx.cache_zero_ref(depth as PageId, key(depth as u64));
        }
        assert_eq!(idx.evict_victim(), Some(2), "leaf goes first");
        assert_eq!(idx.evict_victim(), Some(1));
        assert_eq!(idx.evict_victim(), Some(0), "root goes last");
    }

    #[test]
    fn reuse_outweighs_depth() {
        let mut idx = PrefixIndex::new();
        // a leaf adopted many times must outlive an unused root:
        // score(leaf) = (9+1)/(2+1) > score(root) = 1/1
        idx.publish(key(0), 0, None, &toks(0), 0);
        idx.publish(key(2), 2, None, &toks(2), 2);
        for _ in 0..9 {
            idx.credit_reuse(key(2), 2);
        }
        idx.cache_zero_ref(0, key(0));
        idx.cache_zero_ref(2, key(2));
        assert_eq!(idx.evict_victim(), Some(0), "cold root evicts first");
        assert_eq!(idx.evict_victim(), Some(2));
    }

    #[test]
    fn adoption_removes_from_evictable_set() {
        let mut idx = PrefixIndex::new();
        idx.publish(key(5), 5, None, &toks(5), 0);
        idx.cache_zero_ref(5, key(5));
        assert_eq!(idx.cached_len(), 1);
        idx.unpark(5);
        idx.credit_reuse(key(5), 5);
        assert_eq!(idx.cached_len(), 0);
        // adopted page is not evictable, but stays indexed
        assert_eq!(idx.evict_victim(), None);
        assert_eq!(idx.lookup(key(5), None, &toks(5)), Some(5));
        // re-parking later works
        idx.cache_zero_ref(5, key(5));
        assert_eq!(idx.evict_victim(), Some(5));
    }
}
