//! Prefix index: content-addressed lookup of sealed prompt pages.
//!
//! Maps [`PrefixKey`]s (chained hashes of prompt token runs, see
//! `kvcache::page::chain_key`) to sealed [`PageId`]s so a new sequence
//! whose prompt starts with an already-cached prefix can adopt whole
//! pages instead of re-encoding them.
//!
//! A key match alone is not trusted: token ids are client-controlled
//! and a 64-bit hash can collide, so every entry stores the exact token
//! run it covers plus its parent key, and [`PrefixIndex::lookup`]
//! verifies both before serving a page.  Walking the chain therefore
//! re-verifies the full prefix token-by-token, never by hash equality
//! alone.
//!
//! Ownership rules (see the `kvcache` module docs for the full
//! invariant set):
//!
//! * the index itself holds **no refcounts** — an entry is a hint, not
//!   an owner;
//! * when the last owning sequence releases an indexed page, the cache
//!   manager parks it here as a **zero-ref cached** page: still
//!   resident, adoptable, and evictable;
//! * under pool pressure the manager evicts zero-ref entries in LRU
//!   order ([`PrefixIndex::evict_lru`], O(log n)), which removes the
//!   index entry and lets the page be recycled.  Pages with live owners
//!   are never evicted.

use std::collections::{BTreeMap, HashMap};

use super::allocator::PageId;
use super::page::PrefixKey;

/// One published prefix page: the page plus the exact chain link it
/// claims to encode (verified on every lookup).
#[derive(Debug)]
struct IndexEntry {
    page: PageId,
    parent: Option<PrefixKey>,
    tokens: Vec<i32>,
}

#[derive(Debug, Default)]
pub struct PrefixIndex {
    /// content key → sealed page holding that prefix run
    map: HashMap<PrefixKey, IndexEntry>,
    /// zero-ref indexed pages: page → (its key, LRU stamp); only these
    /// are evictable
    cached: HashMap<PageId, (PrefixKey, u64)>,
    /// LRU order over the zero-ref set: stamp → page (stamps are unique)
    lru: BTreeMap<u64, PageId>,
    /// monotonic stamp source for LRU ordering
    clock: u64,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Number of indexed prefix pages.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Zero-ref (evictable) indexed pages.
    pub fn cached_len(&self) -> usize {
        self.cached.len()
    }

    /// Verified lookup: the entry must exist under `key` AND cover
    /// exactly `tokens` with the same `parent` link.  The token check
    /// makes a hash collision yield a miss, not another request's KV.
    pub fn lookup(
        &self,
        key: PrefixKey,
        parent: Option<PrefixKey>,
        tokens: &[i32],
    ) -> Option<PageId> {
        let e = self.map.get(&key)?;
        (e.parent == parent && e.tokens == tokens).then_some(e.page)
    }

    /// Whether `key` maps to exactly `page` (a page can carry a key yet
    /// have lost the publish race to another page with the same
    /// content).
    pub fn is_indexed(&self, key: PrefixKey, page: PageId) -> bool {
        self.map.get(&key).map(|e| e.page) == Some(page)
    }

    /// Publish a sealed page under its content key, recording the token
    /// run and parent link for lookup verification.  First publisher
    /// wins: if the key is already mapped (another sequence sealed the
    /// same content first) the entry is left untouched and `false` is
    /// returned — the caller's page simply stays private.
    pub fn publish(
        &mut self,
        key: PrefixKey,
        page: PageId,
        parent: Option<PrefixKey>,
        tokens: &[i32],
    ) -> bool {
        use std::collections::hash_map::Entry;
        match self.map.entry(key) {
            Entry::Occupied(_) => false,
            Entry::Vacant(v) => {
                v.insert(IndexEntry {
                    page,
                    parent,
                    tokens: tokens.to_vec(),
                });
                true
            }
        }
    }

    /// A sequence adopted `page` (its refcount is about to go ≥ 1): it
    /// is no longer evictable.
    pub fn on_adopt(&mut self, page: PageId) {
        if let Some((_, stamp)) = self.cached.remove(&page) {
            self.lru.remove(&stamp);
        }
    }

    /// Park a zero-ref indexed page as cached/evictable.  `key` must be
    /// the key the index maps to this page.
    pub fn cache_zero_ref(&mut self, page: PageId, key: PrefixKey) {
        debug_assert!(self.is_indexed(key, page));
        self.clock += 1;
        self.cached.insert(page, (key, self.clock));
        self.lru.insert(self.clock, page);
    }

    /// Evict the least-recently-parked zero-ref page: removes the
    /// cached entry and the index mapping, returning the page for the
    /// caller to recycle.  `None` when nothing is evictable.
    pub fn evict_lru(&mut self) -> Option<PageId> {
        let (_, page) = self.lru.pop_first()?;
        let (key, _) = self.cached.remove(&page).expect("lru/cached out of sync");
        let removed = self.map.remove(&key).map(|e| e.page);
        debug_assert_eq!(removed, Some(page));
        Some(page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::page::chain_key;

    fn key(i: u64) -> PrefixKey {
        chain_key(None, &[i as i32], 7)
    }

    fn toks(i: u64) -> Vec<i32> {
        vec![i as i32]
    }

    #[test]
    fn publish_lookup_first_wins() {
        let mut idx = PrefixIndex::new();
        assert!(idx.lookup(key(1), None, &toks(1)).is_none());
        assert!(idx.publish(key(1), 10, None, &toks(1)));
        assert_eq!(idx.lookup(key(1), None, &toks(1)), Some(10));
        // second publisher of the same content loses
        assert!(!idx.publish(key(1), 11, None, &toks(1)));
        assert_eq!(idx.lookup(key(1), None, &toks(1)), Some(10));
        assert!(idx.is_indexed(key(1), 10));
        assert!(!idx.is_indexed(key(1), 11));
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn lookup_verifies_tokens_and_parent_not_just_hash() {
        let mut idx = PrefixIndex::new();
        idx.publish(key(1), 10, None, &toks(1));
        // same key, wrong tokens (simulated collision) → miss
        assert_eq!(idx.lookup(key(1), None, &toks(2)), None);
        // same key + tokens, wrong parent link → miss
        assert_eq!(idx.lookup(key(1), Some(key(9)), &toks(1)), None);
        // exact match → hit
        assert_eq!(idx.lookup(key(1), None, &toks(1)), Some(10));
    }

    #[test]
    fn lru_eviction_order() {
        let mut idx = PrefixIndex::new();
        for i in 0..3u64 {
            idx.publish(key(i), i as PageId, None, &toks(i));
        }
        assert_eq!(idx.cached_len(), 0);
        // park in order 1, 0, 2 → eviction order must follow
        idx.cache_zero_ref(1, key(1));
        idx.cache_zero_ref(0, key(0));
        idx.cache_zero_ref(2, key(2));
        assert_eq!(idx.cached_len(), 3);
        assert_eq!(idx.evict_lru(), Some(1));
        assert_eq!(idx.evict_lru(), Some(0));
        assert_eq!(idx.evict_lru(), Some(2));
        assert_eq!(idx.evict_lru(), None);
        // evicted entries are gone from the map too
        assert!(idx.lookup(key(0), None, &toks(0)).is_none());
        assert_eq!(idx.len(), 0);
    }

    #[test]
    fn adoption_removes_from_evictable_set() {
        let mut idx = PrefixIndex::new();
        idx.publish(key(5), 5, None, &toks(5));
        idx.cache_zero_ref(5, key(5));
        assert_eq!(idx.cached_len(), 1);
        idx.on_adopt(5);
        assert_eq!(idx.cached_len(), 0);
        // adopted page is not evictable, but stays indexed
        assert_eq!(idx.evict_lru(), None);
        assert_eq!(idx.lookup(key(5), None, &toks(5)), Some(5));
        // re-parking later works
        idx.cache_zero_ref(5, key(5));
        assert_eq!(idx.evict_lru(), Some(5));
    }
}
