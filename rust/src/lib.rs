//! # IsoQuant
//!
//! Full-stack reproduction of *IsoQuant: Hardware-Aligned SO(4) Isoclinic
//! Rotations for LLM KV Cache Compression* (Ji, 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1** — fused Pallas kernels (`python/compile/kernels/`), AOT-lowered
//!   to HLO text;
//! * **L2** — JAX stage-1 pipelines and a small serving transformer
//!   (`python/compile/model.py`);
//! * **L3** — this crate: the serving coordinator, compressed KV cache,
//!   native stage-1 hot path, and the PJRT runtime that executes the AOT
//!   artifacts.  Python never runs on the request path.
//!
//! Start at [`quant::Stage1`] for the paper's core transform and at
//! [`coordinator::Engine`] for the serving stack.

pub mod math;
pub mod quant;
pub mod util;

pub mod attention;
pub mod cmd;
pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod server;
