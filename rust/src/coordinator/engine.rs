//! The serving engine: Orca/vLLM-style iteration-level scheduling over a
//! fixed-lane batch, chunked prefill, greedy decode, and a compressed KV
//! cache on the critical path.
//!
//! One `step()` = one scheduler iteration:
//!   1. admit waiting requests into free lanes (admission-controlled by
//!      the KV page pool),
//!   2. if any lane is mid-prefill → run one batched prefill chunk
//!      (lanes not prefilling carry dummy tokens; their outputs are
//!      discarded),
//!   3. else → run one batched decode step at per-lane positions,
//! compressing each produced token's K/V into the paged cache and
//! reconstructing per-lane caches for the next model call.  IsoQuant
//! stage-1 therefore runs on *every* token append and *every* cache
//! gather — the deployment pattern the paper's kernel-latency argument
//! targets.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use super::request::{Completion, FinishReason, FlightRecorder, Request, Timing, TraceRecord};
use crate::config::EngineConfig;
use crate::kvcache::{CacheManager, GatherWorkspace, PageConfig, PageStore, SeqId, StoreConfig};
use crate::log_info;
use crate::metrics::prometheus::{MetricsSnapshot, PageGauges};
use crate::metrics::{argmax, Counters, Histogram};
use crate::quant::{Stage1, Stage1Config};
use crate::runtime::ServingModel;

/// Last-N-requests kept by the engine's flight recorder.
const FLIGHT_RECORDER_CAP: usize = 256;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Prefill { consumed: usize },
    Decode,
}

struct ActiveSeq {
    req: Request,
    timing: Timing,
    seq: SeqId,
    /// tokens whose K/V are in the cache (starts at the prefix-reuse
    /// coverage, not 0, when shared pages were adopted)
    pos: usize,
    generated: Vec<i32>,
    phase: Phase,
    /// token to feed at the next decode step
    last_token: i32,
    /// pages adopted from the prefix index at admission
    prefix_hit_pages: usize,
    /// absolute deadline (per-request `deadline_ms` or the
    /// `[server] request_timeout_ms` default, from submission);
    /// `None` = run to completion
    deadline: Option<Instant>,
    /// when the previous token was produced (inter-token-latency
    /// accounting); `None` until the first token exists
    last_token_at: Option<Instant>,
}

enum Lane {
    Free,
    Active(Box<ActiveSeq>),
}

/// Step-level latency breakdown.  All recorders are bounded
/// log-bucketed [`Histogram`]s (O(buckets) memory regardless of how
/// long the server runs, O(buckets) percentile queries) — the
/// keep-every-sample `LatencyRecorder` stays available for one-shot
/// benches that want exact percentiles over a bounded run.
#[derive(Default)]
pub struct EngineStats {
    pub decode_step: Histogram,
    pub prefill_step: Histogram,
    pub gather: Histogram,
    pub append: Histogram,
    /// per-request submit → first-token latency
    pub ttft: Histogram,
    /// per-token gap between consecutive generated tokens of a request
    pub inter_token: Histogram,
    /// per-request submit → admission (lane assigned) latency
    pub queue_wait: Histogram,
    /// per-request submit → finished latency (all outcomes)
    pub request_total: Histogram,
    pub counters: Counters,
    pub steps: u64,
    /// per-phase `Engine::step` timings; `Some` only with
    /// `[engine] profile = on` (the off path costs nothing)
    pub profile: Option<Box<PhaseHists>>,
}

/// Per-phase histograms for the `[engine] profile = on` step profiler.
/// Phases are wall-clock sections of [`Engine::step`]; `emit` (the
/// post-forward bookkeeping loop) contains the `append` sections, so
/// the phases are attributable individually but do not sum to the step
/// total.
#[derive(Default, Debug)]
pub struct PhaseHists {
    /// deadline expiry sweep + store health note
    pub expire: Histogram,
    /// admission pass (prefix probe, lane assignment, prefix walk)
    pub admit: Histogram,
    /// cross-lane cache gather into the batch buffers
    pub gather: Histogram,
    /// the model call (prefill chunk or decode step)
    pub forward: Histogram,
    /// cache appends (encode + page writes), inside `emit`
    pub append: Histogram,
    /// post-forward bookkeeping: append staging, sampling, token
    /// events, completion handling
    pub emit: Histogram,
}

impl PhaseHists {
    /// The phases in display order — the one list `/metrics` and the
    /// stats JSON render from.
    pub fn named(&self) -> Vec<(&'static str, &Histogram)> {
        vec![
            ("expire", &self.expire),
            ("admit", &self.admit),
            ("gather", &self.gather),
            ("forward", &self.forward),
            ("append", &self.append),
            ("emit", &self.emit),
        ]
    }
}

/// One generated token of a `"stream": true` request, queued for the
/// serve loop to push over the wire before the terminal completion.
#[derive(Clone, Copy, Debug)]
pub struct TokenEvent {
    pub id: u64,
    /// 0-based position within the request's generated tokens
    pub index: usize,
    pub token: i32,
}

pub struct Engine {
    pub model: ServingModel,
    pub cache: CacheManager,
    pub cfg: EngineConfig,
    lanes: Vec<Lane>,
    waiting: VecDeque<(Request, Timing)>,
    completions: Vec<Completion>,
    next_seq: SeqId,
    // reused (L, B, H, T, dh) buffers
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    /// persistent batched-gather scratch (strip decode state)
    gather_ws: GatherWorkspace,
    /// lanes whose k_buf/v_buf regions hold stale gathered data (true
    /// after a lane has been active; cleared when re-zeroed while free)
    lane_dirty: Vec<bool>,
    // reused per-token (L, H, dh) staging buffers for appends
    tok_k: Vec<f32>,
    tok_v: Vec<f32>,
    // reused (P, L, H, dh) staging buffers for batched prefill appends
    chunk_k: Vec<f32>,
    chunk_v: Vec<f32>,
    /// reused (seq, lane) list for the cross-lane gather drain
    lane_jobs: Vec<(SeqId, usize)>,
    /// backpressure memo: the (available_pages, prefix_index_len)
    /// snapshot at the last denied admission.  While nothing that could
    /// change the verdict has moved (every page release, adoption,
    /// eviction, or publish perturbs one of the two), the per-step
    /// admit pass skips re-running the O(prompt) prefix probe
    admit_denied: Option<(usize, usize)>,
    /// tokens generated by `"stream": true` requests since the last
    /// [`Engine::take_token_events`] drain
    token_events: Vec<TokenEvent>,
    /// ring buffer of the last N finished/cancelled/expired/shed
    /// request timelines, served by `{"stats": true, "traces": K}`
    flight: FlightRecorder,
    pub stats: EngineStats,
}

impl Engine {
    pub fn new(model: ServingModel, cfg: EngineConfig) -> Result<Engine> {
        let m = model.meta.clone();
        let stage1 = Stage1::new({
            let mut c = Stage1Config::new(cfg.variant, m.d_head, cfg.bits);
            c.quant = cfg.quant;
            c.seed = cfg.seed;
            c.backend = cfg.kernel_backend;
            c
        });
        let page_cfg = PageConfig {
            tokens_per_page: cfg.page_tokens,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            d_head: m.d_head,
            encoded_len: stage1.encoded_len(),
        };
        // pool sized for all lanes at max_seq plus 25% headroom
        let max_pages = (m.serve_batch * m.max_seq.div_ceil(cfg.page_tokens)) * 5 / 4 + 1;
        let mut cache = CacheManager::new(stage1, page_cfg, max_pages);
        cache.parallel = cfg.gather_parallel;
        cache.prefix_sharing = cfg.prefix_sharing;
        cache.gather_dedup = cfg.gather_dedup;
        cache.index_kind = cfg.prefix_index;
        if !cfg.persist_dir.is_empty() {
            // persistence rides on the content-addressed index: without
            // sharing nothing is ever published, so nothing could spill
            // or rehydrate — reject the combination instead of silently
            // doing no I/O
            if !cfg.prefix_sharing {
                bail!("[cache] persist_dir requires prefix_sharing = on");
            }
            let store = PageStore::open(
                StoreConfig::for_cache(
                    std::path::PathBuf::from(&cfg.persist_dir),
                    cache.fingerprint(),
                    page_cfg.page_bytes(),
                    (cfg.persist_budget_mb as u64) << 20,
                )
                .with_mmap(cfg.persist_mmap)
                .with_fault_policy(
                    cfg.persist_retries,
                    cfg.persist_retry_backoff_ms,
                    cfg.persist_degrade_after,
                )
                .with_compaction(
                    // the knob is a fractional (reuse+1)/(depth+1) score;
                    // records carry it in SCORE_SCALE fixed point
                    (cfg.compact_threshold * crate::kvcache::prefix::SCORE_SCALE as f64) as u32,
                    cfg.compact_max_bytes_per_pass as u64,
                ),
            )?;
            log_info!(
                "page store at {} — {} cold pages rehydrated ({:.1} MB on disk)",
                cfg.persist_dir,
                store.len(),
                store.disk_bytes() as f64 / 1e6,
            );
            cache.attach_store(store);
        }
        let lanes = (0..m.serve_batch).map(|_| Lane::Free).collect();
        let cache_numel = model.cache_numel();
        let tok_numel = m.n_layers * m.n_heads * m.d_head;
        let profile = cfg.profile;
        Ok(Engine {
            model,
            cache,
            cfg,
            lanes,
            waiting: VecDeque::new(),
            completions: Vec::new(),
            next_seq: 1,
            k_buf: vec![0.0; cache_numel],
            v_buf: vec![0.0; cache_numel],
            gather_ws: GatherWorkspace::new(),
            lane_dirty: vec![false; m.serve_batch],
            tok_k: vec![0.0; tok_numel],
            tok_v: vec![0.0; tok_numel],
            chunk_k: vec![0.0; m.prefill_chunk * tok_numel],
            chunk_v: vec![0.0; m.prefill_chunk * tok_numel],
            lane_jobs: Vec::with_capacity(m.serve_batch),
            admit_denied: None,
            token_events: Vec::new(),
            flight: FlightRecorder::new(FLIGHT_RECORDER_CAP),
            stats: {
                let mut s = EngineStats::default();
                if profile {
                    s.profile = Some(Box::default());
                }
                s
            },
        })
    }

    /// Queue a request.  Length validation happens at admission.
    pub fn submit(&mut self, req: Request) {
        Counters::bump(&self.stats.counters.requests, 1);
        let mut timing = Timing::new();
        // carry the reactor-side stamps (absent for engine-injected
        // requests) onto the engine-owned timeline
        timing.received = req.received_at;
        timing.parsed = req.parsed_at;
        self.waiting.push_back((req, timing));
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn active(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| matches!(l, Lane::Active(_)))
            .count()
    }

    /// Lanes with no active sequence.  The serve loop uses this for the
    /// idle-lane fast path: while free lanes exist, queued requests are
    /// drained into the engine immediately instead of waiting out the
    /// batching window (`batch_window_us` is a *lanes-full* trade, not
    /// a floor on time-to-first-token).
    pub fn free_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| matches!(l, Lane::Free)).count()
    }

    /// True when the KV page pool is running hot: less than a quarter
    /// of capacity is still drawable (free pool + evictable cached
    /// pages).  The serve loop switches queue draining from FIFO to
    /// deepest-cached-prefix-first under pressure, so each admission
    /// costs the fewest fresh pages and the shared stems the rest of
    /// the queue needs are not evicted to make room.
    pub fn cache_pressure(&self) -> bool {
        let cap = self.cache.page_capacity();
        cap > 0 && self.cache.available_pages() * 4 < cap
    }

    /// Read-only longest-cached-prefix probe (tokens), for LCP-aware
    /// queue ordering.  No refcounts are taken.
    pub fn cached_lcp(&self, prompt: &[i32]) -> usize {
        self.cache.cached_lcp(prompt)
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Drain the streamed-token queue (tokens generated by
    /// `"stream": true` requests since the last drain, in generation
    /// order).  Non-streaming requests never enqueue here, so the
    /// default serve path pays nothing.
    pub fn take_token_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.token_events)
    }

    /// One scheduler iteration.  Returns false when fully idle.
    pub fn step(&mut self) -> Result<bool> {
        let t0 = Instant::now();
        self.expire_deadlines();
        self.cache.note_store_health();
        if let Some(p) = &self.stats.profile {
            p.expire.record(t0.elapsed());
        }
        let t0 = Instant::now();
        self.admit()?;
        if let Some(p) = &self.stats.profile {
            p.admit.record(t0.elapsed());
        }
        let any_prefill = self.lanes.iter().any(
            |l| matches!(l, Lane::Active(a) if matches!(a.phase, Phase::Prefill { .. })),
        );
        if any_prefill {
            self.step_prefill()?;
            self.stats.steps += 1;
            return Ok(true);
        }
        if self.lanes.iter().any(|l| matches!(l, Lane::Active(_))) {
            self.step_decode()?;
            self.stats.steps += 1;
            return Ok(true);
        }
        Ok(!self.waiting.is_empty())
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.step()? {}
        Ok(self.take_completions())
    }

    /// Drop a request whose client is gone: removed from the waiting
    /// queue, or — mid-prefill/mid-decode — its lane is freed and every
    /// cache page released (refcounts to zero, CoW tails back to the
    /// pool) in the same call.  No completion is pushed: the socket
    /// that would carry it is dead.  Returns false for unknown ids
    /// (already finished, or never submitted) — a harmless no-op.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.waiting.iter().position(|(r, _)| r.id == id) {
            if let Some((req, mut timing)) = self.waiting.remove(i) {
                timing.finished = Some(Instant::now());
                self.flight.push(TraceRecord {
                    id: req.id,
                    outcome: "cancelled",
                    timing,
                    prompt_len: req.prompt.len(),
                    tokens_generated: 0,
                    pages_reused: 0,
                    pages_allocated: 0,
                });
            }
            self.cache.share.requests_cancelled += 1;
            return true;
        }
        for lane in 0..self.lanes.len() {
            if matches!(&self.lanes[lane], Lane::Active(a) if a.req.id == id) {
                let lane_state = std::mem::replace(&mut self.lanes[lane], Lane::Free);
                if let Lane::Active(mut a) = lane_state {
                    self.cache.drop_seq(a.seq);
                    a.timing.finished = Some(Instant::now());
                    if let Some(us) = a.timing.total_us() {
                        self.stats.request_total.record_us(us);
                    }
                    self.flight.push(TraceRecord {
                        id: a.req.id,
                        outcome: "cancelled",
                        prompt_len: a.req.prompt.len(),
                        tokens_generated: a.generated.len(),
                        pages_reused: a.prefix_hit_pages,
                        pages_allocated: self.pages_allocated_for(a.pos, a.prefix_hit_pages),
                        timing: a.timing,
                    });
                }
                self.cache.share.requests_cancelled += 1;
                // pages went back to the pool: a memoized admission
                // denial may now be stale
                self.admit_denied = None;
                return true;
            }
        }
        false
    }

    /// Fresh pages a sequence at `pos` cached tokens allocated beyond
    /// its adopted prefix (an estimate: CoW tail copies count as
    /// allocations, which they are).
    fn pages_allocated_for(&self, pos: usize, prefix_hit_pages: usize) -> usize {
        pos.div_ceil(self.cfg.page_tokens)
            .saturating_sub(prefix_hit_pages)
    }

    /// Flight-record a request the *server* shed before submission
    /// (bounded queue full): the engine never queued it, so the server
    /// hands it over for the record only.  Counter bumps stay at the
    /// call site.
    pub fn record_shed(&mut self, req: &Request) {
        let mut timing = Timing::new();
        timing.received = req.received_at;
        timing.parsed = req.parsed_at;
        timing.finished = Some(Instant::now());
        self.flight.push(TraceRecord {
            id: req.id,
            outcome: "shed",
            timing,
            prompt_len: req.prompt.len(),
            tokens_generated: 0,
            pages_reused: 0,
            pages_allocated: 0,
        });
    }

    /// The most recent `k` flight-recorder timelines, newest first.
    pub fn recent_traces(&self, k: usize) -> Vec<TraceRecord> {
        self.flight.recent(k)
    }

    /// Shed every request still waiting for admission (graceful drain:
    /// the listener is closed, these will never run).  Each gets a
    /// `finish: "rejected"` completion so connected clients hear a
    /// definitive answer before the socket closes.
    pub fn shed_waiting(&mut self) -> usize {
        let shed = self.waiting.len();
        while let Some((req, mut timing)) = self.waiting.pop_front() {
            timing.finished = Some(Instant::now());
            if let Some(us) = timing.total_us() {
                self.stats.request_total.record_us(us);
            }
            self.flight.push(TraceRecord {
                id: req.id,
                outcome: "shed",
                timing: timing.clone(),
                prompt_len: req.prompt.len(),
                tokens_generated: 0,
                pages_reused: 0,
                pages_allocated: 0,
            });
            self.completions.push(Completion {
                id: req.id,
                tokens: Vec::new(),
                prompt_len: req.prompt.len(),
                prefix_hit_pages: 0,
                pages_allocated: 0,
                timing,
                finish: FinishReason::Rejected,
                trace: req.trace,
            });
            self.cache.share.requests_shed += 1;
        }
        shed
    }

    /// Finish lanes and expire queued requests whose deadline passed.
    /// With deadlines unconfigured (the default) every `deadline` is
    /// `None` and this never touches a lane.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        for lane in 0..self.lanes.len() {
            let expired = matches!(
                &self.lanes[lane],
                Lane::Active(a) if a.deadline.is_some_and(|d| d <= now)
            );
            if expired {
                self.finish_lane(lane, FinishReason::Timeout);
            }
        }
        // queued requests can expire before ever reaching a lane
        // (admission backpressure under overload)
        let default_ms = self.cfg.request_timeout_ms;
        let mut i = 0;
        while i < self.waiting.len() {
            let (req, timing) = &self.waiting[i];
            let expired = req
                .deadline_from(timing.submitted, default_ms)
                .is_some_and(|d| d <= now);
            if !expired {
                i += 1;
                continue;
            }
            let (req, mut timing) = self.waiting.remove(i).unwrap();
            timing.finished = Some(Instant::now());
            if let Some(us) = timing.total_us() {
                self.stats.request_total.record_us(us);
            }
            self.flight.push(TraceRecord {
                id: req.id,
                outcome: "timeout",
                timing: timing.clone(),
                prompt_len: req.prompt.len(),
                tokens_generated: 0,
                pages_reused: 0,
                pages_allocated: 0,
            });
            self.completions.push(Completion {
                id: req.id,
                tokens: Vec::new(),
                prompt_len: req.prompt.len(),
                prefix_hit_pages: 0,
                pages_allocated: 0,
                timing,
                finish: FinishReason::Timeout,
                trace: req.trace,
            });
            self.cache.share.requests_timed_out += 1;
        }
    }

    // ------------------------------------------------------------------

    fn admit(&mut self) -> Result<()> {
        let max_seq = self.model.meta.max_seq;
        // nothing admission-relevant changed since the last denial:
        // the head request would be re-denied, so skip the probe
        let cache_state = (self.cache.available_pages(), self.cache.prefix_index_len());
        if self.admit_denied == Some(cache_state) {
            return Ok(());
        }
        while let Some(free_lane) = self.lanes.iter().position(|l| matches!(l, Lane::Free)) {
            let Some((req, mut timing)) = self.waiting.pop_front() else {
                break;
            };
            let total = req.prompt.len() + req.max_new_tokens;
            if req.prompt.is_empty() || total > max_seq {
                timing.finished = Some(Instant::now());
                self.flight.push(TraceRecord {
                    id: req.id,
                    outcome: "rejected",
                    timing: timing.clone(),
                    prompt_len: req.prompt.len(),
                    tokens_generated: 0,
                    pages_reused: 0,
                    pages_allocated: 0,
                });
                self.completions.push(Completion {
                    id: req.id,
                    tokens: Vec::new(),
                    prompt_len: req.prompt.len(),
                    prefix_hit_pages: 0,
                    pages_allocated: 0,
                    timing,
                    finish: FinishReason::Rejected,
                    trace: req.trace,
                });
                continue;
            }
            // prefix-aware admission: only the pages this request needs
            // *after* index reuse count against the pool, so a burst of
            // same-prefix requests admits far more lanes
            if !self.cache.can_admit_prompt(&req.prompt, total) {
                // backpressure: requeue, stop admitting, and remember
                // the pool/index snapshot so the probe isn't re-run
                // every step while nothing changes
                self.waiting.push_front((req, timing));
                self.admit_denied =
                    Some((self.cache.available_pages(), self.cache.prefix_index_len()));
                break;
            }
            let seq = self.next_seq;
            self.next_seq += 1;
            timing.admitted = Some(Instant::now());
            if let Some(us) = timing.queue_wait_us() {
                self.stats.queue_wait.record_us(us);
            }
            // prefix-hit accounting lives in cache.share (single source
            // of truth); the per-request count rides on the completion
            let reuse = self.cache.start_seq_with_prompt(seq, &req.prompt)?;
            self.admit_denied = None;
            timing.prefix_walk = Some(Instant::now());
            // adopted tokens are already cached; prefill resumes after
            // them — at a *token*, not a page, boundary: with the radix
            // index a slot-range copy can cover a mid-page run (e.g.
            // 15 of a 16-token page), and the first chunk then encodes
            // only the divergent suffix into the open copied tail.
            // Keep ≥ 1 prompt token to run so the first generated
            // token's logits exist — on a full-prefix hit the last
            // prompt token is recomputed (its cache slot is masked by
            // pos0) and its append is skipped.
            let consumed = reuse.tokens.min(req.prompt.len() - 1);
            let deadline = req.deadline_from(timing.submitted, self.cfg.request_timeout_ms);
            self.lanes[free_lane] = Lane::Active(Box::new(ActiveSeq {
                last_token: *req.prompt.first().unwrap(),
                req,
                timing,
                seq,
                pos: reuse.tokens,
                generated: Vec::new(),
                phase: Phase::Prefill { consumed },
                prefix_hit_pages: reuse.pages,
                deadline,
                last_token_at: None,
            }));
        }
        Ok(())
    }

    fn gather_lanes(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let b = self.model.batch();
        let m = &self.model.meta;
        let (l, h, dh, t_max) = (m.n_layers, m.n_heads, m.d_head, m.max_seq);
        // active lanes are fully overwritten by the strip gather below;
        // only a free lane that previously held a sequence would leak
        // stale cache bytes, so zero exactly those regions, once
        for lane in 0..b {
            match self.lanes[lane] {
                Lane::Free if self.lane_dirty[lane] => {
                    let len = h * t_max * dh;
                    for layer in 0..l {
                        let base = ((layer * b) + lane) * len;
                        self.k_buf[base..base + len].fill(0.0);
                        self.v_buf[base..base + len].fill(0.0);
                    }
                    self.lane_dirty[lane] = false;
                }
                Lane::Active(_) => self.lane_dirty[lane] = true,
                Lane::Free => {}
            }
        }
        // one cross-lane drain: every active lane's strip units share a
        // single scope_units queue instead of per-lane barriers
        self.lane_jobs.clear();
        for lane in 0..b {
            if let Lane::Active(a) = &self.lanes[lane] {
                self.lane_jobs.push((a.seq, lane));
            }
        }
        if !self.lane_jobs.is_empty() {
            self.cache.gather_lanes_into_batch_ws(
                &self.lane_jobs,
                b,
                t_max,
                &mut self.k_buf,
                &mut self.v_buf,
                &mut self.gather_ws,
            )?;
        }
        let el = t0.elapsed();
        self.stats.gather.record(el);
        if let Some(p) = &self.stats.profile {
            p.gather.record(el);
        }
        Ok(())
    }

    /// Stage tokens `skip..c` of a `(L, B, H, P, dh)` prefill chunk for
    /// batch lane `lane` into the persistent run buffers (token-major
    /// `[t][layer][head][dh]`, the batch-encode input layout) and append
    /// them in one [`CacheManager::append_run`] call — the whole
    /// chunk's `(c - skip) × L × H` vectors per side go through a single
    /// `encode_batch`.  `skip` > 0 only on a full-prefix hit, where the
    /// chunk's leading token(s) are already cached in adopted pages and
    /// must not be appended again.
    fn append_chunk_run(
        &mut self,
        seq: SeqId,
        lane: usize,
        k_chunk: &[f32],
        v_chunk: &[f32],
        p: usize,
        c: usize,
        skip: usize,
    ) -> Result<()> {
        let m = &self.model.meta;
        let (l, b, h, dh) = (m.n_layers, m.serve_batch, m.n_heads, m.d_head);
        debug_assert!(skip <= c && c <= p);
        debug_assert_eq!(k_chunk.len(), l * b * h * p * dh);
        let n = c - skip;
        debug_assert!(self.chunk_k.len() >= n * l * h * dh);
        for layer in 0..l {
            for head in 0..h {
                let src0 = (((layer * b) + lane) * h + head) * p;
                let dst0 = (layer * h + head) * dh;
                for j in 0..n {
                    let src = (src0 + skip + j) * dh;
                    let dst = j * l * h * dh + dst0;
                    self.chunk_k[dst..dst + dh].copy_from_slice(&k_chunk[src..src + dh]);
                    self.chunk_v[dst..dst + dh].copy_from_slice(&v_chunk[src..src + dh]);
                }
            }
        }
        let t0 = Instant::now();
        self.cache.append_run(
            seq,
            &self.chunk_k[..n * l * h * dh],
            &self.chunk_v[..n * l * h * dh],
            n,
        )?;
        let el = t0.elapsed();
        self.stats.append.record(el);
        if let Some(prof) = &self.stats.profile {
            prof.append.record(el);
        }
        let (cb, ub) = self.cache.slot_bytes();
        Counters::bump(&self.stats.counters.bytes_compressed, (cb * n) as u64);
        Counters::bump(&self.stats.counters.bytes_uncompressed, (ub * n) as u64);
        Ok(())
    }

    /// Append token `j` of a (L, B, H, P, dh)-shaped chunk (P = 1 for
    /// decode outputs) for batch lane `lane` to sequence `seq`.  The
    /// token is staged into the persistent `tok_k`/`tok_v` buffers
    /// (contiguous `[layer][head][dh]`, the batch-encode input layout),
    /// so steady-state appends allocate nothing.
    fn append_from_chunk(
        &mut self,
        seq: SeqId,
        lane: usize,
        k_chunk: &[f32],
        v_chunk: &[f32],
        p: usize,
        j: usize,
    ) -> Result<()> {
        let m = &self.model.meta;
        let (l, b, h, dh) = (m.n_layers, m.serve_batch, m.n_heads, m.d_head);
        debug_assert_eq!(k_chunk.len(), l * b * h * p * dh);
        debug_assert_eq!(self.tok_k.len(), l * h * dh);
        for layer in 0..l {
            for head in 0..h {
                let src = ((((layer * b) + lane) * h + head) * p + j) * dh;
                let dst = (layer * h + head) * dh;
                self.tok_k[dst..dst + dh].copy_from_slice(&k_chunk[src..src + dh]);
                self.tok_v[dst..dst + dh].copy_from_slice(&v_chunk[src..src + dh]);
            }
        }
        let t0 = Instant::now();
        self.cache.append_token(seq, &self.tok_k, &self.tok_v)?;
        let el = t0.elapsed();
        self.stats.append.record(el);
        if let Some(prof) = &self.stats.profile {
            prof.append.record(el);
        }
        let (c, u) = self.cache.slot_bytes();
        Counters::bump(&self.stats.counters.bytes_compressed, c as u64);
        Counters::bump(&self.stats.counters.bytes_uncompressed, u as u64);
        Ok(())
    }

    fn step_prefill(&mut self) -> Result<()> {
        let b = self.model.batch();
        let p = self.model.meta.prefill_chunk;
        let vocab = self.model.meta.vocab;
        self.gather_lanes()?;
        let mut toks = vec![0i32; b * p];
        let mut pos0 = vec![0i32; b];
        let mut chunk_len = vec![0usize; b];
        for lane in 0..b {
            if let Lane::Active(a) = &self.lanes[lane] {
                if let Phase::Prefill { consumed } = a.phase {
                    let c = (a.req.prompt.len() - consumed).min(p);
                    for j in 0..c {
                        toks[lane * p + j] = a.req.prompt[consumed + j];
                    }
                    // chunk positions start at `consumed`, which can
                    // trail `pos` by one on a full-prefix hit; the
                    // artifact masks cache slots ≥ pos0, so the
                    // recomputed token never double-attends itself
                    pos0[lane] = consumed as i32;
                    chunk_len[lane] = c;
                }
            }
        }
        let t0 = Instant::now();
        let out = self
            .model
            .prefill_chunk(&toks, &pos0, &self.k_buf, &self.v_buf)?;
        let el = t0.elapsed();
        self.stats.prefill_step.record(el);
        if let Some(p) = &self.stats.profile {
            p.forward.record(el);
        }

        let t_emit = Instant::now();
        for lane in 0..b {
            let c = chunk_len[lane];
            if c == 0 {
                continue;
            }
            let (seq, consumed, pos) = match &self.lanes[lane] {
                Lane::Active(a) => match a.phase {
                    Phase::Prefill { consumed } => (a.seq, consumed, a.pos),
                    _ => unreachable!(),
                },
                _ => unreachable!(),
            };
            // tokens already cached by prefix adoption (pos > consumed
            // only on a full-prefix hit) are recomputed for their
            // logits but not re-appended
            let skip = pos - consumed;
            debug_assert!(skip <= c);
            self.append_chunk_run(seq, lane, &out.k_new, &out.v_new, p, c, skip)?;
            Counters::bump(&self.stats.counters.tokens_prefilled, c as u64);
            let a = match &mut self.lanes[lane] {
                Lane::Active(a) => a,
                _ => unreachable!(),
            };
            a.pos += c - skip;
            let done = consumed + c >= a.req.prompt.len();
            if done {
                // sample the first generated token from the logits at the
                // last real prompt position of this chunk
                let row = &out.logits[(lane * p + (c - 1)) * vocab..][..vocab];
                let tok = argmax(row) as i32;
                let now = Instant::now();
                a.timing.prefill_done = Some(now);
                a.timing.first_token = Some(now);
                a.last_token_at = Some(now);
                self.stats.ttft.record(now - a.timing.submitted);
                a.generated.push(tok);
                if a.req.stream {
                    self.token_events.push(TokenEvent {
                        id: a.req.id,
                        index: a.generated.len() - 1,
                        token: tok,
                    });
                }
                a.last_token = tok;
                a.phase = Phase::Decode;
                Counters::bump(&self.stats.counters.tokens_decoded, 1);
                self.maybe_finish(lane);
            } else {
                a.phase = Phase::Prefill {
                    consumed: consumed + c,
                };
            }
        }
        if let Some(p) = &self.stats.profile {
            p.emit.record(t_emit.elapsed());
        }
        Ok(())
    }

    fn step_decode(&mut self) -> Result<()> {
        let b = self.model.batch();
        let vocab = self.model.meta.vocab;
        self.gather_lanes()?;
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        for lane in 0..b {
            if let Lane::Active(a) = &self.lanes[lane] {
                toks[lane] = a.last_token;
                pos[lane] = a.pos as i32;
                active[lane] = true;
            }
        }
        let t0 = Instant::now();
        let out = self.model.decode_step(&toks, &pos, &self.k_buf, &self.v_buf)?;
        let el = t0.elapsed();
        self.stats.decode_step.record(el);
        if let Some(p) = &self.stats.profile {
            p.forward.record(el);
        }

        let t_emit = Instant::now();
        for lane in 0..b {
            if !active[lane] {
                continue;
            }
            let seq = match &self.lanes[lane] {
                Lane::Active(a) => a.seq,
                _ => unreachable!(),
            };
            // the processed token's K/V enters the cache
            self.append_from_chunk(seq, lane, &out.k_new, &out.v_new, 1, 0)?;
            let a = match &mut self.lanes[lane] {
                Lane::Active(a) => a,
                _ => unreachable!(),
            };
            a.pos += 1;
            let row = &out.logits[lane * vocab..(lane + 1) * vocab];
            let tok = argmax(row) as i32;
            let now = Instant::now();
            if a.timing.first_token.is_none() {
                a.timing.first_token = Some(now);
                self.stats.ttft.record(now - a.timing.submitted);
            } else if let Some(prev) = a.last_token_at {
                self.stats.inter_token.record(now - prev);
            }
            a.last_token_at = Some(now);
            a.generated.push(tok);
            if a.req.stream {
                self.token_events.push(TokenEvent {
                    id: a.req.id,
                    index: a.generated.len() - 1,
                    token: tok,
                });
            }
            a.last_token = tok;
            Counters::bump(&self.stats.counters.tokens_decoded, 1);
            self.maybe_finish(lane);
        }
        if let Some(p) = &self.stats.profile {
            p.emit.record(t_emit.elapsed());
        }
        Ok(())
    }

    fn maybe_finish(&mut self, lane: usize) {
        let finish = {
            let a = match &self.lanes[lane] {
                Lane::Active(a) => a,
                _ => return,
            };
            if a.generated.len() >= a.req.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else if a.pos + 1 >= self.model.meta.max_seq {
                Some(FinishReason::ContextFull)
            } else {
                None
            }
        };
        if let Some(reason) = finish {
            self.finish_lane(lane, reason);
        }
    }

    /// Retire an active lane with `reason`: pages released, lane freed,
    /// completion pushed (with whatever tokens were generated — a
    /// timeout returns the partial output).
    fn finish_lane(&mut self, lane: usize, reason: FinishReason) {
        let lane_state = std::mem::replace(&mut self.lanes[lane], Lane::Free);
        let mut a = match lane_state {
            Lane::Active(a) => a,
            _ => return,
        };
        a.timing.finished = Some(Instant::now());
        self.cache.drop_seq(a.seq);
        if reason == FinishReason::Timeout {
            self.cache.share.requests_timed_out += 1;
        }
        if let Some(us) = a.timing.total_us() {
            self.stats.request_total.record_us(us);
        }
        let pages_allocated = self.pages_allocated_for(a.pos, a.prefix_hit_pages);
        self.flight.push(TraceRecord {
            id: a.req.id,
            outcome: reason.as_str(),
            timing: a.timing.clone(),
            prompt_len: a.req.prompt.len(),
            tokens_generated: a.generated.len(),
            pages_reused: a.prefix_hit_pages,
            pages_allocated,
        });
        self.completions.push(Completion {
            id: a.req.id,
            tokens: a.generated,
            prompt_len: a.req.prompt.len(),
            prefix_hit_pages: a.prefix_hit_pages,
            pages_allocated,
            timing: a.timing,
            finish: reason,
            trace: a.req.trace,
        });
    }

    /// One-line serving snapshot for the periodic server stats log:
    /// page-pool residency (live/cached/high-water, shared vs
    /// exclusive), prefix-sharing activity, and throughput counters.
    pub fn stats_line(&self) -> String {
        let c = &self.stats.counters;
        let cold = match self.cache.store() {
            Some(s) => format!(" cold={}({:.1}MB)", s.len(), s.disk_bytes() as f64 / 1e6),
            None => String::new(),
        };
        format!(
            "pages: live={} cached={}{cold} hw={}/{} shared={} excl={} | {} | req={} tok={}p+{}d kv={:.1}x",
            self.cache.live_pages(),
            self.cache.cached_pages(),
            self.cache.high_water_pages(),
            self.cache.page_capacity(),
            self.cache.shared_pages(),
            self.cache.exclusive_pages(),
            self.cache.share.summary(),
            Counters::get(&c.requests),
            Counters::get(&c.tokens_prefilled),
            Counters::get(&c.tokens_decoded),
            c.compression_ratio(),
        )
    }

    /// Detach everything `/metrics` needs into a plain-data snapshot.
    /// The serve loop calls this about once a second and renders the
    /// exposition into a shared string; scrapes are served from that
    /// string, never from the engine.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let mut s = MetricsSnapshot {
            share: self.cache.share.clone(),
            counters: self.stats.counters.fields(),
            compression_ratio: self.stats.counters.compression_ratio(),
            ..MetricsSnapshot::default()
        };
        s.pages = PageGauges {
            live: self.cache.live_pages() as u64,
            cached: self.cache.cached_pages() as u64,
            capacity: self.cache.page_capacity() as u64,
            high_water: self.cache.high_water_pages() as u64,
            shared: self.cache.shared_pages() as u64,
            exclusive: self.cache.exclusive_pages() as u64,
            cold: self.cache.store().map_or(0, |st| st.len() as u64),
            store_disk_bytes: self.cache.store().map_or(0, |st| st.disk_bytes() as u64),
            store_attached: self.cache.store().is_some() as u64,
        };
        s.hists = vec![
            ("isoquant_ttft_seconds", self.stats.ttft.snapshot()),
            ("isoquant_inter_token_seconds", self.stats.inter_token.snapshot()),
            ("isoquant_queue_wait_seconds", self.stats.queue_wait.snapshot()),
            ("isoquant_request_total_seconds", self.stats.request_total.snapshot()),
            ("isoquant_decode_step_seconds", self.stats.decode_step.snapshot()),
            ("isoquant_prefill_step_seconds", self.stats.prefill_step.snapshot()),
            ("isoquant_gather_seconds", self.stats.gather.snapshot()),
            ("isoquant_append_seconds", self.stats.append.snapshot()),
        ];
        if let Some(p) = &self.stats.profile {
            s.phases = p.named().iter().map(|(n, h)| (*n, h.snapshot())).collect();
        }
        s
    }
}
