//! The serving coordinator (L3, the paper's deployment context):
//! request types, dynamic batcher, and the iteration-level scheduling
//! engine with compressed-KV decode.

pub mod batcher;
pub mod engine;
pub mod request;

pub use batcher::Batcher;
pub use engine::{Engine, EngineStats, PhaseHists, TokenEvent};
pub use request::{
    Completion, FinishReason, FlightRecorder, Request, RequestId, Timing, TraceRecord,
};
