//! Dynamic batcher: groups incoming requests into admission batches,
//! trading a bounded wait (`window`) for fuller batches — the classic
//! throughput/latency knob of serving systems.
//!
//! Drained batches are stable-sorted by prompt so requests sharing a
//! prefix land in the *same* admission wave: the first of them seals
//! and publishes the prefix pages, the rest adopt them before pool
//! pressure could evict the entries.  FIFO order is preserved within a
//! prefix group (stable sort) and selection into the batch stays FIFO.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Request;

#[derive(Debug)]
pub struct Batcher {
    window: Duration,
    max_batch: usize,
    queue: VecDeque<(Request, Instant)>,
}

impl Batcher {
    pub fn new(window: Duration, max_batch: usize) -> Batcher {
        assert!(max_batch > 0);
        Batcher {
            window,
            max_batch,
            queue: VecDeque::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.submit_at(req, Instant::now());
    }

    pub fn submit_at(&mut self, req: Request, now: Instant) {
        self.queue.push_back((req, now));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Returns a batch when (a) `max_batch` requests are waiting, or
    /// (b) the oldest request has waited ≥ `window`.  Otherwise `None`
    /// (caller keeps decoding and polls again).  The batch is grouped
    /// by shared prefix (stable sort by prompt).
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now.duration_since(self.queue.front().unwrap().1);
        if self.queue.len() >= self.max_batch || oldest_wait >= self.window {
            let n = self.queue.len().min(self.max_batch);
            let mut batch: Vec<Request> = self.queue.drain(..n).map(|(r, _)| r).collect();
            group_by_prefix(&mut batch);
            Some(batch)
        } else {
            None
        }
    }

    /// Drop a still-queued request (client disconnected before
    /// admission).  Returns true if the request was found and removed.
    pub fn cancel(&mut self, id: u64) -> bool {
        let before = self.queue.len();
        self.queue.retain(|(r, _)| r.id != id);
        self.queue.len() != before
    }

    /// Pull up to `n` requests immediately (used when lanes free up
    /// mid-flight — continuous batching does not wait for the window),
    /// grouped by shared prefix like [`Batcher::poll`].
    pub fn take_up_to(&mut self, n: usize) -> Vec<Request> {
        let n = n.min(self.queue.len());
        let mut batch: Vec<Request> = self.queue.drain(..n).map(|(r, _)| r).collect();
        group_by_prefix(&mut batch);
        batch
    }

    /// Pull up to `n` requests, preferring the deepest cached prefix
    /// first.  `lcp` is a read-only probe of the cache index (longest
    /// cached prefix, in tokens, for a prompt).  Used instead of
    /// [`Batcher::take_up_to`] when the page pool is under pressure:
    /// admitting the requests that re-use the most cached tokens costs
    /// the fewest fresh pages per admission, which keeps the pool from
    /// evicting exactly the prefixes the rest of the queue is about to
    /// ask for.  FIFO order breaks depth ties, and requests left behind
    /// re-queue in their original submit order with their original
    /// arrival times (their window clocks keep running).
    pub fn take_up_to_by_lcp(&mut self, n: usize, lcp: impl Fn(&[i32]) -> usize) -> Vec<Request> {
        let n = n.min(self.queue.len());
        if n == 0 {
            return Vec::new();
        }
        let mut ranked: Vec<(usize, usize, Request, Instant)> = self
            .queue
            .drain(..)
            .enumerate()
            .map(|(pos, (r, t))| (lcp(&r.prompt), pos, r, t))
            .collect();
        // deepest cached prefix first; submit position breaks ties
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut rest = ranked.split_off(n);
        let mut batch: Vec<Request> = ranked.into_iter().map(|(_, _, r, _)| r).collect();
        group_by_prefix(&mut batch);
        rest.sort_by_key(|e| e.1);
        for (_, _, r, t) in rest {
            self.queue.push_back((r, t));
        }
        batch
    }
}

/// Stable-sort a drained batch so shared-prefix prompts sit adjacent
/// (lexicographic by token ids groups equal prompts and common-prefix
/// prompts alike); equal prompts keep their FIFO order.
fn group_by_prefix(batch: &mut [Request]) {
    batch.sort_by(|a, b| a.prompt.cmp(&b.prompt));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn full_batch_released_immediately() {
        let mut b = Batcher::new(Duration::from_millis(100), 2);
        let t0 = Instant::now();
        b.submit_at(req(1), t0);
        assert!(b.poll(t0).is_none(), "single request waits for window");
        b.submit_at(req(2), t0);
        let batch = b.poll(t0).expect("full batch releases");
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 1);
    }

    #[test]
    fn window_expiry_releases_partial_batch() {
        let mut b = Batcher::new(Duration::from_millis(10), 8);
        let t0 = Instant::now();
        b.submit_at(req(1), t0);
        assert!(b.poll(t0 + Duration::from_millis(5)).is_none());
        let batch = b.poll(t0 + Duration::from_millis(11)).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn overflow_stays_queued() {
        let mut b = Batcher::new(Duration::from_millis(0), 2);
        let t0 = Instant::now();
        for i in 0..5 {
            b.submit_at(req(i), t0);
        }
        assert_eq!(b.poll(t0).unwrap().len(), 2);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.take_up_to(10).len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn cancel_drops_queued_request_only() {
        let mut b = Batcher::new(Duration::from_millis(0), 4);
        let t0 = Instant::now();
        for i in 0..3 {
            b.submit_at(req(i), t0);
        }
        assert!(b.cancel(1), "queued request is cancellable");
        assert!(!b.cancel(1), "second cancel is a no-op");
        assert!(!b.cancel(99), "unknown id is a no-op");
        assert_eq!(b.pending(), 2);
        let ids: Vec<u64> = b.poll(t0).unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2], "survivors keep FIFO order");
    }

    #[test]
    fn shared_prefix_requests_grouped_in_batch() {
        let mut b = Batcher::new(Duration::from_millis(0), 8);
        let t0 = Instant::now();
        let mk = |id, prompt: &[i32]| Request::new(id, prompt.to_vec(), 1);
        // interleaved prefix groups; ids record submit order
        b.submit_at(mk(0, &[9, 9, 1]), t0);
        b.submit_at(mk(1, &[2, 2]), t0);
        b.submit_at(mk(2, &[9, 9, 1]), t0);
        b.submit_at(mk(3, &[9, 9, 5]), t0);
        b.submit_at(mk(4, &[2, 2]), t0);
        let ids: Vec<u64> = b.poll(t0).unwrap().iter().map(|r| r.id).collect();
        // groups adjacent ([2,2] < [9,9,…]), FIFO within each group,
        // common-prefix prompts ([9,9,1] and [9,9,5]) adjacent too
        assert_eq!(ids, vec![1, 4, 0, 2, 3]);
        // take_up_to groups as well
        b.submit_at(mk(5, &[7]), t0);
        b.submit_at(mk(6, &[3]), t0);
        let ids: Vec<u64> = b.take_up_to(2).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![6, 5]);
    }

    #[test]
    fn lcp_take_prefers_deepest_cached_prefix() {
        let mut b = Batcher::new(Duration::from_millis(0), 8);
        let t0 = Instant::now();
        let mk = |id, prompt: &[i32]| Request::new(id, prompt.to_vec(), 1);
        b.submit_at(mk(0, &[1]), t0); // lcp 0
        b.submit_at(mk(1, &[5, 5, 5, 5]), t0); // lcp 4
        b.submit_at(mk(2, &[5, 5]), t0); // lcp 2
        b.submit_at(mk(3, &[5, 5, 9]), t0); // lcp 2 (FIFO after id 2)
        let lcp = |p: &[i32]| p.iter().take_while(|&&t| t == 5).count();
        let ids: Vec<u64> = b.take_up_to_by_lcp(3, lcp).iter().map(|r| r.id).collect();
        // deepest first wins selection; the drained batch itself is
        // still prefix-grouped (lexicographic), so [5,5] < [5,5,5,5]
        assert_eq!(ids, vec![2, 1, 3]);
        // the shallow request stays queued, untouched
        assert_eq!(b.pending(), 1);
        assert_eq!(b.take_up_to(1)[0].id, 0);
    }

    #[test]
    fn lcp_take_requeues_remainder_in_submit_order() {
        let mut b = Batcher::new(Duration::from_millis(0), 8);
        let t0 = Instant::now();
        let mk = |id, prompt: &[i32]| Request::new(id, prompt.to_vec(), 1);
        b.submit_at(mk(0, &[3]), t0);
        b.submit_at(mk(1, &[5, 5]), t0);
        b.submit_at(mk(2, &[4]), t0);
        b.submit_at(mk(3, &[6]), t0);
        let lcp = |p: &[i32]| p.iter().take_while(|&&t| t == 5).count();
        let ids: Vec<u64> = b.take_up_to_by_lcp(1, lcp).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1], "deepest cached prefix admitted first");
        // survivors keep FIFO order for later plain draining
        let ids: Vec<u64> = b.take_up_to(3).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 3]);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(Duration::from_millis(0), 4);
        let t0 = Instant::now();
        for i in 0..4 {
            b.submit_at(req(i), t0);
        }
        let ids: Vec<u64> = b.poll(t0).unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }
}
