//! Request types flowing through the serving engine.

use std::time::Instant;

pub type RequestId = u64;

/// A generation request (token ids in, token ids out — tokenization is
//  out of scope for the reproduction; the E2E example drives the engine
//  with synthetic token streams).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// per-request deadline in milliseconds from submission (`None` =
    /// use the server default, `[server] request_timeout_ms`; both
    /// unset/0 = no deadline).  Expiry finishes the request with
    /// [`FinishReason::Timeout`], returning whatever tokens were
    /// generated so far
    pub deadline_ms: Option<u64>,
    /// opt-in token-by-token streaming over the wire (`"stream": true`):
    /// the engine queues a [`super::TokenEvent`] per generated token
    /// ahead of the terminal completion.  Off by default — the
    /// non-streaming wire protocol is untouched
    pub stream: bool,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            deadline_ms: None,
            stream: false,
        }
    }

    /// Absolute deadline for a request submitted at `submitted`:
    /// the per-request `deadline_ms` wins over the server default
    /// (`default_ms`); 0 in either place means "no deadline from that
    /// source".
    pub fn deadline_from(&self, submitted: Instant, default_ms: u64) -> Option<Instant> {
        let ms = match self.deadline_ms {
            Some(0) | None => default_ms,
            Some(ms) => ms,
        };
        (ms > 0).then(|| submitted + std::time::Duration::from_millis(ms))
    }
}

/// Lifecycle timestamps for latency accounting.
#[derive(Clone, Debug)]
pub struct Timing {
    pub submitted: Instant,
    pub admitted: Option<Instant>,
    pub first_token: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Timing {
    pub fn new() -> Timing {
        Timing {
            submitted: Instant::now(),
            admitted: None,
            first_token: None,
            finished: None,
        }
    }

    /// time-to-first-token in microseconds
    pub fn ttft_us(&self) -> Option<f64> {
        self.first_token
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }

    pub fn total_us(&self) -> Option<f64> {
        self.finished
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }
}

impl Default for Timing {
    fn default() -> Self {
        Timing::new()
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// whole KV pages adopted from the prefix index at admission (0
    /// with prefix sharing off or on a cold prefix)
    pub prefix_hit_pages: usize,
    pub timing: Timing,
    /// why generation stopped
    pub finish: FinishReason,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    /// sequence hit the model's max_seq capacity
    ContextFull,
    /// rejected at admission (pool exhausted / prompt too long)
    Rejected,
    /// client disconnected or explicitly cancelled; lane and pages are
    /// freed immediately (no completion is written — the socket is gone)
    Cancelled,
    /// deadline expired (per-request `deadline_ms` or the
    /// `[server] request_timeout_ms` default); partial tokens returned
    Timeout,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_resolution() {
        let now = Instant::now();
        let mut r = Request::new(1, vec![1], 4);
        // no per-request deadline, no server default -> none
        assert!(r.deadline_from(now, 0).is_none());
        // server default applies
        assert!(r.deadline_from(now, 100).is_some());
        // explicit 0 means "use default", not "deadline at submission"
        r.deadline_ms = Some(0);
        assert!(r.deadline_from(now, 0).is_none());
        // per-request value wins over the default
        r.deadline_ms = Some(50);
        let d = r.deadline_from(now, 10_000).unwrap();
        assert!(d - now <= std::time::Duration::from_millis(50));
    }

    #[test]
    fn timing_fields() {
        let mut t = Timing::new();
        assert!(t.ttft_us().is_none());
        t.first_token = Some(Instant::now());
        t.finished = Some(Instant::now());
        assert!(t.ttft_us().unwrap() >= 0.0);
        assert!(t.total_us().unwrap() >= t.ttft_us().unwrap() * 0.5);
    }
}
