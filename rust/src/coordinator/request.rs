//! Request types flowing through the serving engine.

use std::time::Instant;

pub type RequestId = u64;

/// A generation request (token ids in, token ids out — tokenization is
//  out of scope for the reproduction; the E2E example drives the engine
//  with synthetic token streams).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Lifecycle timestamps for latency accounting.
#[derive(Clone, Debug)]
pub struct Timing {
    pub submitted: Instant,
    pub admitted: Option<Instant>,
    pub first_token: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Timing {
    pub fn new() -> Timing {
        Timing {
            submitted: Instant::now(),
            admitted: None,
            first_token: None,
            finished: None,
        }
    }

    /// time-to-first-token in microseconds
    pub fn ttft_us(&self) -> Option<f64> {
        self.first_token
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }

    pub fn total_us(&self) -> Option<f64> {
        self.finished
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }
}

impl Default for Timing {
    fn default() -> Self {
        Timing::new()
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// whole KV pages adopted from the prefix index at admission (0
    /// with prefix sharing off or on a cold prefix)
    pub prefix_hit_pages: usize,
    pub timing: Timing,
    /// why generation stopped
    pub finish: FinishReason,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    /// sequence hit the model's max_seq capacity
    ContextFull,
    /// rejected at admission (pool exhausted / prompt too long)
    Rejected,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_fields() {
        let mut t = Timing::new();
        assert!(t.ttft_us().is_none());
        t.first_token = Some(Instant::now());
        t.finished = Some(Instant::now());
        assert!(t.ttft_us().unwrap() >= 0.0);
        assert!(t.total_us().unwrap() >= t.ttft_us().unwrap() * 0.5);
    }
}
