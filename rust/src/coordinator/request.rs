//! Request types flowing through the serving engine.

use std::time::Instant;

pub type RequestId = u64;

/// A generation request (token ids in, token ids out — tokenization is
//  out of scope for the reproduction; the E2E example drives the engine
//  with synthetic token streams).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// per-request deadline in milliseconds from submission (`None` =
    /// use the server default, `[server] request_timeout_ms`; both
    /// unset/0 = no deadline).  Expiry finishes the request with
    /// [`FinishReason::Timeout`], returning whatever tokens were
    /// generated so far
    pub deadline_ms: Option<u64>,
    /// opt-in token-by-token streaming over the wire (`"stream": true`):
    /// the engine queues a [`super::TokenEvent`] per generated token
    /// ahead of the terminal completion.  Off by default — the
    /// non-streaming wire protocol is untouched
    pub stream: bool,
    /// opt-in per-request trace (`"trace": true`): the completion line
    /// carries the full lifecycle timeline.  Off by default — the wire
    /// protocol without the knob is byte-identical
    pub trace: bool,
    /// reactor-side stamp: first byte of this request's line observed
    pub received_at: Option<Instant>,
    /// reactor-side stamp: JSON parse finished
    pub parsed_at: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            deadline_ms: None,
            stream: false,
            trace: false,
            received_at: None,
            parsed_at: None,
        }
    }

    /// Absolute deadline for a request submitted at `submitted`:
    /// the per-request `deadline_ms` wins over the server default
    /// (`default_ms`); 0 in either place means "no deadline from that
    /// source".
    pub fn deadline_from(&self, submitted: Instant, default_ms: u64) -> Option<Instant> {
        let ms = match self.deadline_ms {
            Some(0) | None => default_ms,
            Some(ms) => ms,
        };
        (ms > 0).then(|| submitted + std::time::Duration::from_millis(ms))
    }
}

/// Lifecycle timestamps for latency accounting and per-request traces.
/// Stamps before `submitted` come from the reactor thread (absent when
/// a request is injected directly into the engine, e.g. by tests or
/// benches); everything from `submitted` on is stamped by the engine.
#[derive(Clone, Debug)]
pub struct Timing {
    /// wire: first byte of the request line observed by the reactor
    pub received: Option<Instant>,
    /// wire: JSON parse finished
    pub parsed: Option<Instant>,
    /// entered the engine queue
    pub submitted: Instant,
    /// left the queue, lane assigned
    pub admitted: Option<Instant>,
    /// prefix-index walk finished (pages adopted, tail copied)
    pub prefix_walk: Option<Instant>,
    /// prefill finished (prompt fully encoded into the cache)
    pub prefill_done: Option<Instant>,
    pub first_token: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Timing {
    pub fn new() -> Timing {
        Timing {
            received: None,
            parsed: None,
            submitted: Instant::now(),
            admitted: None,
            prefix_walk: None,
            prefill_done: None,
            first_token: None,
            finished: None,
        }
    }

    /// time-to-first-token in microseconds
    pub fn ttft_us(&self) -> Option<f64> {
        self.first_token
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }

    pub fn total_us(&self) -> Option<f64> {
        self.finished
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }

    /// time spent queued before a lane was assigned, in microseconds
    pub fn queue_wait_us(&self) -> Option<f64> {
        self.admitted
            .map(|t| (t - self.submitted).as_secs_f64() * 1e6)
    }

    /// Trace origin: the earliest stamp we have.  Offsets in a rendered
    /// timeline are relative to this instant.
    pub fn origin(&self) -> Instant {
        self.received.unwrap_or(self.submitted)
    }

    /// The timeline as `(stamp name, offset in µs from origin)` pairs,
    /// in lifecycle order, skipping absent stamps.  This is the one
    /// list both the wire trace object and the flight-recorder dump
    /// render from.
    pub fn stamps_us(&self) -> Vec<(&'static str, f64)> {
        let o = self.origin();
        let off = |t: Instant| (t - o).as_secs_f64() * 1e6;
        let mut v = Vec::with_capacity(8);
        if let Some(t) = self.received {
            v.push(("received", off(t)));
        }
        if let Some(t) = self.parsed {
            v.push(("parsed", off(t)));
        }
        v.push(("queued", off(self.submitted)));
        if let Some(t) = self.admitted {
            v.push(("admitted", off(t)));
        }
        if let Some(t) = self.prefix_walk {
            v.push(("prefix_walk", off(t)));
        }
        if let Some(t) = self.prefill_done {
            v.push(("prefill_done", off(t)));
        }
        if let Some(t) = self.first_token {
            v.push(("first_token", off(t)));
        }
        if let Some(t) = self.finished {
            v.push(("finished", off(t)));
        }
        v
    }
}

impl Default for Timing {
    fn default() -> Self {
        Timing::new()
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// whole KV pages adopted from the prefix index at admission (0
    /// with prefix sharing off or on a cold prefix)
    pub prefix_hit_pages: usize,
    /// fresh pages this request allocated (pages beyond the adopted
    /// prefix hit)
    pub pages_allocated: usize,
    pub timing: Timing,
    /// why generation stopped
    pub finish: FinishReason,
    /// the request asked for its timeline on the completion line
    pub trace: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    /// sequence hit the model's max_seq capacity
    ContextFull,
    /// rejected at admission (pool exhausted / prompt too long)
    Rejected,
    /// client disconnected or explicitly cancelled; lane and pages are
    /// freed immediately (no completion is written — the socket is gone)
    Cancelled,
    /// deadline expired (per-request `deadline_ms` or the
    /// `[server] request_timeout_ms` default); partial tokens returned
    Timeout,
}

impl FinishReason {
    /// The wire spelling used by the completion line's `finish` field
    /// and the flight recorder's `outcome`.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::ContextFull => "context_full",
            FinishReason::Rejected => "rejected",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Timeout => "timeout",
        }
    }
}

/// One finished request's timeline, as kept by the [`FlightRecorder`].
#[derive(Clone, Debug)]
pub struct TraceRecord {
    pub id: RequestId,
    /// terminal outcome: "max_tokens" / "context_full" / "rejected" /
    /// "cancelled" / "timeout" / "shed"
    pub outcome: &'static str,
    pub timing: Timing,
    pub prompt_len: usize,
    pub tokens_generated: usize,
    /// whole pages adopted from the prefix index
    pub pages_reused: usize,
    /// fresh pages allocated beyond the reused prefix
    pub pages_allocated: usize,
}

/// Fixed-size ring buffer of the last N request timelines — the flight
/// recorder behind `{"stats": true, "traces": K}`.  Push is O(1) and
/// allocation-free after the ring fills.
#[derive(Debug)]
pub struct FlightRecorder {
    ring: Vec<TraceRecord>,
    cap: usize,
    /// next write position (ring[next] is the oldest entry once full)
    next: usize,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            ring: Vec::with_capacity(cap.max(1)),
            cap: cap.max(1),
            next: 0,
        }
    }

    pub fn push(&mut self, rec: TraceRecord) {
        if self.ring.len() < self.cap {
            self.ring.push(rec);
        } else {
            self.ring[self.next] = rec;
        }
        self.next = (self.next + 1) % self.cap;
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// The most recent `k` records, newest first.
    pub fn recent(&self, k: usize) -> Vec<TraceRecord> {
        let n = self.ring.len().min(k);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // newest is the slot just before `next`, wrapping
            let idx = (self.next + self.cap - 1 - i) % self.cap;
            if idx < self.ring.len() {
                out.push(self.ring[idx].clone());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_resolution() {
        let now = Instant::now();
        let mut r = Request::new(1, vec![1], 4);
        // no per-request deadline, no server default -> none
        assert!(r.deadline_from(now, 0).is_none());
        // server default applies
        assert!(r.deadline_from(now, 100).is_some());
        // explicit 0 means "use default", not "deadline at submission"
        r.deadline_ms = Some(0);
        assert!(r.deadline_from(now, 0).is_none());
        // per-request value wins over the default
        r.deadline_ms = Some(50);
        let d = r.deadline_from(now, 10_000).unwrap();
        assert!(d - now <= std::time::Duration::from_millis(50));
    }

    #[test]
    fn timing_fields() {
        let mut t = Timing::new();
        assert!(t.ttft_us().is_none());
        t.first_token = Some(Instant::now());
        t.finished = Some(Instant::now());
        assert!(t.ttft_us().unwrap() >= 0.0);
        assert!(t.total_us().unwrap() >= t.ttft_us().unwrap() * 0.5);
    }

    #[test]
    fn stamps_are_ordered_and_relative_to_origin() {
        let mut t = Timing::new();
        let base = t.submitted;
        t.received = Some(base - std::time::Duration::from_micros(50));
        t.parsed = Some(base - std::time::Duration::from_micros(10));
        t.admitted = Some(base + std::time::Duration::from_micros(100));
        t.prefix_walk = Some(base + std::time::Duration::from_micros(150));
        t.prefill_done = Some(base + std::time::Duration::from_micros(900));
        t.first_token = Some(base + std::time::Duration::from_micros(1000));
        t.finished = Some(base + std::time::Duration::from_micros(5000));
        let stamps = t.stamps_us();
        let names: Vec<&str> = stamps.iter().map(|(n, _)| *n).collect();
        assert_eq!(
            names,
            [
                "received",
                "parsed",
                "queued",
                "admitted",
                "prefix_walk",
                "prefill_done",
                "first_token",
                "finished"
            ]
        );
        // offsets are relative to `received` and monotone non-decreasing
        assert_eq!(stamps[0].1, 0.0);
        for w in stamps.windows(2) {
            assert!(w[1].1 >= w[0].1, "{:?} before {:?}", w[1], w[0]);
        }
        assert!((t.queue_wait_us().unwrap() - 100.0).abs() < 1.0);
    }

    #[test]
    fn stamps_skip_absent() {
        let t = Timing::new(); // engine-injected: no wire stamps
        let names: Vec<&str> = t.stamps_us().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, ["queued"]);
    }

    #[test]
    fn flight_recorder_ring() {
        let mut fr = FlightRecorder::new(4);
        assert!(fr.is_empty());
        for id in 0..6u64 {
            fr.push(TraceRecord {
                id,
                outcome: "max_tokens",
                timing: Timing::new(),
                prompt_len: 3,
                tokens_generated: 2,
                pages_reused: 0,
                pages_allocated: 1,
            });
        }
        assert_eq!(fr.len(), 4, "ring capped");
        let recent = fr.recent(10);
        let ids: Vec<u64> = recent.iter().map(|r| r.id).collect();
        assert_eq!(ids, [5, 4, 3, 2], "newest first, oldest evicted");
        assert_eq!(fr.recent(2).len(), 2);
    }
}
