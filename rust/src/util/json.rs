//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`) and the
//! server line protocol.  Full RFC 8259 value model; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `obj.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---------- construction ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---------- parsing ----------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let bytes = input.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------- writing ----------

    #[allow(clippy::inherent_to_string_shadow_display)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&{
            let mut s = String::new();
            self.write(&mut s);
            s
        })
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs outside BMP are not
                            // needed by our manifests; map lone
                            // surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy the full utf-8 sequence
                    let s = &self.b[self.pos..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c\nd"}], "e": false}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c\nd"
        );
        assert_eq!(v.get("e").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"o":{"k":true}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line1\nline2\t\"q\"\\".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn u_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn manifest_shape() {
        let m = Json::parse(
            r#"{"version":1,"artifacts":[{"name":"a","inputs":[{"shape":[64,128],"dtype":"f32"}]}]}"#,
        )
        .unwrap();
        let a = &m.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = a
            .path(&["inputs"])
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![64, 128]);
    }
}
