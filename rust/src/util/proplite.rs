//! proptest-lite: a tiny property-based testing harness (proptest is not
//! vendorable offline).
//!
//! Usage:
//! ```ignore
//! proplite::check(200, 0xC0FFEE, |g| {
//!     let d = g.usize_in(4, 512) & !3;
//!     let x = g.vec_f32(d, 3.0);
//!     // ... assert property, return Result<(), String> ...
//!     Ok(())
//! });
//! ```
//! On failure the case index and seed are printed so the exact draw can
//! be replayed deterministically.

use crate::util::prng::Rng;

/// Generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Gaussian vector with the given scale.
    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.gaussian() as f32 * scale).collect()
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }
}

/// Run `cases` random cases of `prop`.  Panics (failing the enclosing
/// `#[test]`) on the first counterexample, printing the replay seed.
pub fn check<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        // derive a per-case seed so cases are independent and replayable
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: Rng::new(case_seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "mismatch at {i}: {x} vs {y} (|Δ|={} > tol {tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(50, 1, |g| {
            let n = g.usize_in(1, 64);
            let v = g.vec_f32(n, 1.0);
            if v.len() == n {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, 2, |g| {
            let n = g.usize_in(0, 100);
            if n < 90 {
                Ok(())
            } else {
                Err(format!("n={n} too big"))
            }
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<usize> = Vec::new();
        check(10, 3, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check(10, 3, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
