//! proptest-lite: a tiny property-based testing harness with
//! Hypothesis-style *integrated shrinking* (proptest is not vendorable
//! offline).
//!
//! Usage:
//! ```ignore
//! proplite::check(200, 0xC0FFEE, |g| {
//!     let d = g.usize_in(4, 512) & !3;
//!     let x = g.vec_f32(d, 3.0);
//!     // ... assert property, return Result<(), String> ...
//!     Ok(())
//! });
//! ```
//!
//! Every tracked draw (`usize_in`, `bool`, `f32_in`, `vec_f32`,
//! `choose`) is recorded on a *tape* of reduced values, where 0 is the
//! minimal draw (range low, `false`, first element, 0.0).  On the first
//! counterexample the harness greedily minimizes the tape — deleting
//! chunks of draws, zeroing chunks, and binary-searching individual
//! scalars toward 0 — re-running the property after each mutation and
//! keeping any strictly simpler tape that still fails.  The panic then
//! reports the *minimal* failure: the replay seed plus a short forced
//! tape instead of case 173 of a 200-case run.
//!
//! Forcing never desynchronizes untracked draws: tracked draws advance
//! the underlying RNG exactly as if unforced and only override the
//! result, so code reaching into `g.rng` directly sees the same stream
//! under replay (those draws just aren't shrinkable).

use crate::util::prng::Rng;

/// Shrink-attempt budget per counterexample.  Bounds worst-case shrink
/// time; the greedy passes normally converge far earlier.
const SHRINK_ATTEMPTS: usize = 2000;

/// Fixed-point scale for `f32_in` fractions on the tape.
const FRAC_SCALE: f64 = u32::MAX as f64;

/// Generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
    /// reduced values recorded for every tracked draw this run
    tape: Vec<u64>,
    /// tape prefix to force instead of the natural draws (shrinking /
    /// replay); draws past its end fall back to the natural values
    forced: Vec<u64>,
    cursor: usize,
}

impl Gen {
    fn new(case_seed: u64, case: usize, forced: Vec<u64>) -> Gen {
        Gen {
            rng: Rng::new(case_seed),
            case,
            tape: Vec::new(),
            forced,
            cursor: 0,
        }
    }

    /// Record one tracked draw: take the forced value if the tape
    /// prefix still covers this position (clamped into `0..=max` so
    /// cross-draw remapping after a chunk deletion stays in range),
    /// else the natural one.
    fn draw(&mut self, natural: u64, max: u64) -> u64 {
        let v = match self.forced.get(self.cursor) {
            Some(&f) => f.min(max),
            None => natural,
        };
        self.cursor += 1;
        self.tape.push(v);
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let natural = self.rng.below(hi - lo + 1) as u64;
        lo + self.draw(natural, (hi - lo) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let x = self.rng.uniform_range(lo as f64, hi as f64);
        let span = (hi - lo) as f64;
        let frac = if span > 0.0 {
            ((x - lo as f64) / span).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let v = self.draw((frac * FRAC_SCALE) as u64, u32::MAX as u64);
        (lo as f64 + (v as f64 / FRAC_SCALE) * span) as f32
    }

    pub fn bool(&mut self) -> bool {
        let natural = self.rng.next_u64() & 1;
        self.draw(natural, 1) == 1
    }

    /// Gaussian vector with the given scale.  Each element rides the
    /// tape as its f32 bit pattern, so zeroed chunks shrink to 0.0.
    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let natural = (self.rng.gaussian() as f32 * scale).to_bits() as u64;
                f32::from_bits(self.draw(natural, u32::MAX as u64) as u32)
            })
            .collect()
    }

    /// Pick one element from a slice (shrinks toward the first).
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let natural = self.rng.below(items.len()) as u64;
        &items[self.draw(natural, (items.len() - 1) as u64) as usize]
    }
}

/// A shrunk counterexample: the per-case replay seed plus the minimal
/// forced tape that still fails, ready for [`replay`].
#[derive(Debug, Clone)]
pub struct Counterexample {
    /// index of the originally failing case
    pub case: usize,
    /// that case's derived seed (feed to [`replay`])
    pub case_seed: u64,
    /// minimal forced draw tape
    pub tape: Vec<u64>,
    /// failure message of the minimal run
    pub message: String,
}

/// Run `cases` random cases of `prop`.  Panics (failing the enclosing
/// `#[test]`) on the first counterexample — after shrinking it —
/// printing the replay seed and the minimal forced tape.
pub fn check<F>(cases: usize, seed: u64, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let Some(cx) = find_counterexample(cases, seed, prop) {
        panic!(
            "property failed at case {case}/{cases} (replay seed {seed:#x}): {msg}\n\
             minimal repro: proplite::replay({seed:#x}, {case}, &{tape:?}, prop)",
            case = cx.case,
            msg = cx.message,
            seed = cx.case_seed,
            tape = cx.tape,
        );
    }
}

/// Like [`check`] but returns the shrunk counterexample instead of
/// panicking — `None` when every case passes.  Lets tests assert *on*
/// the shrinker (e.g. that a seeded violation minimizes to a handful
/// of ops) and lets CI harnesses persist the repro as an artifact.
pub fn find_counterexample<F>(cases: usize, seed: u64, mut prop: F) -> Option<Counterexample>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        // derive a per-case seed so cases are independent and replayable
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(case_seed, case, Vec::new());
        if let Err(msg) = prop(&mut g) {
            let tape = std::mem::take(&mut g.tape);
            let (tape, message) = shrink(case_seed, case, tape, msg, &mut prop);
            return Some(Counterexample {
                case,
                case_seed,
                tape,
                message,
            });
        }
    }
    None
}

/// Re-run a property against a recorded tape (from a [`check`] panic or
/// a [`Counterexample`]).  Returns the property's verdict so a repro
/// can be asserted in a normal `#[test]`.
pub fn replay<F>(case_seed: u64, case: usize, tape: &[u64], mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    prop(&mut Gen::new(case_seed, case, tape.to_vec()))
}

/// Greedy tape minimization: chunk deletion (halving chunk sizes),
/// chunk zeroing, then per-scalar binary search toward 0, iterated to a
/// fixpoint under the [`SHRINK_ATTEMPTS`] budget.  Each accepted
/// mutation adopts the *recorded* tape of the failing re-run (the
/// canonical form — forcing may have clamped or run short), and
/// acceptance demands a strictly smaller `(len, lexicographic)` order,
/// which is well-founded, so the loop terminates even without the
/// budget.
fn shrink<F>(
    case_seed: u64,
    case: usize,
    tape: Vec<u64>,
    message: String,
    prop: &mut F,
) -> (Vec<u64>, String)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut best = tape;
    let mut best_msg = message;
    let mut attempts = 0usize;
    // run a candidate tape; Some(recorded tape, msg) iff it still fails
    let mut run = |cand: &[u64]| -> Option<(Vec<u64>, String)> {
        if attempts >= SHRINK_ATTEMPTS {
            return None;
        }
        attempts += 1;
        let mut g = Gen::new(case_seed, case, cand.to_vec());
        match prop(&mut g) {
            Err(m) => Some((std::mem::take(&mut g.tape), m)),
            Ok(()) => None,
        }
    };
    let simpler =
        |t: &[u64], b: &[u64]| t.len() < b.len() || (t.len() == b.len() && t < b);

    for _round in 0..8 {
        let mut improved = false;

        // pass 1: delete chunks of draws, large chunks first
        let mut k = best.len().max(1);
        while k >= 1 {
            let mut i = 0;
            while i + k <= best.len() {
                let mut cand = best[..i].to_vec();
                cand.extend_from_slice(&best[i + k..]);
                match run(&cand) {
                    Some((t, m)) if simpler(&t, &best) => {
                        best = t;
                        best_msg = m;
                        improved = true;
                        // re-try the same window against the new best
                    }
                    _ => i += k,
                }
            }
            k /= 2;
        }

        // pass 2: zero chunks (ops become their minimal form without
        // changing the sequence length)
        let mut k = best.len().max(1);
        while k >= 1 {
            let mut i = 0;
            while i + k <= best.len() {
                if best[i..i + k].iter().all(|&v| v == 0) {
                    i += k;
                    continue;
                }
                let mut cand = best.clone();
                cand[i..i + k].iter_mut().for_each(|v| *v = 0);
                match run(&cand) {
                    Some((t, m)) if simpler(&t, &best) => {
                        best = t;
                        best_msg = m;
                        improved = true;
                    }
                    _ => i += k,
                }
            }
            k /= 2;
        }

        // pass 3: binary-search each scalar toward 0
        let mut j = 0;
        while j < best.len() {
            let (mut lo, mut hi) = (0u64, best[j]);
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                let mut cand = best.clone();
                cand[j] = mid;
                match run(&cand) {
                    Some((t, m)) => {
                        // the re-run may have recorded a clamped value;
                        // track the search window on what actually stuck
                        hi = t.get(j).copied().unwrap_or(mid).min(mid);
                        if simpler(&t, &best) {
                            best = t;
                            best_msg = m;
                            improved = true;
                        }
                        if j >= best.len() {
                            break;
                        }
                    }
                    None => lo = mid + 1,
                }
            }
            j += 1;
        }

        if !improved {
            break;
        }
    }
    (best, best_msg)
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "mismatch at {i}: {x} vs {y} (|Δ|={} > tol {tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(50, 1, |g| {
            let n = g.usize_in(1, 64);
            let v = g.vec_f32(n, 1.0);
            if v.len() == n {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, 2, |g| {
            let n = g.usize_in(0, 100);
            if n < 90 {
                Ok(())
            } else {
                Err(format!("n={n} too big"))
            }
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<usize> = Vec::new();
        check(10, 3, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check(10, 3, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn scalar_shrinks_to_threshold() {
        // fails iff n >= 90; the minimal counterexample is exactly 90
        let cx = find_counterexample(100, 2, |g| {
            let n = g.usize_in(0, 1000);
            if n < 90 {
                Ok(())
            } else {
                Err(format!("n={n}"))
            }
        })
        .expect("property must fail somewhere in 100 cases");
        assert_eq!(cx.tape, vec![90], "binary search finds the boundary");
        assert_eq!(cx.message, "n=90");
    }

    #[test]
    fn op_sequence_shrinks_to_single_bad_op() {
        // a random op program fails iff it ever executes op 3; the
        // shrunk tape should be one op long: [1, 3] = "1 op, op 3"
        let cx = find_counterexample(100, 7, |g| {
            let n_ops = g.usize_in(1, 20);
            for _ in 0..n_ops {
                let op = g.usize_in(0, 5);
                if op == 3 {
                    return Err("op 3 executed".into());
                }
            }
            Ok(())
        })
        .expect("op 3 must appear in 100 random programs");
        assert_eq!(cx.tape, vec![0, 3], "one op (usize_in lo=1 ⇒ reduced 0), op id 3");
    }

    #[test]
    fn shrunk_tape_replays_to_the_same_failure() {
        let prop = |g: &mut Gen| {
            let a = g.usize_in(0, 50);
            let b = g.usize_in(0, 50);
            if a + b >= 60 {
                Err(format!("{a}+{b}"))
            } else {
                Ok(())
            }
        };
        let cx = find_counterexample(200, 11, prop).expect("must fail");
        let replayed = replay(cx.case_seed, cx.case, &cx.tape, prop);
        assert_eq!(replayed, Err(cx.message.clone()), "tape is a faithful repro");
        // and the minimum really is minimal: a+b == 60 with a as small
        // as the greedy order allows
        assert_eq!(cx.tape.iter().sum::<u64>(), 60);
    }

    #[test]
    fn untracked_rng_draws_survive_forcing() {
        // direct g.rng access bypasses the tape; forcing tracked draws
        // must not shift the raw stream
        let mut raw_unforced = 0u64;
        let _ = find_counterexample(1, 5, |g| {
            let _ = g.usize_in(0, 9);
            raw_unforced = g.rng.next_u64();
            Ok(())
        });
        let mut raw_forced = 0u64;
        let _ = replay(5, 0, &[7], |g| {
            let _ = g.usize_in(0, 9);
            raw_forced = g.rng.next_u64();
            Ok(())
        });
        assert_eq!(raw_unforced, raw_forced);
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
