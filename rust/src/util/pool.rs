//! Scoped thread pool (tokio is unavailable offline; the coordinator and
//! benches use this instead).
//!
//! Primitives:
//! * [`ThreadPool`] — long-lived workers consuming boxed jobs from a
//!   shared queue; used by the serving engine for decode workers.
//! * [`scope_chunks`] — data-parallel helper: split a mutable slice into
//!   chunks processed on `std::thread::scope` threads; used by batch
//!   compression paths.
//! * [`scope_units`] — task-parallel helper: drain a queue of
//!   independent work units (each typically carrying its own `&mut`
//!   output strips) on scoped threads; used by the page-granular KV
//!   gather path.
//! * [`ParallelPolicy`] — the off/auto/n configuration knob that decides
//!   how many threads the data-parallel helpers may use.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// Fixed-size worker pool with a `join`/barrier primitive.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("isoquant-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.  Panics in jobs abort the worker loop but are
    /// confined to that job (the worker catches unwind and continues).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let mut guard = self.shared.done_mx.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.done_mx.lock().unwrap();
            sh.done_cv.notify_all();
        }
        if result.is_err() {
            // job panicked: the panic is reported, the pool survives
            crate::log_error!("pool: job panicked (pool continues)");
        }
    }
}

/// Process `data` in roughly equal chunks on up to `threads` scoped
/// threads: `f(chunk_index, chunk)`.
pub fn scope_chunks<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    let threads = threads.max(1).min(data.len().max(1));
    let chunk = data.len().div_ceil(threads);
    if threads == 1 || data.len() <= 1 {
        f(0, data);
        return;
    }
    std::thread::scope(|s| {
        for (i, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i, part));
        }
    });
}

/// Run every unit in `units` exactly once on up to `threads` scoped
/// threads, draining a shared queue (units may be unevenly sized, so a
/// queue beats static chunking).  `threads <= 1` runs inline.
///
/// Units typically carry disjoint `&mut` output regions — ownership
/// moves into `f`, so the borrow checker enforces disjointness at the
/// call site.
pub fn scope_units<T: Send, F>(units: Vec<T>, threads: usize, f: F)
where
    F: Fn(T) + Send + Sync,
{
    let threads = threads.max(1).min(units.len());
    if threads <= 1 {
        for u in units {
            f(u);
        }
        return;
    }
    let queue = std::sync::Mutex::new(units.into_iter());
    let f = &f;
    let queue = &queue;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || loop {
                let next = queue.lock().unwrap().next();
                match next {
                    Some(u) => f(u),
                    None => break,
                }
            });
        }
    });
}

/// How a data-parallel section may use threads: the serving config's
/// `off` / `auto` / `n` knob (see `config::EngineConfig`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ParallelPolicy {
    /// single-threaded (deterministic baseline, also the default for
    /// directly-constructed components)
    #[default]
    Off,
    /// one thread per available core, capped by the number of work units
    Auto,
    /// exactly `n` threads (still capped by the number of work units)
    Fixed(usize),
}

impl ParallelPolicy {
    /// Threads to use for `units` independent work items.
    pub fn threads(&self, units: usize) -> usize {
        let t = match self {
            ParallelPolicy::Off => 1,
            ParallelPolicy::Auto => default_threads(),
            ParallelPolicy::Fixed(n) => (*n).max(1),
        };
        t.min(units.max(1))
    }

    /// Parse the config-file form: `"off"`, `"auto"`, or a thread count
    /// (`0` means off).
    pub fn parse(s: &str) -> Option<ParallelPolicy> {
        match s {
            "off" => Some(ParallelPolicy::Off),
            "auto" => Some(ParallelPolicy::Auto),
            _ => match s.parse::<usize>() {
                Ok(0) => Some(ParallelPolicy::Off),
                Ok(n) => Some(ParallelPolicy::Fixed(n)),
                Err(_) => None,
            },
        }
    }
}

/// Available parallelism with a sane floor.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn join_waits_for_slow_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicBool::new(false));
        let d = done.clone();
        pool.submit(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            d.store(true, Ordering::SeqCst);
        });
        pool.join();
        assert!(done.load(Ordering::SeqCst));
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        pool.join();
        let ok = Arc::new(AtomicBool::new(false));
        let o = ok.clone();
        pool.submit(move || o.store(true, Ordering::SeqCst));
        pool.join();
        assert!(ok.load(Ordering::SeqCst));
    }

    #[test]
    fn scope_chunks_covers_everything() {
        let mut data: Vec<u32> = vec![0; 1037];
        scope_chunks(&mut data, 8, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn scope_units_runs_every_unit_once() {
        let mut data = vec![0u32; 137];
        // each unit owns a disjoint &mut chunk
        let units: Vec<&mut [u32]> = data.chunks_mut(10).collect();
        scope_units(units, 4, |chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn scope_units_inline_when_single_thread() {
        let mut hits = vec![false; 5];
        let units: Vec<&mut bool> = hits.iter_mut().collect();
        scope_units(units, 1, |h| *h = true);
        assert!(hits.iter().all(|&h| h));
    }

    #[test]
    fn scope_units_empty_ok() {
        scope_units(Vec::<u32>::new(), 8, |_| {});
    }

    #[test]
    fn parallel_policy_threads_and_parse() {
        assert_eq!(ParallelPolicy::Off.threads(64), 1);
        assert_eq!(ParallelPolicy::Fixed(3).threads(64), 3);
        assert_eq!(ParallelPolicy::Fixed(8).threads(2), 2);
        assert!(ParallelPolicy::Auto.threads(64) >= 1);
        assert_eq!(ParallelPolicy::Auto.threads(1), 1);
        assert_eq!(ParallelPolicy::parse("off"), Some(ParallelPolicy::Off));
        assert_eq!(ParallelPolicy::parse("auto"), Some(ParallelPolicy::Auto));
        assert_eq!(ParallelPolicy::parse("0"), Some(ParallelPolicy::Off));
        assert_eq!(ParallelPolicy::parse("6"), Some(ParallelPolicy::Fixed(6)));
        assert_eq!(ParallelPolicy::parse("warp"), None);
    }

    #[test]
    fn scope_chunks_single_element() {
        let mut data = vec![5u32];
        scope_chunks(&mut data, 8, |_, chunk| chunk[0] *= 2);
        assert_eq!(data, vec![10]);
    }
}
