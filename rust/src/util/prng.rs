//! Deterministic PRNG substrate (no external crates are available in this
//! environment, so we ship our own): SplitMix64 seeding, xoshiro256++
//! core, Gaussian sampling, and Haar-distributed rotation sampling.
//!
//! Haar sampling follows paper §5.5: Gaussian-normalize on S³ for the
//! quaternion factors, uniform angles for the planar case, and QR of a
//! Gaussian matrix (sign-fixed) for dense orthogonal baselines.

/// xoshiro256++ by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from the polar method
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fold `v` into accumulator `h` (golden-ratio multiply + xor-shift).
/// The single non-cryptographic field mixer used by every fingerprint
/// in the tree (`Stage1Config::fingerprint`, the KV cache geometry
/// salt) — widen or change hashing HERE, not at the call sites, so all
/// fingerprints move together.  (Token-run chain hashing in
/// `kvcache::page::chain_key` intentionally uses byte-wise FNV-1a
/// instead: it streams variable-length token runs.)
#[inline]
pub fn mix64(h: u64, v: u64) -> u64 {
    let x = (h ^ v).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^ (x >> 29)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits → double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53)
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Marsaglia polar method (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    pub fn gaussian_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() as f32).collect()
    }

    /// Haar-uniform unit quaternion (w, x, y, z) on S³.
    pub fn haar_quaternion(&mut self) -> [f32; 4] {
        loop {
            let q = [
                self.gaussian(),
                self.gaussian(),
                self.gaussian(),
                self.gaussian(),
            ];
            let n = (q[0] * q[0] + q[1] * q[1] + q[2] * q[2] + q[3] * q[3]).sqrt();
            if n > 1e-12 {
                return [
                    (q[0] / n) as f32,
                    (q[1] / n) as f32,
                    (q[2] / n) as f32,
                    (q[3] / n) as f32,
                ];
            }
        }
    }

    /// Haar angle on SO(2): Unif[0, 2π).
    pub fn haar_angle(&mut self) -> f32 {
        self.uniform_range(0.0, std::f64::consts::TAU) as f32
    }

    /// Haar-distributed dense orthogonal d×d matrix (row-major), via
    /// modified Gram–Schmidt on a Gaussian matrix with sign fixing —
    /// equivalent to QR with R-diagonal sign convention.
    pub fn haar_orthogonal(&mut self, d: usize) -> Vec<f32> {
        let mut a: Vec<Vec<f64>> = (0..d).map(|_| self.gaussian_vec(d)).collect();
        for i in 0..d {
            for j in 0..i {
                let dot: f64 = (0..d).map(|k| a[i][k] * a[j][k]).sum();
                for k in 0..d {
                    a[i][k] -= dot * a[j][k];
                }
            }
            let nrm: f64 = (0..d).map(|k| a[i][k] * a[i][k]).sum::<f64>().sqrt();
            // re-draw pathological rows (measure-zero; defensive)
            assert!(nrm > 1e-9, "degenerate Gaussian row in haar_orthogonal");
            for k in 0..d {
                a[i][k] /= nrm;
            }
        }
        let mut out = Vec::with_capacity(d * d);
        for row in &a {
            out.extend(row.iter().map(|&x| x as f32));
        }
        out
    }

    /// Fill a slice with uniform bytes (used by failure-injection tests).
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            let n = chunk.len();
            chunk.copy_from_slice(&v[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs = r.gaussian_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn haar_quaternion_unit_norm() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let q = r.haar_quaternion();
            let n: f32 = q.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn haar_quaternion_first_coord_marginal() {
        // paper eq. 38: f_4(z) = (2/π)√(1-z²) → P(|z| > 0.99) tiny
        let mut r = Rng::new(11);
        let n = 100_000;
        let extreme = (0..n)
            .filter(|_| r.haar_quaternion()[0].abs() > 0.99)
            .count();
        assert!((extreme as f64) / (n as f64) < 0.01);
    }

    #[test]
    fn haar_orthogonal_is_orthogonal() {
        let mut r = Rng::new(5);
        for d in [4, 16, 32] {
            let m = r.haar_orthogonal(d);
            for i in 0..d {
                for j in 0..d {
                    let dot: f32 = (0..d).map(|k| m[i * d + k] * m[j * d + k]).sum();
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (dot - want).abs() < 1e-4,
                        "d={d} i={i} j={j} dot={dot}"
                    );
                }
            }
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(1);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
