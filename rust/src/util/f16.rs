//! Software IEEE 754 binary16 (half precision).
//!
//! The paper's Table 2 sweeps dtype ∈ {fp16, fp32}.  This environment has
//! no GPU half-precision units and no `half` crate, so fp16 execution is
//! modeled the way quantization studies care about: values are *stored*
//! as 16-bit and every load/store rounds through binary16, reproducing
//! fp16's precision effects exactly; arithmetic runs in f32 (which is
//! also what tensor-core accumulators do).

/// A 16-bit IEEE 754 half-precision float (storage type).
#[derive(Clone, Copy, PartialEq, Debug, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    pub const MAX: F16 = F16(0x7BFF); // 65504

    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

/// Round-to-nearest-even conversion f32 → binary16 bit pattern.
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf / nan
        return if mant == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00 | ((mant >> 13) as u16 & 0x03FF)
        };
    }

    // unbias to f16 exponent
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → inf
    }
    if unbiased >= -14 {
        // normal f16
        let e16 = (unbiased + 15) as u32;
        let m16 = mant >> 13;
        let rest = mant & 0x1FFF;
        let mut h = (sign as u32) | (e16 << 10) | m16;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (m16 & 1) == 1) {
            h += 1; // may carry into exponent — that is correct behaviour
        }
        return h as u16;
    }
    if unbiased >= -25 {
        // subnormal f16
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - unbiased) as u32 + 13;
        let m16 = m >> shift;
        let rest = m & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = (sign as u32) | m16;
        if rest > half || (rest == half && (m16 & 1) == 1) {
            h += 1;
        }
        return h as u16;
    }
    sign // underflow → signed zero
}

/// Conversion binary16 bit pattern → f32 (exact).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // subnormal: normalize
            let mut e = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x03FF) << 13)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through binary16 precision (the fp16 "execution dtype"
/// model used by the Table-2 sweep).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Round a slice in place through binary16.
pub fn round_f16_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_f16(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(round_f16(x), x, "{x} should be exact in f16");
        }
    }

    #[test]
    fn one_roundtrips() {
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(F16::from_f32(1.0), F16::ONE);
    }

    #[test]
    fn max_value() {
        assert_eq!(F16::MAX.to_f32(), 65504.0);
        assert_eq!(round_f16(65504.0), 65504.0);
    }

    #[test]
    fn overflow_to_inf() {
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(F16::from_f32(-1e6).is_infinite());
        assert_eq!(F16::from_f32(1e6), F16::INFINITY);
    }

    #[test]
    fn nan_propagates() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn subnormals() {
        // smallest positive subnormal f16 = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(round_f16(tiny), tiny);
        // below half of it rounds to zero
        assert_eq!(round_f16(tiny / 4.0), 0.0);
        // smallest normal
        let min_norm = 2.0f32.powi(-14);
        assert_eq!(round_f16(min_norm), min_norm);
    }

    #[test]
    fn signed_zero() {
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
        assert_eq!(F16::from_f32(0.0).0, 0x0000);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 → ties to even (1.0)
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(round_f16(x), 1.0);
        // 1 + 3*2^-11 ties to 1 + 2*2^-10? No: between 1+2^-10 and 1+2^-9·...
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(round_f16(y), 1.0 + 2.0 * 2.0f32.powi(-10));
    }

    #[test]
    fn relative_error_bound() {
        // f16 has 11 significand bits → rel err ≤ 2^-11 for normals
        let mut worst: f32 = 0.0;
        let mut x = 0.001f32;
        while x < 60000.0 {
            let r = (round_f16(x) - x).abs() / x;
            worst = worst.max(r);
            x *= 1.37;
        }
        assert!(worst <= 2.0f32.powi(-11), "worst rel err {worst}");
    }

    #[test]
    fn exhaustive_roundtrip_f16_to_f32_to_f16() {
        // every finite f16 must roundtrip bit-exactly through f32
        for bits in 0..=0xFFFFu16 {
            let h = F16(bits);
            if h.is_nan() {
                continue;
            }
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, bits, "bits {bits:#06x}");
        }
    }
}
