//! Reader for the `weights.bin` tensorfile written by
//! `python/compile/aot.py::write_tensorfile`, plus a writer so Rust tools
//! can emit the same format (snapshots, learned parameter banks).
//!
//! Layout (little endian):
//!   magic "ISOQTNSR" | u32 version | u32 count
//!   per tensor: u32 name_len | name utf8 | u32 ndim | u64 dims[] |
//!               u32 dtype (0=f32, 1=f16, 2=i32) | u64 byte_len | raw

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"ISOQTNSR";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    I32,
}

impl Dtype {
    fn from_code(c: u32) -> Result<Dtype> {
        Ok(match c {
            0 => Dtype::F32,
            1 => Dtype::F16,
            2 => Dtype::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    fn code(self) -> u32 {
        match self {
            Dtype::F32 => 0,
            Dtype::F16 => 1,
            Dtype::I32 => 2,
        }
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F16 => 2,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        match self.dtype {
            Dtype::F32 => Ok(self
                .data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            Dtype::F16 => Ok(self
                .data
                .chunks_exact(2)
                .map(|c| crate::util::f16::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                .collect()),
            Dtype::I32 => bail!("tensor {} is i32, not float", self.name),
        }
    }

    pub fn from_f32(name: &str, shape: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor {
            name: name.to_string(),
            shape,
            dtype: Dtype::F32,
            data,
        }
    }
}

pub fn read_tensorfile(path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open tensorfile {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_tensorfile(&buf)
}

pub fn parse_tensorfile(buf: &[u8]) -> Result<Vec<Tensor>> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > buf.len() {
            bail!("truncated tensorfile at byte {pos}: need {n} more bytes");
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> Result<u32> {
        let b = take(pos, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    };
    let u64_at = |pos: &mut usize| -> Result<u64> {
        let b = take(pos, 8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    };

    if take(&mut pos, 8)? != MAGIC {
        bail!("bad tensorfile magic");
    }
    let version = u32_at(&mut pos)?;
    if version != 1 {
        bail!("unsupported tensorfile version {version}");
    }
    let count = u32_at(&mut pos)? as usize;
    if count > 1_000_000 {
        bail!("implausible tensor count {count}");
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = u32_at(&mut pos)? as usize;
        if name_len > 4096 {
            bail!("implausible tensor name length {name_len}");
        }
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .context("tensor name not utf-8")?;
        let ndim = u32_at(&mut pos)? as usize;
        if ndim > 16 {
            bail!("implausible ndim {ndim} for {name}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64_at(&mut pos)? as usize);
        }
        let dtype = Dtype::from_code(u32_at(&mut pos)?)?;
        let byte_len = u64_at(&mut pos)? as usize;
        // corrupted dims must not overflow the size computation
        let expect = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .and_then(|n| n.checked_mul(dtype.size()));
        let Some(expect) = expect else {
            bail!("tensor {name}: shape {shape:?} overflows");
        };
        if byte_len != expect {
            bail!("tensor {name}: byte_len {byte_len} != shape-implied {expect}");
        }
        let data = take(&mut pos, byte_len)?.to_vec();
        out.push(Tensor {
            name,
            shape,
            dtype,
            data,
        });
    }
    if pos != buf.len() {
        bail!("{} trailing bytes in tensorfile", buf.len() - pos);
    }
    Ok(out)
}

pub fn write_tensorfile(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create tensorfile {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        f.write_all(&(t.name.len() as u32).to_le_bytes())?;
        f.write_all(t.name.as_bytes())?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        f.write_all(&t.dtype.code().to_le_bytes())?;
        f.write_all(&(t.data.len() as u64).to_le_bytes())?;
        f.write_all(&t.data)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("isoquant_tensorfile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let tensors = vec![
            Tensor::from_f32("a", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            Tensor::from_f32("b.scale", vec![1], &[0.5]),
        ];
        write_tensorfile(&path, &tensors).unwrap();
        let back = read_tensorfile(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "a");
        assert_eq!(back[0].shape, vec![2, 3]);
        assert_eq!(back[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(back[1].name, "b.scale");
    }

    #[test]
    fn rejects_truncation() {
        let t = vec![Tensor::from_f32("x", vec![4], &[1.0; 4])];
        let dir = std::env::temp_dir().join("isoquant_tensorfile_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        write_tensorfile(&path, &t).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        assert!(parse_tensorfile(&bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_tensorfile(b"NOTMAGIC\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_length_mismatch() {
        let t = vec![Tensor::from_f32("x", vec![4], &[1.0; 4])];
        let dir = std::env::temp_dir().join("isoquant_tensorfile_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        write_tensorfile(&path, &t).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // corrupt the ndim field's first dim to 5 (byte_len now mismatched)
        // dims start after magic(8)+ver(4)+count(4)+name_len(4)+name(1)+ndim(4)
        let dim_off = 8 + 4 + 4 + 4 + 1 + 4;
        bytes[dim_off] = 5;
        assert!(parse_tensorfile(&bytes).is_err());
    }
}
