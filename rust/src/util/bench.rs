//! Benchmark timing harness (criterion is not vendorable offline).
//!
//! Methodology mirrors criterion's core loop: warmup, then repeated
//! timed batches; we report median / p10 / p90 over batch means, which is
//! robust to scheduler noise on a shared CPU.  All `cargo bench` targets
//! in `rust/benches/` use this.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// median per-iteration time
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters_per_batch: u64,
    pub batches: usize,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_batches: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_batches: 50,
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            max_batches: 20,
        }
    }

    /// Time `f` (call overhead amortized over auto-sized batches).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup + batch sizing: grow batch until one batch ≥ ~2ms
        let mut iters_per_batch = 1u64;
        let warm_deadline = Instant::now() + self.warmup;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            let dt = t0.elapsed();
            if dt < Duration::from_millis(2) {
                iters_per_batch = (iters_per_batch * 2).min(1 << 24);
            }
            if Instant::now() >= warm_deadline && dt >= Duration::from_micros(500) {
                break;
            }
        }

        // measurement batches
        let mut samples: Vec<f64> = Vec::new();
        let deadline = Instant::now() + self.measure;
        while Instant::now() < deadline && samples.len() < self.max_batches {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters_per_batch as f64);
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters_per_batch as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> Duration {
            let idx = ((samples.len() - 1) as f64 * p).round() as usize;
            Duration::from_secs_f64(samples[idx])
        };
        BenchResult {
            name: name.to_string(),
            median: q(0.5),
            p10: q(0.1),
            p90: q(0.9),
            iters_per_batch,
            batches: samples.len(),
        }
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width markdown-ish table printer used by the bench binaries.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:>w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let r = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.median > Duration::ZERO);
        assert!(r.p10 <= r.p90);
    }

    #[test]
    fn ordering_detects_slower_code() {
        let b = Bencher::quick();
        let fast = b.run("fast", || {
            black_box((0..10u64).sum::<u64>());
        });
        let slow = b.run("slow", || {
            black_box((0..100_000u64).map(|x| x.wrapping_mul(x)).sum::<u64>());
        });
        assert!(slow.median > fast.median);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }
}
