//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid { key: String, msg: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} requires a value"),
            CliError::Invalid { key, msg } => write!(f, "invalid value for --{key}: {msg}"),
        }
    }
}

impl std::error::Error for CliError {}

pub struct Parser {
    specs: Vec<ArgSpec>,
    pub command: &'static str,
    pub about: &'static str,
}

impl Parser {
    pub fn new(command: &'static str, about: &'static str) -> Parser {
        Parser {
            specs: Vec::new(),
            command,
            about,
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.command, self.about);
        for spec in &self.specs {
            let kind = if spec.is_flag { "" } else { " <value>" };
            let def = spec
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!(
                "  --{}{kind}\n      {}{def}\n",
                spec.name, spec.help
            ));
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.is_flag {
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    out.values.insert(key, val);
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        // apply defaults
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.values
                    .entry(spec.name.to_string())
                    .or_insert_with(|| d.to_string());
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        self.parse_as(key)
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        self.parse_as(key)
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        self.parse_as(key)
    }

    fn parse_as<T: std::str::FromStr>(&self, key: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(key).ok_or_else(|| CliError::MissingValue(key.into()))?;
        raw.parse().map_err(|e: T::Err| CliError::Invalid {
            key: key.into(),
            msg: e.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn parser() -> Parser {
        Parser::new("test", "about")
            .opt("dim", "128", "head dim")
            .opt("bits", "4", "bit width")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let a = parser().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("dim").unwrap(), 128);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn key_value_and_equals_forms() {
        let a = parser()
            .parse(&argv(&["--dim", "256", "--bits=2", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("dim").unwrap(), 256);
        assert_eq!(a.get_usize("bits").unwrap(), 2);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn positional_collected() {
        let a = parser().parse(&argv(&["serve", "--dim", "64"])).unwrap();
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn unknown_rejected() {
        assert!(matches!(
            parser().parse(&argv(&["--nope"])),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            parser().parse(&argv(&["--dim"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_value_rejected() {
        let a = parser().parse(&argv(&["--dim", "abc"])).unwrap();
        assert!(matches!(a.get_usize("dim"), Err(CliError::Invalid { .. })));
    }

    #[test]
    fn usage_mentions_options() {
        let u = parser().usage();
        assert!(u.contains("--dim"));
        assert!(u.contains("default: 128"));
    }
}
