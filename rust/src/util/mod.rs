//! Dependency-light substrates: everything an offline build needs that a
//! normal project would pull from crates.io (see DESIGN.md §6).

pub mod bench;
pub mod cli;
pub mod f16;
pub mod json;
pub mod log;
pub mod pool;
pub mod prng;
pub mod proplite;
pub mod tensorfile;
