//! Minimal leveled logging for the serving stack — the no-new-deps
//! replacement for the scattered `eprintln!` call sites.
//!
//! Four levels (error > warn > info > debug), a process-global level
//! set from `[server] log_level`, and an optional JSON-lines mode
//! (`[server] log_json = on`) that emits one machine-parseable object
//! per event instead of the human text line.  The default (`info`,
//! text) reproduces the exact lines the server printed before this
//! module existed; tests and benches silence the periodic stats line
//! with `set_level(Level::Error)`.
//!
//! Use through the crate-level macros:
//!
//! ```ignore
//! log_info!("serving on {addr}");
//! log_warn!("store degraded after {n} failures");
//! ```
//!
//! Every emitted line goes to stderr, same as before — stdout stays
//! reserved for command output.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static JSON: AtomicBool = AtomicBool::new(false);

/// Set the process-wide log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current process-wide log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Switch between human text lines (off, the default) and JSON-lines.
pub fn set_json(on: bool) {
    JSON.store(on, Ordering::Relaxed);
}

/// Would a message at `l` be emitted right now?  The macros check this
/// before formatting, so a silenced `log_debug!` costs one atomic load.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one already-formatted message at `l`.  Prefer the macros.
pub fn emit(l: Level, msg: &str) {
    if !enabled(l) {
        return;
    }
    if JSON.load(Ordering::Relaxed) {
        let ts_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        // hand-rolled object, same idiom as util::json serialization
        let mut esc = String::with_capacity(msg.len());
        for c in msg.chars() {
            match c {
                '"' => esc.push_str("\\\""),
                '\\' => esc.push_str("\\\\"),
                '\n' => esc.push_str("\\n"),
                '\t' => esc.push_str("\\t"),
                c if (c as u32) < 0x20 => esc.push_str(&format!("\\u{:04x}", c as u32)),
                c => esc.push(c),
            }
        }
        eprintln!(
            "{{\"ts_ms\": {ts_ms}, \"level\": \"{}\", \"msg\": \"{esc}\"}}",
            l.name()
        );
    } else {
        // the historical prefix, so existing log-scraping keeps working
        match l {
            Level::Info => eprintln!("isoquant: {msg}"),
            _ => eprintln!("isoquant[{}]: {msg}", l.name()),
        }
    }
}

/// Apply the `[server] log_level` / `log_json` knobs.
pub fn configure(level_name: &str, json: bool) -> Result<(), String> {
    let l = Level::parse(level_name)
        .ok_or_else(|| format!("log_level must be error|warn|info|debug, got {level_name:?}"))?;
    set_level(l);
    set_json(json);
    Ok(())
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Error) {
            $crate::util::log::emit($crate::util::log::Level::Error, &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            $crate::util::log::emit($crate::util::log::Level::Warn, &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::emit($crate::util::log::Level::Info, &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::emit($crate::util::log::Level::Debug, &format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_parse() {
        assert!(Level::Error < Level::Debug);
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARN"), None, "levels are lowercase");
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn configure_validates() {
        assert!(configure("shouty", false).is_err());
        // leave the process default in place for other tests
        assert!(configure("info", false).is_ok());
    }

    #[test]
    fn enabled_respects_level() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(prev);
    }
}
