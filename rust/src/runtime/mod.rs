//! XLA/PJRT runtime: artifact manifest, typed execution helpers, and the
//! serving model (decode/prefill executables + resident weights).

pub mod artifacts;
pub mod client;
pub mod exec;
pub mod model;

pub use artifacts::{default_dir, ArtifactSpec, IoDtype, IoSpec, Manifest, ModelMeta};
pub use client::Runtime;
pub use exec::HostTensor;
pub use model::{DecodeOut, ServingModel};
