//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  Parses `artifacts/manifest.json` and exposes typed
//! specs for every AOT-compiled graph plus the model geometry.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoDtype {
    F32,
    F16,
    I32,
}

impl IoDtype {
    fn from_str(s: &str) -> Result<IoDtype> {
        Ok(match s {
            "f32" => IoDtype::F32,
            "f16" => IoDtype::F16,
            "i32" => IoDtype::I32,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: IoDtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub meta: BTreeMap<String, String>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// Model geometry (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prefill_chunk: usize,
    pub n_params: usize,
    pub serve_batch: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub weights_file: String,
    pub weight_specs: Vec<(String, Vec<usize>)>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Manifest::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("parse manifest.json")?;
        let m = root.get("model").context("manifest missing 'model'")?;
        let mu = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("model.{k} missing"))
        };
        let model = ModelMeta {
            vocab: mu("vocab")?,
            d_model: mu("d_model")?,
            n_heads: mu("n_heads")?,
            d_head: mu("d_head")?,
            n_layers: mu("n_layers")?,
            d_ff: mu("d_ff")?,
            max_seq: mu("max_seq")?,
            prefill_chunk: mu("prefill_chunk")?,
            n_params: mu("n_params")?,
            serve_batch: mu("serve_batch")?,
        };
        let weights_file = root
            .get("weights")
            .and_then(|v| v.as_str())
            .unwrap_or("weights.bin")
            .to_string();
        let mut weight_specs = Vec::new();
        for w in root
            .get("weight_specs")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
        {
            let name = w
                .get("name")
                .and_then(|v| v.as_str())
                .context("weight_specs entry missing name")?
                .to_string();
            let shape = w
                .get("shape")
                .and_then(|v| v.as_arr())
                .context("weight_specs entry missing shape")?
                .iter()
                .map(|v| v.as_usize().context("bad dim"))
                .collect::<Result<Vec<_>>>()?;
            weight_specs.push((name, shape));
        }

        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .context("manifest missing 'artifacts'")?
        {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .context("artifact missing name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(|v| v.as_str())
                .context("artifact missing file")?
                .to_string();
            let mut inputs = Vec::new();
            for i in a.get("inputs").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                inputs.push(IoSpec {
                    name: i
                        .get("name")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .to_string(),
                    shape: i
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .context("input missing shape")?
                        .iter()
                        .map(|v| v.as_usize().context("bad dim"))
                        .collect::<Result<Vec<_>>>()?,
                    dtype: IoDtype::from_str(
                        i.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32"),
                    )?,
                });
            }
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(mm)) = a.get("meta") {
                for (k, v) in mm {
                    let vs = match v {
                        Json::Str(s) => s.clone(),
                        Json::Num(n) => {
                            if n.fract() == 0.0 {
                                format!("{}", *n as i64)
                            } else {
                                format!("{n}")
                            }
                        }
                        Json::Bool(b) => format!("{b}"),
                        _ => continue,
                    };
                    meta.insert(k.clone(), vs);
                }
            }
            artifacts.push(ArtifactSpec {
                name,
                file,
                inputs,
                meta,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            weights_file,
            weight_specs,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// All stage-1 parity artifacts.
    pub fn stage1_artifacts(&self) -> Vec<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.meta.get("kind").map(|k| k == "stage1").unwrap_or(false))
            .collect()
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }
}

/// Default artifacts directory: `$ISOQUANT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("ISOQUANT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "model": {"vocab": 512, "d_model": 256, "n_heads": 4, "d_head": 64,
                "n_layers": 2, "d_ff": 512, "max_seq": 256,
                "prefill_chunk": 32, "n_params": 1312000, "serve_batch": 4},
      "weights": "weights.bin",
      "weight_specs": [{"name": "embed", "shape": [512, 256]}],
      "artifacts": [
        {"name": "stage1_full_d128_b2", "file": "s.hlo.txt",
         "inputs": [{"name": "x", "shape": [64, 128], "dtype": "f32"},
                    {"name": "q_l", "shape": [32, 4], "dtype": "f32"},
                    {"name": "q_r", "shape": [32, 4], "dtype": "f32"}],
         "meta": {"kind": "stage1", "variant": "full", "d": 128, "bits": 2,
                  "batch": 64, "quantizer": "lloyd"}},
        {"name": "decode_step", "file": "d.hlo.txt",
         "inputs": [{"name": "tok", "shape": [4], "dtype": "i32"}],
         "meta": {"kind": "decode", "batch": 4}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.model.d_head, 64);
        assert_eq!(m.model.n_params, 1_312_000);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.artifact("stage1_full_d128_b2").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![64, 128]);
        assert_eq!(a.inputs[0].dtype, IoDtype::F32);
        assert_eq!(a.meta_usize("bits"), Some(2));
        assert_eq!(a.meta.get("variant").map(|s| s.as_str()), Some("full"));
        assert_eq!(m.stage1_artifacts().len(), 1);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // integration-level check against the actual AOT output
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifact("decode_step").is_ok());
            assert!(m.artifact("prefill_chunk").is_ok());
            assert!(!m.stage1_artifacts().is_empty());
            assert_eq!(m.weight_specs.len(), 3 + 8 * m.model.n_layers);
        }
    }
}
