//! Typed host↔device tensor helpers over the `xla` crate's literals.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

use super::artifacts::{IoDtype, IoSpec};

/// A host-side tensor heading into (or out of) an executable.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::I32(v, _) => v.len(),
        }
    }

    /// Validate against an artifact input spec (shape + dtype).
    pub fn check(&self, spec: &IoSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "input {:?}: shape {:?} != expected {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        let ok = matches!(
            (self, spec.dtype),
            (HostTensor::F32(_, _), IoDtype::F32) | (HostTensor::I32(_, _), IoDtype::I32)
        );
        if !ok {
            bail!("input {:?}: dtype mismatch", spec.name);
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            HostTensor::F32(v, shape) => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
                    .context("create f32 literal")
            }
            HostTensor::I32(v, shape) => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
                    .context("create i32 literal")
            }
        }
    }
}

/// Read an f32 literal back to a host vector.
pub fn literal_to_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to f32 vec")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, -2.5, 3.25, 0.0], vec![2, 2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), vec![1.0, -2.5, 3.25, 0.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::I32(vec![7, -3], vec![2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -3]);
    }

    #[test]
    fn check_validates_shape_and_dtype() {
        let spec = IoSpec {
            name: "x".into(),
            shape: vec![2, 2],
            dtype: IoDtype::F32,
        };
        assert!(HostTensor::F32(vec![0.0; 4], vec![2, 2]).check(&spec).is_ok());
        assert!(HostTensor::F32(vec![0.0; 4], vec![4]).check(&spec).is_err());
        assert!(HostTensor::I32(vec![0; 4], vec![2, 2]).check(&spec).is_err());
    }
}
