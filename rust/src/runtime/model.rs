//! The serving model: decode / prefill executables with device-resident
//! weights.  This is the only thing that runs model math on the request
//! path — all of it inside XLA executables compiled from the AOT
//! artifacts (Python never runs here).

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use super::artifacts::ModelMeta;
use super::client::{Runtime, Staged};
use super::exec::HostTensor;

pub struct ServingModel {
    pub rt: Runtime,
    pub meta: ModelMeta,
    /// weights staged once as device buffers (order = manifest order);
    /// `Staged` keeps the backing literals alive (async upload)
    weights: Vec<Staged>,
}

/// Output of one decode step.
pub struct DecodeOut {
    /// (B, vocab)
    pub logits: Vec<f32>,
    /// (L, B, H, dh) — this token's K per layer
    pub k_new: Vec<f32>,
    /// (L, B, H, dh)
    pub v_new: Vec<f32>,
}

impl ServingModel {
    pub fn load(artifacts_dir: &Path) -> Result<ServingModel> {
        let mut rt = Runtime::load(artifacts_dir)?;
        let meta = rt.manifest.model.clone();
        // compile eagerly so serving never pays JIT latency mid-request
        rt.executable("decode_step")?;
        rt.executable("prefill_chunk")?;
        let host_weights = rt.load_weights()?;
        let weights = host_weights
            .iter()
            .map(|t| rt.stage(t))
            .collect::<Result<Vec<_>>>()
            .context("stage weights")?;
        Ok(ServingModel { rt, meta, weights })
    }

    pub fn batch(&self) -> usize {
        self.meta.serve_batch
    }

    pub fn cache_numel(&self) -> usize {
        let m = &self.meta;
        m.n_layers * m.serve_batch * m.n_heads * m.max_seq * m.d_head
    }

    /// One batched decode step with per-lane positions (continuous
    /// batching).
    ///
    /// `tok`: (B,) token ids; `pos`: (B,) per-lane positions;
    /// `k_cache`/`v_cache`: (L, B, H, T, dh) reconstructed caches.
    pub fn decode_step(
        &mut self,
        tok: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
    ) -> Result<DecodeOut> {
        let m = self.meta.clone();
        if tok.len() != m.serve_batch || pos.len() != m.serve_batch {
            bail!("decode_step: tok/pos len != batch {}", m.serve_batch);
        }
        if k_cache.len() != self.cache_numel() || v_cache.len() != self.cache_numel() {
            bail!("decode_step: cache shape mismatch");
        }
        let cache_shape = vec![m.n_layers, m.serve_batch, m.n_heads, m.max_seq, m.d_head];
        let ins = [
            HostTensor::I32(tok.to_vec(), vec![m.serve_batch]),
            HostTensor::I32(pos.to_vec(), vec![m.serve_batch]),
            HostTensor::F32(k_cache.to_vec(), cache_shape.clone()),
            HostTensor::F32(v_cache.to_vec(), cache_shape),
        ];
        let outs = self.run_with_weights("decode_step", &ins)?;
        let [logits, k_new, v_new]: [Vec<f32>; 3] = outs
            .try_into()
            .map_err(|_| anyhow::anyhow!("decode_step: expected 3 outputs"))?;
        Ok(DecodeOut {
            logits,
            k_new,
            v_new,
        })
    }

    /// One chunked prefill step over P = meta.prefill_chunk tokens with
    /// per-lane chunk start positions.
    /// Returns (logits (B, P, vocab), k_chunk (L,B,H,P,dh), v_chunk).
    pub fn prefill_chunk(
        &mut self,
        tok: &[i32],
        pos0: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
    ) -> Result<DecodeOut> {
        let m = self.meta.clone();
        let p = m.prefill_chunk;
        if tok.len() != m.serve_batch * p || pos0.len() != m.serve_batch {
            bail!("prefill_chunk: tok/pos0 shape mismatch");
        }
        let cache_shape = vec![m.n_layers, m.serve_batch, m.n_heads, m.max_seq, m.d_head];
        let ins = [
            HostTensor::I32(tok.to_vec(), vec![m.serve_batch, p]),
            HostTensor::I32(pos0.to_vec(), vec![m.serve_batch]),
            HostTensor::F32(k_cache.to_vec(), cache_shape.clone()),
            HostTensor::F32(v_cache.to_vec(), cache_shape),
        ];
        let outs = self.run_with_weights("prefill_chunk", &ins)?;
        let [logits, k_new, v_new]: [Vec<f32>; 3] = outs
            .try_into()
            .map_err(|_| anyhow::anyhow!("prefill_chunk: expected 3 outputs"))?;
        Ok(DecodeOut {
            logits,
            k_new,
            v_new,
        })
    }

    fn run_with_weights(&mut self, name: &str, ins: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        // stage per-call inputs (literals kept alive by `Staged`), then
        // execute with the resident weights
        let staged: Vec<Staged> = ins
            .iter()
            .map(|t| self.rt.stage(t))
            .collect::<Result<_>>()?;
        let mut args: Vec<&PjRtBuffer> = staged.iter().map(|s| &s.buffer).collect();
        args.extend(self.weights.iter().map(|s| &s.buffer));
        self.rt.run_buffers_f32(name, &args)
    }
}
