//! The PJRT runtime: loads HLO-text artifacts, compiles them once on the
//! CPU client, and executes them from the serving hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.  The
//! AOT graphs are lowered with `return_tuple=True`, so every execution
//! returns one tuple literal that we unpack.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::{ArtifactSpec, Manifest};
use super::exec::{literal_to_f32, HostTensor};
use crate::util::tensorfile;

pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    exes: HashMap<String, PjRtLoadedExecutable>,
}

/// A device buffer plus the host literal backing its (possibly async)
/// upload — see [`Runtime::stage`].
pub struct Staged {
    pub buffer: PjRtBuffer,
    _literal: Literal,
}

impl Runtime {
    /// Boot a CPU PJRT client and load the manifest (compilation is lazy).
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let spec = self.manifest.artifact(name)?.clone();
            let path = self.manifest.hlo_path(&spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    /// Execute an artifact with host tensors, validating against the
    /// manifest's input specs; returns the flattened output tuple as f32
    /// vectors.
    pub fn run_f32(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.check_inputs(&spec, inputs)?;
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<Literal>(&lits).context("execute")?;
        untuple_f32(result)
    }

    /// Execute with pre-staged device buffers (weights stay resident).
    pub fn run_buffers_f32(
        &mut self,
        name: &str,
        inputs: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let result = exe.execute_b::<&PjRtBuffer>(inputs).context("execute_b")?;
        untuple_f32(result)
    }

    /// Stage a host tensor onto the device (used for resident weights).
    ///
    /// IMPORTANT: `buffer_from_host_literal` on the TFRT CPU client is
    /// asynchronous — the copy may happen after this call returns, so the
    /// source literal must outlive the buffer's first use.  [`Staged`]
    /// keeps the literal alive alongside the buffer (dropping it early is
    /// a use-after-free that crashes inside XLA).
    pub fn stage(&self, t: &HostTensor) -> Result<Staged> {
        let lit = t.to_literal()?;
        let buffer = self
            .client
            .buffer_from_host_literal(None, &lit)
            .context("stage buffer")?;
        Ok(Staged {
            buffer,
            _literal: lit,
        })
    }

    fn check_inputs(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: {} inputs given, {} expected",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            t.check(s)
                .with_context(|| format!("artifact {}", spec.name))?;
        }
        Ok(())
    }

    /// Load the model weights from the manifest's tensorfile, in
    /// weight-spec order.
    pub fn load_weights(&self) -> Result<Vec<HostTensor>> {
        let tensors = tensorfile::read_tensorfile(&self.manifest.weights_path())?;
        let by_name: HashMap<&str, &tensorfile::Tensor> =
            tensors.iter().map(|t| (t.name.as_str(), t)).collect();
        let mut out = Vec::new();
        for (name, shape) in &self.manifest.weight_specs {
            let t = by_name
                .get(name.as_str())
                .with_context(|| format!("weight {name} missing from weights.bin"))?;
            if &t.shape != shape {
                bail!("weight {name}: shape {:?} != manifest {:?}", t.shape, shape);
            }
            out.push(HostTensor::F32(t.as_f32()?, t.shape.clone()));
        }
        Ok(out)
    }
}

/// Unpack the `[[tuple_buffer]]` returned by PJRT execute into f32 vecs.
fn untuple_f32(result: Vec<Vec<PjRtBuffer>>) -> Result<Vec<Vec<f32>>> {
    let buf = result
        .into_iter()
        .next()
        .and_then(|r| r.into_iter().next())
        .context("empty execution result")?;
    let lit = buf.to_literal_sync().context("fetch result literal")?;
    let parts = lit.to_tuple().context("untuple result")?;
    parts.iter().map(literal_to_f32).collect()
}
