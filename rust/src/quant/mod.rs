//! Stage-1 online vector quantization — the paper's core contribution —
//! plus the stage-2 residual extension and the learned-rotation trainer.
//!
//! Layout:
//! * [`params`]   — rotation parameter banks per variant (paper §5.5)
//! * [`scalar`]   — Lloyd–Max / uniform scalar quantizers (+ [`codebooks`])
//! * [`packing`]  — 2/3/4-bit code packing
//! * [`pipeline`] — the fused stage-1 hot path (paper Alg. 1) + the
//!   unfused module-level reference (§9.4)
//! * [`kernels`]  — runtime-dispatched SIMD (AVX2/NEON) encode/decode
//!   kernels behind the `Stage1Config::backend` knob; scalar reference
//!   retained as the bit-exact fallback
//! * [`cost`]     — the analytical complexity model (Table 1)
//! * [`residual`] — QJL-style stage-2 correction (§8)
//! * [`learn`]    — learned rotations (Table 3 axis)

pub mod codebooks;
pub mod cost;
pub mod kernels;
pub mod learn;
pub mod packing;
pub mod params;
pub mod pipeline;
pub mod residual;
pub mod scalar;

pub use kernels::KernelBackend;
pub use params::{ParamBank, Variant};
pub use pipeline::{mse, BatchScratch, PackedSink, Stage1, Stage1Config, Stage1Unfused};
pub use scalar::{QuantKind, ScalarQuantizer};
