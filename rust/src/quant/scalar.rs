//! Scalar quantizers (paper §3 item 2): Lloyd–Max codebooks trained on
//! the analytic rotated-coordinate marginal (shipped as constants shared
//! with the Pallas kernels — see `codebooks.rs`) and a symmetric uniform
//! quantizer.  Includes a Lloyd trainer used by tests and by codebook
//! retraining on empirical data (ablation axis).

use crate::quant::codebooks;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantKind {
    /// Lloyd–Max codebook for the f_k marginal (paper eq. 36).
    Lloyd,
    /// Symmetric mid-rise uniform on [-√k, √k].
    Uniform,
}

/// A small scalar codebook quantizer (≤ 16 levels at b ≤ 4).
///
/// Hot-path layout (§Perf): levels and boundaries live in fixed-size
/// arrays (no heap indirection); `encode1` is a branchless 4-step binary
/// search over boundaries padded with +∞, so every bit width costs the
/// same 4 predictable compare+adds — the CPU analogue of the fused CUDA
/// kernel's unrolled compile-time codebook.
#[derive(Clone, Debug)]
pub struct ScalarQuantizer {
    pub bits: u8,
    n_levels: usize,
    levels: [f32; 16],
    /// bounds[i] separates level i from level i+1; padded with +∞
    bounds: [f32; 15],
}

impl ScalarQuantizer {
    pub fn from_levels(bits: u8, levels_in: Vec<f32>) -> ScalarQuantizer {
        assert_eq!(levels_in.len(), 1usize << bits, "level count != 2^bits");
        assert!(
            levels_in.windows(2).all(|w| w[0] < w[1]),
            "levels must be strictly ascending"
        );
        let n_levels = levels_in.len();
        let mut levels = [0.0f32; 16];
        levels[..n_levels].copy_from_slice(&levels_in);
        // pad the tail with the top level so a (padded-)search result of
        // an out-of-range index still decodes to something sane
        for i in n_levels..16 {
            levels[i] = levels_in[n_levels - 1];
        }
        let mut bounds = [f32::INFINITY; 15];
        for (i, w) in levels_in.windows(2).enumerate() {
            bounds[i] = 0.5 * (w[0] + w[1]);
        }
        ScalarQuantizer {
            bits,
            n_levels,
            levels,
            bounds,
        }
    }

    /// The shipped Lloyd–Max codebook for block size k.
    pub fn lloyd(k: usize, bits: u8) -> ScalarQuantizer {
        let levels = codebooks::lloyd_codebook(k, bits).to_vec();
        ScalarQuantizer::from_levels(bits, levels)
    }

    /// Lloyd–Max for N(0,1) (grouped-8D / unnormalized ablations).
    pub fn gaussian(bits: u8) -> ScalarQuantizer {
        ScalarQuantizer::from_levels(bits, codebooks::gaussian_lloyd_codebook(bits).to_vec())
    }

    /// Symmetric mid-rise uniform quantizer on [-clip, clip], matching
    /// `python/compile/kernels/quantizer.py::quant_dequant_uniform`.
    pub fn uniform(bits: u8, clip: f32) -> ScalarQuantizer {
        let n = 1usize << bits;
        let step = 2.0 * clip / n as f32;
        let levels = (0..n)
            .map(|i| (i as f32 + 0.5) * step - clip)
            .collect();
        ScalarQuantizer::from_levels(bits, levels)
    }

    pub fn for_kind(kind: QuantKind, k: usize, bits: u8) -> ScalarQuantizer {
        match kind {
            QuantKind::Lloyd => ScalarQuantizer::lloyd(k, bits),
            QuantKind::Uniform => ScalarQuantizer::uniform(bits, (k as f32).sqrt()),
        }
    }

    pub fn levels(&self) -> &[f32] {
        &self.levels[..self.n_levels]
    }

    pub fn n_levels(&self) -> usize {
        self.n_levels
    }

    /// The raw 16-entry level table (tail padded with the top level) —
    /// exactly what `decode1` indexes.  Exposed for the SIMD kernels'
    /// in-register table lookups (`quant::kernels`).
    pub fn levels_padded(&self) -> &[f32; 16] {
        &self.levels
    }

    /// The raw 15-entry decision-boundary table (tail padded with +∞) —
    /// exactly what `encode1` searches.  `encode1` equals the rank
    /// `|{i : x > bounds[i]}|`, which is how the SIMD kernels compute it.
    pub fn bounds_padded(&self) -> &[f32; 15] {
        &self.bounds
    }

    /// Nearest-level index: branchless 4-step binary search over the
    /// ∞-padded boundary array.  Identical cost for b ∈ {2, 3, 4}.
    #[inline(always)]
    pub fn encode1(&self, x: f32) -> u8 {
        let b = &self.bounds;
        let mut lo = 8 * usize::from(x > b[7]);
        lo += 4 * usize::from(x > b[lo + 3]);
        lo += 2 * usize::from(x > b[lo + 1]);
        lo += usize::from(x > b[lo]);
        lo as u8
    }

    #[inline(always)]
    pub fn decode1(&self, idx: u8) -> f32 {
        self.levels[(idx & 15) as usize]
    }

    /// Fused quantize→dequantize of one value.
    #[inline(always)]
    pub fn qdq1(&self, x: f32) -> f32 {
        self.levels[(self.encode1(x) & 15) as usize]
    }

    pub fn encode_slice(&self, xs: &[f32], out: &mut Vec<u8>) {
        out.extend(xs.iter().map(|&x| self.encode1(x)));
    }

    pub fn qdq_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.qdq1(*x);
        }
    }

    /// Mean squared distortion of this quantizer on a sample.
    pub fn distortion(&self, xs: &[f32]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|&x| {
                let e = (x - self.qdq1(x)) as f64;
                e * e
            })
            .sum::<f64>()
            / xs.len() as f64
    }
}

/// Classic Lloyd iteration on empirical samples; returns sorted levels.
/// Mirrors `python/compile/kernels/quantizer.py::lloyd_max_train`.
pub fn train_lloyd(samples: &[f32], n_levels: usize, iters: usize) -> Vec<f32> {
    assert!(n_levels >= 2 && !samples.is_empty());
    let mut s: Vec<f32> = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = s[0] as f64;
    let hi = s[s.len() - 1] as f64;
    let mut levels: Vec<f64> = (1..=n_levels)
        .map(|i| lo + (hi - lo) * i as f64 / (n_levels + 1) as f64)
        .collect();
    let mut sums = vec![0.0f64; n_levels];
    let mut counts = vec![0usize; n_levels];
    for _ in 0..iters {
        sums.fill(0.0);
        counts.fill(0);
        // partition by boundaries (s sorted → sweep)
        let mut j = 0usize;
        for &x in &s {
            while j + 1 < n_levels && (x as f64) > 0.5 * (levels[j] + levels[j + 1]) {
                j += 1;
            }
            // x may belong to an earlier cell when sweeping restarted; since
            // s is sorted j only advances — correct.
            sums[j] += x as f64;
            counts[j] += 1;
        }
        let mut moved = 0.0f64;
        for i in 0..n_levels {
            if counts[i] > 0 {
                let nl = sums[i] / counts[i] as f64;
                moved = moved.max((nl - levels[i]).abs());
                levels[i] = nl;
            }
        }
        // levels must stay sorted; Lloyd preserves order, assert in debug
        debug_assert!(levels.windows(2).all(|w| w[0] <= w[1]));
        if moved < 1e-10 {
            break;
        }
    }
    levels.iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn shipped_codebooks_valid() {
        for k in [2, 3, 4] {
            for bits in [2u8, 3, 4] {
                let q = ScalarQuantizer::lloyd(k, bits);
                assert_eq!(q.levels().len(), 1 << bits);
            }
        }
    }

    #[test]
    fn codebooks_symmetric() {
        for k in [2usize, 3, 4] {
            let q = ScalarQuantizer::lloyd(k, 4);
            let l = q.levels();
            for i in 0..l.len() {
                assert!(
                    (l[i] + l[l.len() - 1 - i]).abs() < 6e-3,
                    "k={k} level {i}: {} vs {}",
                    l[i],
                    l[l.len() - 1 - i]
                );
            }
        }
    }

    #[test]
    fn encode_is_nearest_neighbor() {
        let q = ScalarQuantizer::lloyd(4, 3);
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let x = rng.gaussian() as f32 * 2.0;
            let idx = q.encode1(x) as usize;
            let best = q
                .levels()
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    (a.1 - x).abs().partial_cmp(&(b.1 - x).abs()).unwrap()
                })
                .unwrap()
                .0;
            // ties at exact boundaries can go either way — accept both
            let d_idx = (q.levels()[idx] - x).abs();
            let d_best = (q.levels()[best] - x).abs();
            assert!((d_idx - d_best).abs() < 1e-6);
        }
    }

    #[test]
    fn qdq_idempotent() {
        let q = ScalarQuantizer::lloyd(2, 2);
        let mut rng = Rng::new(2);
        for _ in 0..500 {
            let x = rng.gaussian() as f32;
            let once = q.qdq1(x);
            assert_eq!(q.qdq1(once), once);
        }
    }

    #[test]
    fn uniform_matches_python_formula() {
        // python: idx = clip(floor((clip(x) + c)/step), 0, n-1); out = (idx+.5)*step - c
        let bits = 3u8;
        let clip = 2.0f32;
        let q = ScalarQuantizer::uniform(bits, clip);
        let n = 1 << bits;
        let step = 2.0 * clip / n as f32;
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let x = rng.gaussian() as f32 * 2.0;
            let xc = x.clamp(-clip, clip - 1e-7 * clip);
            let idx = (((xc + clip) / step).floor()).clamp(0.0, (n - 1) as f32);
            let want = (idx + 0.5) * step - clip;
            assert!(
                (q.qdq1(x) - want).abs() < 1e-5,
                "x={x} got={} want={want}",
                q.qdq1(x)
            );
        }
    }

    #[test]
    fn distortion_decreases_with_bits() {
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.gaussian() as f32).collect();
        let d2 = ScalarQuantizer::gaussian(2).distortion(&xs);
        let d3 = ScalarQuantizer::gaussian(3).distortion(&xs);
        let d4 = ScalarQuantizer::gaussian(4).distortion(&xs);
        assert!(d2 > d3 && d3 > d4, "{d2} {d3} {d4}");
    }

    #[test]
    fn trained_lloyd_beats_uniform_on_gaussian() {
        let mut rng = Rng::new(5);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.gaussian() as f32).collect();
        let levels = train_lloyd(&xs, 8, 100);
        let trained = ScalarQuantizer::from_levels(3, levels);
        let uniform = ScalarQuantizer::uniform(3, 3.0);
        assert!(trained.distortion(&xs) < uniform.distortion(&xs));
    }

    #[test]
    fn rust_trainer_close_to_shipped_gaussian_codebook() {
        // the shipped python-trained gaussian codebook and our rust
        // trainer should agree to sampling error
        let mut rng = Rng::new(6);
        let xs: Vec<f32> = (0..400_000).map(|_| rng.gaussian() as f32).collect();
        let levels = train_lloyd(&xs, 8, 200);
        let shipped = codebooks::gaussian_lloyd_codebook(3);
        for (a, b) in levels.iter().zip(shipped) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "level count")]
    fn from_levels_validates_count() {
        ScalarQuantizer::from_levels(2, vec![0.0, 1.0]);
    }
}
