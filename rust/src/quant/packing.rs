//! Bit packing for quantized codes (2/3/4 bits per code).
//!
//! The compressed KV-cache pages store codes packed; the paper's
//! hardware-alignment argument shows up here too: 2- and 4-bit codes pack
//! into whole bytes with power-of-two fan-in (4 or 2 codes per byte),
//! while the generic path handles 3-bit codes via a u64 bit accumulator.

/// Number of bytes needed for `n` codes at `bits` bits each.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Pack `codes` (each < 2^bits) into `out` (cleared first).
pub fn pack(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    out.clear();
    pack_append(codes, bits, out);
}

/// Pack `codes` (each < 2^bits), appending to `out` — the batch encode
/// path packs many vectors into one contiguous buffer with this.
pub fn pack_append(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    out.reserve(packed_len(codes.len(), bits));
    match bits {
        4 => {
            for pair in codes.chunks(2) {
                let lo = pair[0] & 0x0F;
                let hi = if pair.len() > 1 { pair[1] & 0x0F } else { 0 };
                out.push(lo | (hi << 4));
            }
        }
        2 => {
            for quad in codes.chunks(4) {
                let mut b = 0u8;
                for (i, &c) in quad.iter().enumerate() {
                    b |= (c & 0x03) << (2 * i);
                }
                out.push(b);
            }
        }
        _ => {
            // generic bitstream (used for 3-bit and any future widths)
            let mut acc: u64 = 0;
            let mut nbits: u32 = 0;
            let mask = (1u64 << bits) - 1;
            for &c in codes {
                acc |= (c as u64 & mask) << nbits;
                nbits += bits as u32;
                while nbits >= 8 {
                    out.push((acc & 0xFF) as u8);
                    acc >>= 8;
                    nbits -= 8;
                }
            }
            if nbits > 0 {
                out.push((acc & 0xFF) as u8);
            }
        }
    }
}

/// Unpack `n` codes of `bits` bits from `data` into `out` (cleared first).
pub fn unpack(data: &[u8], bits: u8, n: usize, out: &mut Vec<u8>) {
    out.clear();
    out.resize(n, 0);
    unpack_into(data, bits, n, out);
}

/// Unpack `n` codes of `bits` bits from `data` into the slice `out`
/// (`out.len() >= n`) — the batch tile paths stage several vectors'
/// codes into rows of one scratch buffer with this.
pub fn unpack_into(data: &[u8], bits: u8, n: usize, out: &mut [u8]) {
    debug_assert!(out.len() >= n);
    match bits {
        4 => {
            for (i, o) in out.iter_mut().enumerate().take(n) {
                let byte = data[i / 2];
                *o = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            }
        }
        2 => {
            for (i, o) in out.iter_mut().enumerate().take(n) {
                let byte = data[i / 4];
                *o = (byte >> (2 * (i % 4))) & 0x03;
            }
        }
        _ => {
            let mut acc: u64 = 0;
            let mut nbits: u32 = 0;
            let mut pos = 0usize;
            let mask = (1u64 << bits) - 1;
            for o in out.iter_mut().take(n) {
                while nbits < bits as u32 {
                    acc |= (data[pos] as u64) << nbits;
                    pos += 1;
                    nbits += 8;
                }
                *o = (acc & mask) as u8;
                acc >>= bits;
                nbits -= bits as u32;
            }
        }
    }
}

/// Direct dequantize-from-packed: avoids materializing the index vector
/// on the decode hot path.  `levels.len() == 2^bits`.
pub fn unpack_dequantize(data: &[u8], bits: u8, n: usize, levels: &[f32], out: &mut [f32]) {
    debug_assert_eq!(levels.len(), 1usize << bits);
    debug_assert!(out.len() >= n);
    match bits {
        4 => {
            for i in 0..n {
                let byte = data[i / 2];
                let c = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                out[i] = levels[c as usize];
            }
        }
        2 => {
            for i in 0..n {
                let byte = data[i / 4];
                out[i] = levels[((byte >> (2 * (i % 4))) & 0x03) as usize];
            }
        }
        _ => {
            let mut acc: u64 = 0;
            let mut nbits: u32 = 0;
            let mut pos = 0usize;
            let mask = (1u64 << bits) - 1;
            for o in out.iter_mut().take(n) {
                while nbits < bits as u32 {
                    acc |= (data[pos] as u64) << nbits;
                    pos += 1;
                    nbits += 8;
                }
                *o = levels[(acc & mask) as usize];
                acc >>= bits;
                nbits -= bits as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn roundtrip_case(bits: u8, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
        let mut packed = Vec::new();
        pack(&codes, bits, &mut packed);
        assert_eq!(packed.len(), packed_len(n, bits));
        let mut back = Vec::new();
        unpack(&packed, bits, n, &mut back);
        assert_eq!(back, codes, "bits={bits} n={n}");
    }

    #[test]
    fn roundtrip_all_widths_and_lengths() {
        for bits in [2u8, 3, 4] {
            for n in [0usize, 1, 2, 3, 7, 8, 9, 64, 127, 128, 1000] {
                roundtrip_case(bits, n, bits as u64 * 1000 + n as u64);
            }
        }
    }

    #[test]
    fn packed_len_math() {
        assert_eq!(packed_len(128, 2), 32);
        assert_eq!(packed_len(128, 3), 48);
        assert_eq!(packed_len(128, 4), 64);
        assert_eq!(packed_len(3, 3), 2); // 9 bits → 2 bytes
        assert_eq!(packed_len(0, 3), 0);
    }

    #[test]
    fn compression_ratio() {
        // the headline KV saving: f32 (4 bytes) → b bits
        for (bits, ratio) in [(2u8, 16.0f64), (3, 32.0 / 3.0), (4, 8.0)] {
            let n = 1024;
            let packed = packed_len(n, bits);
            let r = (n * 4) as f64 / packed as f64;
            assert!((r - ratio).abs() < 0.1, "bits={bits}: {r}");
        }
    }

    #[test]
    fn unpack_dequantize_matches_two_step() {
        let mut rng = Rng::new(9);
        for bits in [2u8, 3, 4] {
            let levels: Vec<f32> = (0..(1 << bits)).map(|i| i as f32 * 0.5 - 2.0).collect();
            let n = 333;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let mut packed = Vec::new();
            pack(&codes, bits, &mut packed);
            let mut direct = vec![0.0f32; n];
            unpack_dequantize(&packed, bits, n, &levels, &mut direct);
            let want: Vec<f32> = codes.iter().map(|&c| levels[c as usize]).collect();
            assert_eq!(direct, want);
        }
    }

    #[test]
    fn pack_append_concatenates_per_vector_packings() {
        // appending two packings must equal packing each separately and
        // concatenating the byte runs (vectors are byte-aligned)
        let mut rng = Rng::new(11);
        for bits in [2u8, 3, 4] {
            // ragged lengths: each vector's packing is byte-padded, so
            // appends always start byte-aligned
            let a: Vec<u8> = (0..127).map(|_| rng.below(1 << bits) as u8).collect();
            let b: Vec<u8> = (0..61).map(|_| rng.below(1 << bits) as u8).collect();
            let mut joined = Vec::new();
            pack_append(&a, bits, &mut joined);
            pack_append(&b, bits, &mut joined);
            let mut pa = Vec::new();
            let mut pb = Vec::new();
            pack(&a, bits, &mut pa);
            pack(&b, bits, &mut pb);
            pa.extend_from_slice(&pb);
            assert_eq!(joined, pa, "bits={bits}");
        }
    }

    #[test]
    fn high_bits_masked() {
        // stray high bits in input codes must not corrupt neighbors
        let codes = vec![0xFFu8, 0x00, 0xFF, 0x00];
        let mut packed = Vec::new();
        pack(&codes, 2, &mut packed);
        let mut back = Vec::new();
        unpack(&packed, 2, 4, &mut back);
        assert_eq!(back, vec![3, 0, 3, 0]);
    }
}
