//! Analytical complexity model — regenerates paper Table 1 and scales it
//! to arbitrary d (§6).
//!
//! Counting conventions follow the paper: one quaternion product ≈ 16
//! FMAs; the RotorQuant 3D block costs ≈ 56 FMAs (the fused rotor
//! sandwich as shipped by the baseline's CUDA kernel, including its
//! multivector expansion overhead); a dense rotation costs d² FMAs; a
//! planar 2D block costs ~4 FMAs.  `measured_*` counters in tests pin
//! the *implemented* arithmetic to the model within the documented
//! conventions.

use crate::quant::params::Variant;

/// Forward rotation cost (FMAs) for one vector at head dim d — the
/// quantity in paper Table 1's "FMAs" column.
pub fn forward_rotation_fmas(variant: Variant, d: usize) -> usize {
    let g4 = d.div_ceil(4);
    let g2 = d.div_ceil(2);
    match variant {
        // two quaternion products per block (eq. 22): 32 g₄
        Variant::IsoFull => 32 * g4,
        // one quaternion product per block (eq. 25): 16 g₄
        Variant::IsoFast => 16 * g4,
        // one 2×2 rotation per pair: 4 FMAs
        Variant::Planar2D => 4 * g2,
        // paper's counting: ≈ 56 FMAs per 3D rotor block (incl. the
        // multivector expansion its kernel pays), plus the planar tail
        Variant::Rotor3D => {
            let nfull = d / 3;
            let tail = match d % 3 {
                2 => 4,
                1 => 0,
                _ => 0,
            };
            56 * nfull + tail
        }
        Variant::Dense => d * d,
        // two chained double-sided stages per 8-block: 2 × 2 × 32 = 128
        Variant::Grouped8D => 128 * d.div_ceil(8),
    }
}

/// Stored rotation parameters (scalars) — Table 1's "Params" column, in
/// the paper's convention (per-block realized scalars: (cosθ, sinθ)
/// counts as 2, a rotor as 4 incl. tail handling).
pub fn param_scalars_paper_convention(variant: Variant, d: usize) -> usize {
    match variant {
        Variant::Planar2D => 2 * d.div_ceil(2), // (cos, sin) per pair → 128 at d=128
        Variant::Rotor3D => 4 * d.div_ceil(3),  // 43 blocks × 4 → 172 at d=128
        v => v.param_count(d),
    }
}

/// One row of Table 1.
#[derive(Clone, Debug)]
pub struct CostRow {
    pub method: &'static str,
    pub block_structure: String,
    pub params: usize,
    pub fmas: usize,
}

/// Regenerate Table 1 for a given head dim (the paper prints d = 128).
pub fn table1(d: usize) -> Vec<CostRow> {
    let g4 = d.div_ceil(4);
    let g2 = d.div_ceil(2);
    let n3 = d / 3;
    let tail = d % 3;
    vec![
        CostRow {
            method: "TurboQuant (dense)",
            block_structure: format!("dense {d}x{d}"),
            params: param_scalars_paper_convention(Variant::Dense, d),
            fmas: forward_rotation_fmas(Variant::Dense, d),
        },
        CostRow {
            method: "RotorQuant",
            block_structure: if tail == 2 {
                format!("{n3} x 3D + 2D tail")
            } else if tail == 1 {
                format!("{n3} x 3D + 1D tail")
            } else {
                format!("{n3} x 3D")
            },
            params: param_scalars_paper_convention(Variant::Rotor3D, d),
            fmas: forward_rotation_fmas(Variant::Rotor3D, d),
        },
        CostRow {
            method: "IsoQuant-2D",
            block_structure: format!("{g2} x 2D"),
            params: param_scalars_paper_convention(Variant::Planar2D, d),
            fmas: forward_rotation_fmas(Variant::Planar2D, d),
        },
        CostRow {
            method: "IsoQuant-Full",
            block_structure: format!("{g4} x 4D"),
            params: param_scalars_paper_convention(Variant::IsoFull, d),
            fmas: forward_rotation_fmas(Variant::IsoFull, d),
        },
        CostRow {
            method: "IsoQuant-Fast",
            block_structure: format!("{g4} x 4D"),
            params: param_scalars_paper_convention(Variant::IsoFast, d),
            fmas: forward_rotation_fmas(Variant::IsoFast, d),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_table1_at_d128() {
        // paper Table 1 (d = 128)
        assert_eq!(forward_rotation_fmas(Variant::Dense, 128), 16_384);
        assert_eq!(forward_rotation_fmas(Variant::IsoFull, 128), 1_024);
        assert_eq!(forward_rotation_fmas(Variant::IsoFast, 128), 512);
        assert_eq!(forward_rotation_fmas(Variant::Planar2D, 128), 256);
        // paper: ≈ 2,408 = 42×56 + tail ≈ 2352 + 4 (we print 2356; the
        // paper's 2408 uses 43×56, counting the tail as a full block)
        let rotor = forward_rotation_fmas(Variant::Rotor3D, 128);
        assert!((2_300..=2_410).contains(&rotor), "rotor {rotor}");

        assert_eq!(param_scalars_paper_convention(Variant::Dense, 128), 16_384);
        assert_eq!(param_scalars_paper_convention(Variant::Rotor3D, 128), 172);
        assert_eq!(param_scalars_paper_convention(Variant::Planar2D, 128), 128);
        assert_eq!(param_scalars_paper_convention(Variant::IsoFull, 128), 256);
        assert_eq!(param_scalars_paper_convention(Variant::IsoFast, 128), 128);
    }

    #[test]
    fn full_cuts_rotor_cost_by_more_than_2x() {
        // §6: "cuts rotation arithmetic by more than 2×"
        for d in [128usize, 256, 512] {
            let rotor = forward_rotation_fmas(Variant::Rotor3D, d);
            let full = forward_rotation_fmas(Variant::IsoFull, d);
            let fast = forward_rotation_fmas(Variant::IsoFast, d);
            assert!(rotor as f64 / full as f64 > 2.0, "d={d}");
            assert!(rotor as f64 / fast as f64 > 4.0, "d={d}");
        }
    }

    #[test]
    fn linear_scaling_in_d() {
        for v in [Variant::IsoFull, Variant::IsoFast, Variant::Planar2D, Variant::Rotor3D] {
            let f128 = forward_rotation_fmas(v, 128) as f64;
            let f512 = forward_rotation_fmas(v, 512) as f64;
            assert!((f512 / f128 - 4.0).abs() < 0.1, "{v:?}");
        }
        // dense is quadratic
        let d128 = forward_rotation_fmas(Variant::Dense, 128) as f64;
        let d512 = forward_rotation_fmas(Variant::Dense, 512) as f64;
        assert!((d512 / d128 - 16.0).abs() < 0.1);
    }

    #[test]
    fn table_rows_complete() {
        let rows = table1(128);
        assert_eq!(rows.len(), 5);
        assert!(rows[1].block_structure.contains("42 x 3D + 2D tail"));
        assert!(rows[3].block_structure.contains("32 x 4D"));
    }
}
