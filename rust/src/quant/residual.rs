//! Stage-2 residual correction (paper §8): QJL-style quantized
//! Johnson–Lindenstrauss projection of the stage-1 residual
//! r = x − x̂_mse, providing an (approximately) unbiased inner-product
//! correction ⟨q, x⟩ ≈ ⟨q, x̂⟩ + ĉ(q, r).
//!
//! Following QJL (paper [3]): project the residual with a Gaussian
//! matrix S (m × d), keep only the *signs* of Sr (1 bit each) plus the
//! residual norm ‖r‖; estimate the inner product against a query q as
//!
//! ```text
//! ĉ(q, r) = √(π/2) / m · ‖r‖ · ⟨sign(Sr), Sq⟩
//! ```
//!
//! which is unbiased for the cosine similarity under Gaussian S (the
//! sign-projection estimator).  This module makes IsoQuant a drop-in
//! stage-1 inside a TurboQuant-style two-stage pipeline (§9.6 item 1).

use crate::util::prng::Rng;

/// Shared projection matrix (one per model/layer, reused across tokens).
pub struct QjlProjector {
    pub d: usize,
    pub m: usize,
    /// row-major m × d Gaussian matrix
    s: Vec<f32>,
}

/// Compressed residual: 1-bit signs + the residual norm.
#[derive(Clone, Debug)]
pub struct QjlResidual {
    pub signs: Vec<u8>, // bit-packed, ⌈m/8⌉ bytes
    pub norm: f32,
}

impl QjlProjector {
    pub fn new(d: usize, m: usize, seed: u64) -> QjlProjector {
        let mut rng = Rng::new(seed);
        QjlProjector {
            d,
            m,
            s: rng.gaussian_vec_f32(m * d),
        }
    }

    /// Bytes per stored residual.
    pub fn encoded_len(&self) -> usize {
        self.m.div_ceil(8) + 4
    }

    /// Compress a residual vector r (length d).
    pub fn encode(&self, r: &[f32]) -> QjlResidual {
        assert_eq!(r.len(), self.d);
        let norm = r.iter().map(|&v| v * v).sum::<f32>().sqrt();
        let mut signs = vec![0u8; self.m.div_ceil(8)];
        for i in 0..self.m {
            let row = &self.s[i * self.d..(i + 1) * self.d];
            let mut dot = 0.0f32;
            for j in 0..self.d {
                dot += row[j] * r[j];
            }
            if dot >= 0.0 {
                signs[i / 8] |= 1 << (i % 8);
            }
        }
        QjlResidual { signs, norm }
    }

    /// Estimate ⟨q, r⟩ from the compressed residual (QJL estimator).
    pub fn inner_product(&self, q: &[f32], res: &QjlResidual) -> f32 {
        assert_eq!(q.len(), self.d);
        if res.norm == 0.0 {
            return 0.0;
        }
        let qn = q.iter().map(|&v| v * v).sum::<f32>().sqrt();
        if qn == 0.0 {
            return 0.0;
        }
        let mut acc = 0.0f64;
        let mut sq_norm_acc = 0.0f64;
        for i in 0..self.m {
            let row = &self.s[i * self.d..(i + 1) * self.d];
            let mut sq = 0.0f32;
            for j in 0..self.d {
                sq += row[j] * q[j];
            }
            let sgn = if res.signs[i / 8] >> (i % 8) & 1 == 1 {
                1.0f64
            } else {
                -1.0f64
            };
            acc += sgn * sq as f64;
            sq_norm_acc += (sq as f64) * (sq as f64);
        }
        // E[sign(⟨s,r⟩)·⟨s,q⟩] = √(2/π) ‖q‖ cos∠(q,r); invert:
        let scale = (std::f64::consts::PI / 2.0).sqrt() / self.m as f64;
        let cos_est = (acc * scale) / qn as f64;
        let _ = sq_norm_acc;
        (cos_est * res.norm as f64 * qn as f64) as f32
    }
}

/// Two-stage pipeline glue: stage-1 reconstruction plus stage-2 corrected
/// inner products (the quantity attention cares about, §9.6 item 2).
pub struct TwoStage {
    pub stage1: crate::quant::pipeline::Stage1,
    pub projector: QjlProjector,
}

/// Compressed two-stage representation of one vector.
pub struct TwoStageCode {
    pub stage1_bytes: Vec<u8>,
    pub residual: QjlResidual,
}

impl TwoStage {
    pub fn new(stage1: crate::quant::pipeline::Stage1, m: usize, seed: u64) -> TwoStage {
        let d = stage1.d();
        TwoStage {
            stage1,
            projector: QjlProjector::new(d, m, seed),
        }
    }

    pub fn encode(&self, x: &[f32]) -> TwoStageCode {
        let mut s1 = Vec::new();
        self.stage1.encode(x, &mut s1);
        let mut xhat = vec![0.0f32; x.len()];
        self.stage1.decode(&s1, &mut xhat);
        let r: Vec<f32> = x.iter().zip(&xhat).map(|(&a, &b)| a - b).collect();
        TwoStageCode {
            stage1_bytes: s1,
            residual: self.projector.encode(&r),
        }
    }

    /// Corrected inner-product estimate ⟨q, x⟩ ≈ ⟨q, x̂⟩ + ĉ(q, r).
    pub fn inner_product(&self, q: &[f32], code: &TwoStageCode) -> f32 {
        let mut xhat = vec![0.0f32; q.len()];
        self.stage1.decode(&code.stage1_bytes, &mut xhat);
        let base: f32 = q.iter().zip(&xhat).map(|(&a, &b)| a * b).sum();
        base + self.projector.inner_product(q, &code.residual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::params::Variant;
    use crate::quant::pipeline::{Stage1, Stage1Config};

    #[test]
    fn sign_estimator_roughly_unbiased() {
        // average over many projectors: estimate of ⟨q, r⟩ converges
        let d = 64;
        let mut rng = Rng::new(1);
        let r: Vec<f32> = rng.gaussian_vec_f32(d);
        let q: Vec<f32> = rng.gaussian_vec_f32(d);
        let truth: f32 = q.iter().zip(&r).map(|(&a, &b)| a * b).sum();
        let mut est_sum = 0.0f64;
        let trials = 30;
        for t in 0..trials {
            let p = QjlProjector::new(d, 256, 100 + t);
            let code = p.encode(&r);
            est_sum += p.inner_product(&q, &code) as f64;
        }
        let est = est_sum / trials as f64;
        let scale = (r.iter().map(|&v| (v * v) as f64).sum::<f64>()
            * q.iter().map(|&v| (v * v) as f64).sum::<f64>())
        .sqrt();
        assert!(
            (est - truth as f64).abs() < 0.25 * scale,
            "est {est} truth {truth} scale {scale}"
        );
    }

    #[test]
    fn residual_correction_reduces_inner_product_error() {
        // §8/§9.6: two-stage beats stage-1-only on inner products
        let d = 128;
        let mut rng = Rng::new(2);
        let s1 = Stage1::new(Stage1Config::new(Variant::IsoFull, d, 2));
        let two = TwoStage::new(s1.clone(), 512, 7);
        let mut err1 = 0.0f64;
        let mut err2 = 0.0f64;
        let n = 200;
        for _ in 0..n {
            let x = rng.gaussian_vec_f32(d);
            let q = rng.gaussian_vec_f32(d);
            let truth: f32 = q.iter().zip(&x).map(|(&a, &b)| a * b).sum();
            let code = two.encode(&x);
            let mut xhat = vec![0.0f32; d];
            s1.decode(&code.stage1_bytes, &mut xhat);
            let base: f32 = q.iter().zip(&xhat).map(|(&a, &b)| a * b).sum();
            let corrected = two.inner_product(&q, &code);
            err1 += ((base - truth) as f64).powi(2);
            err2 += ((corrected - truth) as f64).powi(2);
        }
        assert!(
            err2 < err1,
            "corrected {err2} should beat stage-1-only {err1}"
        );
    }

    #[test]
    fn zero_residual_estimates_zero() {
        let p = QjlProjector::new(16, 64, 3);
        let code = p.encode(&vec![0.0; 16]);
        assert_eq!(code.norm, 0.0);
        let q = vec![1.0f32; 16];
        assert_eq!(p.inner_product(&q, &code), 0.0);
    }

    #[test]
    fn encoded_len() {
        let p = QjlProjector::new(128, 256, 1);
        assert_eq!(p.encoded_len(), 32 + 4);
    }
}
