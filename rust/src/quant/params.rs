//! Rotation parameter banks for every stage-1 variant (paper §5.5):
//! random Haar initialization, learned refinement, serialization, and
//! flattening into the shapes the AOT HLO graphs expect.

use anyhow::{bail, Result};

use crate::math::quaternion::{self as quat, Quat};
use crate::util::prng::Rng;
use crate::util::tensorfile::Tensor;

/// The rotation families of the paper (plus the 8D grouped ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// IsoQuant-Full: v ↦ qL v conj(qR), 6 DoF per 4-block (§5.2).
    IsoFull,
    /// IsoQuant-Fast: v ↦ qL v, 3 DoF per 4-block (§5.3).
    IsoFast,
    /// 2D planar special case (§5.4).
    Planar2D,
    /// RotorQuant baseline: 3D Clifford rotors + 2D tail (paper [2]).
    Rotor3D,
    /// TurboQuant-style dense rotation (paper [1]).
    Dense,
    /// 8D grouped variant: two chained 4-blocks with a fixed lane swap
    /// (Table 3 "optionally 8D grouped variants" axis).
    Grouped8D,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::IsoFull => "iso-full",
            Variant::IsoFast => "iso-fast",
            Variant::Planar2D => "iso-2d",
            Variant::Rotor3D => "rotorquant",
            Variant::Dense => "dense",
            Variant::Grouped8D => "iso-8d",
        }
    }

    pub fn from_name(s: &str) -> Result<Variant> {
        Ok(match s {
            "iso-full" | "full" => Variant::IsoFull,
            "iso-fast" | "fast" => Variant::IsoFast,
            "iso-2d" | "2d" | "planar" => Variant::Planar2D,
            "rotorquant" | "rotor" => Variant::Rotor3D,
            "dense" | "turboquant" => Variant::Dense,
            "iso-8d" | "8d" => Variant::Grouped8D,
            _ => bail!("unknown variant {s:?}"),
        })
    }

    /// Block size k of the local rotations (the quantizer marginal's k).
    pub fn block_k(self) -> usize {
        match self {
            Variant::IsoFull | Variant::IsoFast => 4,
            Variant::Planar2D => 2,
            Variant::Rotor3D => 3,
            Variant::Dense => 4,     // large-d marginal ≈ the k=4 table (see ref.py)
            Variant::Grouped8D => 4, // per-lane marginal of chained 4D rotations
        }
    }

    /// Parameter count at head dim d (paper §6).
    pub fn param_count(self, d: usize) -> usize {
        let g4 = d.div_ceil(4);
        let g2 = d.div_ceil(2);
        match self {
            Variant::IsoFull => 8 * g4,
            Variant::IsoFast => 4 * g4,
            Variant::Planar2D => g2, // one angle per pair (stored as θ)
            Variant::Rotor3D => {
                // one rotor (4 scalars) per 3-block + tail angle
                4 * (d / 3) + if d % 3 == 2 { 1 } else { 0 }
            }
            Variant::Dense => d * d,
            Variant::Grouped8D => 16 * (d.div_ceil(8)), // two quaternion pairs per 8-block
        }
    }
}

/// Parameters for one (variant, d) rotation bank.
#[derive(Clone, Debug)]
pub struct ParamBank {
    pub variant: Variant,
    pub d: usize,
    /// left quaternions: IsoFull / IsoFast / Rotor3D (rotor as quat) /
    /// Grouped8D (2 per 8-block: positions 2i, 2i+1)
    pub q_l: Vec<Quat>,
    /// right quaternions: IsoFull / Grouped8D
    pub q_r: Vec<Quat>,
    /// planar angles θ (Planar2D: one per pair; Rotor3D: tail angle)
    pub theta: Vec<f32>,
    /// precomputed (cosθ, sinθ) mirroring `theta`
    pub cos_sin: Vec<(f32, f32)>,
    /// dense d×d row-major orthogonal matrix (Dense only)
    pub dense: Vec<f32>,
}

impl ParamBank {
    /// Haar-random bank (paper §5.5: Gaussian-normalize on S³, uniform
    /// angles, QR-of-Gaussian for dense).
    pub fn random(variant: Variant, d: usize, seed: u64) -> ParamBank {
        let mut rng = Rng::new(seed);
        let mut bank = ParamBank {
            variant,
            d,
            q_l: Vec::new(),
            q_r: Vec::new(),
            theta: Vec::new(),
            cos_sin: Vec::new(),
            dense: Vec::new(),
        };
        match variant {
            Variant::IsoFull => {
                let g = d.div_ceil(4);
                bank.q_l = (0..g).map(|_| rng.haar_quaternion()).collect();
                bank.q_r = (0..g).map(|_| rng.haar_quaternion()).collect();
            }
            Variant::IsoFast => {
                let g = d.div_ceil(4);
                bank.q_l = (0..g).map(|_| rng.haar_quaternion()).collect();
            }
            Variant::Planar2D => {
                let g = d.div_ceil(2);
                bank.theta = (0..g).map(|_| rng.haar_angle()).collect();
            }
            Variant::Rotor3D => {
                let nfull = d / 3;
                bank.q_l = (0..nfull).map(|_| rng.haar_quaternion()).collect();
                if d % 3 == 2 {
                    bank.theta = vec![rng.haar_angle()];
                }
            }
            Variant::Dense => {
                bank.dense = rng.haar_orthogonal(d);
            }
            Variant::Grouped8D => {
                let g8 = d.div_ceil(8);
                bank.q_l = (0..2 * g8).map(|_| rng.haar_quaternion()).collect();
                bank.q_r = (0..2 * g8).map(|_| rng.haar_quaternion()).collect();
            }
        }
        bank.refresh_cos_sin();
        bank
    }

    /// Identity bank (no rotation) — baseline for ablations.
    pub fn identity(variant: Variant, d: usize) -> ParamBank {
        let mut bank = ParamBank::random(variant, d, 0);
        for q in bank.q_l.iter_mut().chain(bank.q_r.iter_mut()) {
            *q = quat::IDENTITY;
        }
        for t in bank.theta.iter_mut() {
            *t = 0.0;
        }
        if !bank.dense.is_empty() {
            bank.dense.fill(0.0);
            for i in 0..d {
                bank.dense[i * d + i] = 1.0;
            }
        }
        bank.refresh_cos_sin();
        bank
    }

    pub fn refresh_cos_sin(&mut self) {
        self.cos_sin = self.theta.iter().map(|&t| (t.cos(), t.sin())).collect();
    }

    /// Flatten into the tensors the AOT stage-1 HLO graph expects
    /// (shapes must match `python/compile/model.py::stage1_example_args`).
    pub fn to_hlo_inputs(&self) -> Vec<Tensor> {
        let quats = |qs: &[Quat], name: &str| {
            let flat: Vec<f32> = qs.iter().flatten().copied().collect();
            Tensor::from_f32(name, vec![qs.len(), 4], &flat)
        };
        match self.variant {
            Variant::IsoFull => vec![quats(&self.q_l, "q_l"), quats(&self.q_r, "q_r")],
            Variant::IsoFast => vec![quats(&self.q_l, "q_l")],
            Variant::Planar2D => vec![Tensor::from_f32(
                "theta",
                vec![self.theta.len()],
                &self.theta,
            )],
            Variant::Rotor3D => vec![
                quats(&self.q_l, "q"),
                Tensor::from_f32("tail_theta", vec![self.theta.len()], &self.theta),
            ],
            Variant::Dense => vec![Tensor::from_f32(
                "m",
                vec![self.d, self.d],
                &self.dense,
            )],
            Variant::Grouped8D => vec![quats(&self.q_l, "q_l"), quats(&self.q_r, "q_r")],
        }
    }

    /// Serialize to tensorfile tensors (persisted parameter banks).
    pub fn to_tensors(&self) -> Vec<Tensor> {
        let mut out = vec![Tensor::from_f32(
            "meta",
            vec![2],
            &[self.d as f32, self.variant.block_k() as f32],
        )];
        out[0].name = format!("bank.{}.meta", self.variant.name());
        let mut push = |name: &str, shape: Vec<usize>, data: &[f32]| {
            if !data.is_empty() {
                out.push(Tensor::from_f32(
                    &format!("bank.{}.{}", self.variant.name(), name),
                    shape,
                    data,
                ));
            }
        };
        let ql: Vec<f32> = self.q_l.iter().flatten().copied().collect();
        let qr: Vec<f32> = self.q_r.iter().flatten().copied().collect();
        push("q_l", vec![self.q_l.len(), 4], &ql);
        push("q_r", vec![self.q_r.len(), 4], &qr);
        push("theta", vec![self.theta.len()], &self.theta);
        push("dense", vec![self.d, self.d], &self.dense);
        out
    }

    /// Interpolate two banks of the same shape on the rotation manifold
    /// (slerp per quaternion, lerp per angle) — the §11 smooth-
    /// interpolation property, used by the adaptive-rotation extension.
    pub fn interpolate(&self, other: &ParamBank, t: f32) -> ParamBank {
        assert_eq!(self.variant, other.variant);
        assert_eq!(self.d, other.d);
        let mut out = self.clone();
        for (q, o) in out.q_l.iter_mut().zip(&other.q_l) {
            *q = quat::slerp(*q, *o, t);
        }
        for (q, o) in out.q_r.iter_mut().zip(&other.q_r) {
            *q = quat::slerp(*q, *o, t);
        }
        for (a, b) in out.theta.iter_mut().zip(&other.theta) {
            // shortest-path angular interpolation
            let mut diff = (b - *a) % std::f32::consts::TAU;
            if diff > std::f32::consts::PI {
                diff -= std::f32::consts::TAU;
            }
            if diff < -std::f32::consts::PI {
                diff += std::f32::consts::TAU;
            }
            *a += t * diff;
        }
        out.refresh_cos_sin();
        assert!(out.dense.is_empty(), "dense banks do not interpolate");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_paper_table1_at_d128() {
        // Table 1: TurboQuant 16384, RotorQuant 172, 2D 128 (paper counts
        // cos/sin? no — 2 per block in the paper; we store θ only, the
        // paper's "Params 128" for 64 blocks = 2 per block: count the
        // (cos,sin) realization), Full 256, Fast 128
        assert_eq!(Variant::Dense.param_count(128), 16_384);
        assert_eq!(Variant::IsoFull.param_count(128), 256);
        assert_eq!(Variant::IsoFast.param_count(128), 128);
        // rotor: 42 rotors × 4 + 1 tail angle = 169; the paper's 172
        // counts 43 blocks × 4 — both conventions are small; ours is the
        // literal stored-scalar count
        assert_eq!(Variant::Rotor3D.param_count(128), 169);
        // planar: θ per pair = 64 stored scalars (paper's 128 counts the
        // (cos, sin) pair per block)
        assert_eq!(Variant::Planar2D.param_count(128), 64);
    }

    #[test]
    fn random_banks_have_unit_quaternions() {
        for v in [Variant::IsoFull, Variant::IsoFast, Variant::Rotor3D, Variant::Grouped8D] {
            let bank = ParamBank::random(v, 128, 7);
            for q in bank.q_l.iter().chain(&bank.q_r) {
                let n = quat::norm(*q);
                assert!((n - 1.0).abs() < 1e-5, "{v:?}");
            }
        }
    }

    #[test]
    fn bank_shapes() {
        let full = ParamBank::random(Variant::IsoFull, 128, 1);
        assert_eq!(full.q_l.len(), 32);
        assert_eq!(full.q_r.len(), 32);
        let fast = ParamBank::random(Variant::IsoFast, 128, 1);
        assert_eq!(fast.q_l.len(), 32);
        assert!(fast.q_r.is_empty());
        let p2 = ParamBank::random(Variant::Planar2D, 128, 1);
        assert_eq!(p2.theta.len(), 64);
        let rot = ParamBank::random(Variant::Rotor3D, 128, 1);
        assert_eq!(rot.q_l.len(), 42);
        assert_eq!(rot.theta.len(), 1); // d=128 → 2-wide tail
        let rot129 = ParamBank::random(Variant::Rotor3D, 129, 1);
        assert_eq!(rot129.q_l.len(), 43);
        assert!(rot129.theta.is_empty());
        let dense = ParamBank::random(Variant::Dense, 64, 1);
        assert_eq!(dense.dense.len(), 64 * 64);
        let g8 = ParamBank::random(Variant::Grouped8D, 128, 1);
        assert_eq!(g8.q_l.len(), 32); // 16 8-blocks × 2
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ParamBank::random(Variant::IsoFull, 64, 42);
        let b = ParamBank::random(Variant::IsoFull, 64, 42);
        assert_eq!(a.q_l, b.q_l);
        let c = ParamBank::random(Variant::IsoFull, 64, 43);
        assert_ne!(a.q_l, c.q_l);
    }

    #[test]
    fn hlo_inputs_shapes() {
        let bank = ParamBank::random(Variant::IsoFull, 128, 1);
        let t = bank.to_hlo_inputs();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].shape, vec![32, 4]);
        let rot = ParamBank::random(Variant::Rotor3D, 128, 1);
        let t = rot.to_hlo_inputs();
        assert_eq!(t[0].shape, vec![42, 4]);
        assert_eq!(t[1].shape, vec![1]);
    }

    #[test]
    fn interpolation_stays_on_manifold() {
        let a = ParamBank::random(Variant::IsoFull, 64, 1);
        let b = ParamBank::random(Variant::IsoFull, 64, 2);
        let mid = a.interpolate(&b, 0.5);
        for q in mid.q_l.iter().chain(&mid.q_r) {
            assert!((quat::norm(*q) - 1.0).abs() < 1e-5);
        }
        let at0 = a.interpolate(&b, 0.0);
        assert_eq!(at0.q_l, a.q_l);
    }

    #[test]
    fn identity_bank_is_identity() {
        let bank = ParamBank::identity(Variant::IsoFull, 64);
        for q in bank.q_l.iter().chain(&bank.q_r) {
            assert_eq!(*q, quat::IDENTITY);
        }
    }
}
