//! AVX2 stage-1 kernels (x86_64).
//!
//! Layout conventions (see `kernels` module docs for the contracts):
//!
//! * single-vector kernels put 8 consecutive *blocks* in the 8 lanes of
//!   a register (SoA transpose at the load/store boundary, quaternion
//!   components loaded from the prebuilt [`SoaBank`] arrays);
//! * tile kernels put 8 consecutive *vectors* in the 8 lanes (block's
//!   quaternion broadcast), which is the block-major shape of the KV
//!   page gather;
//! * codes travel packed four-per-dword — block `b`'s four code bytes
//!   are exactly dword `b` of the code array, so an 8-block group's
//!   codes are one 256-bit load/store with byte lanes `w|x<<8|y<<16|z<<24`.
//!
//! Every function here is `unsafe` solely because of
//! `#[target_feature(enable = "avx2")]`; callers (the dispatch in
//! `kernels::mod`) guarantee the feature was runtime-detected.  All
//! memory access is through unaligned intrinsics on ranges proven in
//! bounds by the leading `assert!`s.

#![allow(clippy::too_many_arguments)]

use std::arch::x86_64::*;

use super::SoaBank;
use crate::quant::scalar::ScalarQuantizer;

// ---------------------------------------------------------------------
// small wrappers: keep the hamilton bodies readable while staying on
// the exact-mul/add/sub (never FMA) instruction set
// ---------------------------------------------------------------------

#[inline(always)]
unsafe fn mul(a: __m256, b: __m256) -> __m256 {
    _mm256_mul_ps(a, b)
}

#[inline(always)]
unsafe fn add(a: __m256, b: __m256) -> __m256 {
    _mm256_add_ps(a, b)
}

#[inline(always)]
unsafe fn sub(a: __m256, b: __m256) -> __m256 {
    _mm256_sub_ps(a, b)
}

/// Exact sign flip (IEEE negation never rounds).
#[inline(always)]
unsafe fn neg(a: __m256) -> __m256 {
    _mm256_xor_ps(a, _mm256_set1_ps(-0.0))
}

/// 8 independent quaternions, one per lane, in SoA registers.
#[derive(Clone, Copy)]
struct Q8 {
    w: __m256,
    x: __m256,
    y: __m256,
    z: __m256,
}

/// Vertical Hamilton product with the *exact* left-to-right operation
/// order of `math::quaternion::hamilton` (bit-exactness contract).
#[inline(always)]
unsafe fn hamilton8(a: Q8, b: Q8) -> Q8 {
    Q8 {
        w: sub(sub(sub(mul(a.w, b.w), mul(a.x, b.x)), mul(a.y, b.y)), mul(a.z, b.z)),
        x: sub(add(add(mul(a.w, b.x), mul(a.x, b.w)), mul(a.y, b.z)), mul(a.z, b.y)),
        y: add(add(sub(mul(a.w, b.y), mul(a.x, b.z)), mul(a.y, b.w)), mul(a.z, b.x)),
        z: add(sub(add(mul(a.w, b.z), mul(a.x, b.y)), mul(a.y, b.x)), mul(a.z, b.w)),
    }
}

/// `encode1` as a rank count: `idx = |{i : v > bounds[i]}|` over the
/// ascending boundary array (equal to the scalar branchless binary
/// search — see module docs).
#[inline(always)]
unsafe fn encode_cmp(v: __m256, bounds: &[f32; 15], n_bounds: usize) -> __m256i {
    let mut acc = _mm256_setzero_si256();
    for &b in bounds.iter().take(n_bounds) {
        let m = _mm256_cmp_ps::<_CMP_GT_OQ>(v, _mm256_set1_ps(b));
        // true lanes are integer -1: subtracting accumulates the rank
        acc = _mm256_sub_epi32(acc, _mm256_castps_si256(m));
    }
    acc
}

/// `decode1` as an in-register table select over the 16-entry padded
/// level table (`lo` = levels[0..8], `hi` = levels[8..16]).
#[inline(always)]
unsafe fn lookup16(lo: __m256, hi: __m256, idx: __m256i) -> __m256 {
    let a = _mm256_permutevar8x32_ps(lo, idx); // uses idx mod 8
    let b = _mm256_permutevar8x32_ps(hi, idx);
    let use_hi = _mm256_cmpgt_epi32(idx, _mm256_set1_epi32(7));
    _mm256_blendv_ps(a, b, _mm256_castsi256_ps(use_hi))
}

/// Split a code dword register (one block/vector per lane, four packed
/// code bytes per dword) into four index registers.
#[inline(always)]
unsafe fn unpack_code_dwords(dw: __m256i) -> (__m256i, __m256i, __m256i, __m256i) {
    let m = _mm256_set1_epi32(0xFF);
    (
        _mm256_and_si256(dw, m),
        _mm256_and_si256(_mm256_srli_epi32::<8>(dw), m),
        _mm256_and_si256(_mm256_srli_epi32::<16>(dw), m),
        _mm256_srli_epi32::<24>(dw),
    )
}

/// Pack four code index registers back into one dword-per-lane register
/// (inverse of [`unpack_code_dwords`]; codes are < 16 so bytes never
/// collide).
#[inline(always)]
unsafe fn pack_code_dwords(c0: __m256i, c1: __m256i, c2: __m256i, c3: __m256i) -> __m256i {
    _mm256_or_si256(
        _mm256_or_si256(c0, _mm256_slli_epi32::<8>(c1)),
        _mm256_or_si256(_mm256_slli_epi32::<16>(c2), _mm256_slli_epi32::<24>(c3)),
    )
}

/// 8 AoS blocks (32 consecutive floats) -> SoA (W,X,Y,Z with lane k =
/// block k).
#[inline(always)]
unsafe fn transpose_load8(p: *const f32) -> Q8 {
    let r0 = _mm256_loadu_ps(p);
    let r1 = _mm256_loadu_ps(p.add(8));
    let r2 = _mm256_loadu_ps(p.add(16));
    let r3 = _mm256_loadu_ps(p.add(24));
    lane_transpose(
        _mm256_permute2f128_ps::<0x20>(r0, r2), // [b0 | b4]
        _mm256_permute2f128_ps::<0x31>(r0, r2), // [b1 | b5]
        _mm256_permute2f128_ps::<0x20>(r1, r3), // [b2 | b6]
        _mm256_permute2f128_ps::<0x31>(r1, r3), // [b3 | b7]
    )
}

/// Four registers holding one (w,x,y,z) quadruple in each 128-bit half
/// (`q0` = items 0 and 4, `q1` = 1 and 5, ...) -> SoA.
#[inline(always)]
unsafe fn lane_transpose(q0: __m256, q1: __m256, q2: __m256, q3: __m256) -> Q8 {
    let t0 = _mm256_unpacklo_ps(q0, q1); // [w0 w1 x0 x1 | w4 w5 x4 x5]
    let t1 = _mm256_unpacklo_ps(q2, q3); // [w2 w3 x2 x3 | w6 w7 x6 x7]
    let t2 = _mm256_unpackhi_ps(q0, q1); // [y0 y1 z0 z1 | y4 y5 z4 z5]
    let t3 = _mm256_unpackhi_ps(q2, q3);
    Q8 {
        w: _mm256_shuffle_ps::<0b01_00_01_00>(t0, t1),
        x: _mm256_shuffle_ps::<0b11_10_11_10>(t0, t1),
        y: _mm256_shuffle_ps::<0b01_00_01_00>(t2, t3),
        z: _mm256_shuffle_ps::<0b11_10_11_10>(t2, t3),
    }
}

/// SoA -> four registers with item k's (w,x,y,z) contiguous: returns
/// (p0, p1, p2, p3) where p0 holds items 0 (low half) and 4 (high),
/// p1 items 1/5, p2 items 2/6, p3 items 3/7.
#[inline(always)]
unsafe fn soa_to_quads(v: Q8) -> (__m256, __m256, __m256, __m256) {
    let t0 = _mm256_unpacklo_ps(v.w, v.x); // [w0 x0 w1 x1 | w4 x4 w5 x5]
    let t1 = _mm256_unpackhi_ps(v.w, v.x); // [w2 x2 w3 x3 | w6 x6 w7 x7]
    let t2 = _mm256_unpacklo_ps(v.y, v.z); // [y0 z0 y1 z1 | y4 z4 y5 z5]
    let t3 = _mm256_unpackhi_ps(v.y, v.z);
    (
        _mm256_shuffle_ps::<0b01_00_01_00>(t0, t2), // [it0 | it4]
        _mm256_shuffle_ps::<0b11_10_11_10>(t0, t2), // [it1 | it5]
        _mm256_shuffle_ps::<0b01_00_01_00>(t1, t3), // [it2 | it6]
        _mm256_shuffle_ps::<0b11_10_11_10>(t1, t3), // [it3 | it7]
    )
}

/// SoA -> 8 AoS blocks stored at 32 consecutive floats.
#[inline(always)]
unsafe fn transpose_store8(p: *mut f32, v: Q8) {
    let (p0, p1, p2, p3) = soa_to_quads(v);
    _mm256_storeu_ps(p, _mm256_permute2f128_ps::<0x20>(p0, p1)); // blocks 0,1
    _mm256_storeu_ps(p.add(8), _mm256_permute2f128_ps::<0x20>(p2, p3)); // 2,3
    _mm256_storeu_ps(p.add(16), _mm256_permute2f128_ps::<0x31>(p0, p1)); // 4,5
    _mm256_storeu_ps(p.add(24), _mm256_permute2f128_ps::<0x31>(p2, p3)); // 6,7
}

/// Broadcast quaternion `b` of the left bank, conjugated when `conj`.
#[inline(always)]
unsafe fn splat_quat(w: &[f32], x: &[f32], y: &[f32], z: &[f32], b: usize, conj: bool) -> Q8 {
    let s = if conj { -1.0f32 } else { 1.0 };
    Q8 {
        w: _mm256_set1_ps(w[b]),
        x: _mm256_set1_ps(s * x[b]),
        y: _mm256_set1_ps(s * y[b]),
        z: _mm256_set1_ps(s * z[b]),
    }
}

/// Load 8 consecutive blocks' quaternion components from the SoA bank.
#[inline(always)]
unsafe fn load_quats(w: &[f32], x: &[f32], y: &[f32], z: &[f32], b0: usize, conj: bool) -> Q8 {
    let q = Q8 {
        w: _mm256_loadu_ps(w.as_ptr().add(b0)),
        x: _mm256_loadu_ps(x.as_ptr().add(b0)),
        y: _mm256_loadu_ps(y.as_ptr().add(b0)),
        z: _mm256_loadu_ps(z.as_ptr().add(b0)),
    };
    if conj {
        Q8 {
            w: q.w,
            x: neg(q.x),
            y: neg(q.y),
            z: neg(q.z),
        }
    } else {
        q
    }
}

// ---------------------------------------------------------------------
// single-vector kernels (8 blocks per iteration)
// ---------------------------------------------------------------------

/// Fused rotate→quantize of the leading `8⌊(d/4)/8⌋` blocks of one
/// vector; returns codes written.  `use_right`: IsoFull (two-sided
/// sandwich) vs IsoFast (left-only).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn encode_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
    use_right: bool,
) -> usize {
    let full = d / 4;
    let nsimd = full - full % 8;
    if nsimd == 0 {
        return 0;
    }
    assert!(x.len() >= nsimd * 4);
    assert!(codes.len() >= nsimd * 4);
    assert!(soa.lw.len() >= nsimd);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = _mm256_set1_ps(pre);
    for b0 in (0..nsimd).step_by(8) {
        let v0 = transpose_load8(x.as_ptr().add(b0 * 4));
        let v = Q8 {
            w: mul(v0.w, prev),
            x: mul(v0.x, prev),
            y: mul(v0.y, prev),
            z: mul(v0.z, prev),
        };
        let l = load_quats(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b0, false);
        let mut y = hamilton8(l, v);
        if use_right {
            let r = load_quats(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b0, true);
            y = hamilton8(y, r);
        }
        let packed = pack_code_dwords(
            encode_cmp(y.w, bounds, nb),
            encode_cmp(y.x, bounds, nb),
            encode_cmp(y.y, bounds, nb),
            encode_cmp(y.z, bounds, nb),
        );
        _mm256_storeu_si256(codes.as_mut_ptr().add(b0 * 4) as *mut __m256i, packed);
    }
    nsimd * 4
}

/// Fused dequantize→unrotate of the leading `8⌊(d/4)/8⌋` blocks;
/// returns codes consumed.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
    use_right: bool,
) -> usize {
    let full = d / 4;
    let nsimd = full - full % 8;
    if nsimd == 0 {
        return 0;
    }
    assert!(codes.len() >= nsimd * 4);
    assert!(out.len() >= nsimd * 4);
    assert!(soa.lw.len() >= nsimd);
    let levels = q.levels_padded();
    let lo = _mm256_loadu_ps(levels.as_ptr());
    let hi = _mm256_loadu_ps(levels.as_ptr().add(8));
    let postv = _mm256_set1_ps(post);
    for b0 in (0..nsimd).step_by(8) {
        let dw = _mm256_loadu_si256(codes.as_ptr().add(b0 * 4) as *const __m256i);
        let (iw, ix, iy, iz) = unpack_code_dwords(dw);
        let yq = Q8 {
            w: lookup16(lo, hi, iw),
            x: lookup16(lo, hi, ix),
            y: lookup16(lo, hi, iy),
            z: lookup16(lo, hi, iz),
        };
        let lc = load_quats(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b0, true);
        let mut r = hamilton8(lc, yq);
        if use_right {
            let rp = load_quats(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b0, false);
            r = hamilton8(r, rp);
        }
        let o = Q8 {
            w: mul(r.w, postv),
            x: mul(r.x, postv),
            y: mul(r.y, postv),
            z: mul(r.z, postv),
        };
        transpose_store8(out.as_mut_ptr().add(b0 * 4), o);
    }
    nsimd * 4
}

/// dword-lane order fixup for the planar even/odd shuffle:
/// [0 1 4 5 2 3 6 7] (self-inverse).
#[inline(always)]
unsafe fn planar_fix() -> __m256i {
    _mm256_setr_epi32(0, 1, 4, 5, 2, 3, 6, 7)
}

/// Planar2D forward: the leading `8⌊(d/2)/8⌋` pairs.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn encode_planar(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
) -> usize {
    let full = d / 2;
    let nsimd = full - full % 8;
    if nsimd == 0 {
        return 0;
    }
    assert!(x.len() >= nsimd * 2);
    assert!(codes.len() >= nsimd * 2);
    assert!(soa.cs.len() >= nsimd);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = _mm256_set1_ps(pre);
    let fix = planar_fix();
    for p0 in (0..nsimd).step_by(8) {
        let r0 = _mm256_loadu_ps(x.as_ptr().add(p0 * 2));
        let r1 = _mm256_loadu_ps(x.as_ptr().add(p0 * 2 + 8));
        // deinterleave pairs: u0 = even elements, u1 = odd elements
        let e = _mm256_shuffle_ps::<0b10_00_10_00>(r0, r1);
        let o = _mm256_shuffle_ps::<0b11_01_11_01>(r0, r1);
        let u0 = mul(_mm256_permutevar8x32_ps(e, fix), prev);
        let u1 = mul(_mm256_permutevar8x32_ps(o, fix), prev);
        let c = _mm256_loadu_ps(soa.cs.as_ptr().add(p0));
        let s = _mm256_loadu_ps(soa.sn.as_ptr().add(p0));
        let y0 = sub(mul(c, u0), mul(s, u1)); // c*u0 - s*u1
        let y1 = add(mul(s, u0), mul(c, u1)); // s*u0 + c*u1
        let packed = _mm256_or_si256(
            encode_cmp(y0, bounds, nb),
            _mm256_slli_epi32::<8>(encode_cmp(y1, bounds, nb)),
        );
        let mut buf = [0i32; 8];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, packed);
        for (k, &pk) in buf.iter().enumerate() {
            codes[(p0 + k) * 2] = pk as u8;
            codes[(p0 + k) * 2 + 1] = (pk >> 8) as u8;
        }
    }
    nsimd * 2
}

/// Planar2D inverse: the leading `8⌊(d/2)/8⌋` pairs.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_planar(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
) -> usize {
    let full = d / 2;
    let nsimd = full - full % 8;
    if nsimd == 0 {
        return 0;
    }
    assert!(codes.len() >= nsimd * 2);
    assert!(out.len() >= nsimd * 2);
    assert!(soa.cs.len() >= nsimd);
    let levels = q.levels_padded();
    let lo = _mm256_loadu_ps(levels.as_ptr());
    let hi = _mm256_loadu_ps(levels.as_ptr().add(8));
    let postv = _mm256_set1_ps(post);
    let fix = planar_fix();
    for p0 in (0..nsimd).step_by(8) {
        // 8 pairs = 16 code bytes = 8 u16s; widen to one dword per pair
        let raw = _mm_loadu_si128(codes.as_ptr().add(p0 * 2) as *const __m128i);
        let v = _mm256_cvtepu16_epi32(raw);
        let i0 = _mm256_and_si256(v, _mm256_set1_epi32(0xFF));
        let i1 = _mm256_srli_epi32::<8>(v);
        let y0 = lookup16(lo, hi, i0);
        let y1 = lookup16(lo, hi, i1);
        let c = _mm256_loadu_ps(soa.cs.as_ptr().add(p0));
        let s = _mm256_loadu_ps(soa.sn.as_ptr().add(p0));
        let o0 = mul(add(mul(c, y0), mul(s, y1)), postv); // (c*y0 + s*y1) * post
        let o1 = mul(add(mul(neg(s), y0), mul(c, y1)), postv); // (-s*y0 + c*y1) * post
        // re-interleave and store
        let a = _mm256_permutevar8x32_ps(o0, fix);
        let b = _mm256_permutevar8x32_ps(o1, fix);
        _mm256_storeu_ps(out.as_mut_ptr().add(p0 * 2), _mm256_unpacklo_ps(a, b));
        _mm256_storeu_ps(out.as_mut_ptr().add(p0 * 2 + 8), _mm256_unpackhi_ps(a, b));
    }
    nsimd * 2
}

// ---------------------------------------------------------------------
// block-major tile kernels (8 vectors per tile)
// ---------------------------------------------------------------------

/// Tile decode: 8 vectors' unpacked code rows (row `v` at
/// `codes_tile[v * n_codes ..]`), per-vector `post` factors, output
/// rows at `out[v * d ..]`.  Covers all `d/4` full blocks; returns the
/// codes consumed per vector.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_tile_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [f32],
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(posts.len(), 8);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 8 * n_codes);
    assert!(out.len() >= 7 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let levels = q.levels_padded();
    let lo = _mm256_loadu_ps(levels.as_ptr());
    let hi = _mm256_loadu_ps(levels.as_ptr().add(8));
    let postv = _mm256_loadu_ps(posts.as_ptr());
    let nc = n_codes as i32;
    // byte offset of each vector's code row (gather scale 1)
    let rows = _mm256_setr_epi32(0, nc, 2 * nc, 3 * nc, 4 * nc, 5 * nc, 6 * nc, 7 * nc);
    let base = codes_tile.as_ptr() as *const i32;
    let outp = out.as_mut_ptr();
    for b in 0..full {
        // lane v = vector v's four packed code bytes for block b
        let vidx = _mm256_add_epi32(rows, _mm256_set1_epi32((4 * b) as i32));
        let dw = _mm256_i32gather_epi32::<1>(base, vidx);
        let (iw, ix, iy, iz) = unpack_code_dwords(dw);
        let yq = Q8 {
            w: lookup16(lo, hi, iw),
            x: lookup16(lo, hi, ix),
            y: lookup16(lo, hi, iy),
            z: lookup16(lo, hi, iz),
        };
        let lc = splat_quat(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b, true);
        let mut r = hamilton8(lc, yq);
        if use_right {
            let rp = splat_quat(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b, false);
            r = hamilton8(r, rp);
        }
        let o = Q8 {
            w: mul(r.w, postv),
            x: mul(r.x, postv),
            y: mul(r.y, postv),
            z: mul(r.z, postv),
        };
        // scatter each vector's reconstructed block to its output row
        let (p0, p1, p2, p3) = soa_to_quads(o);
        let col = 4 * b;
        _mm_storeu_ps(outp.add(col), _mm256_castps256_ps128(p0));
        _mm_storeu_ps(outp.add(d + col), _mm256_castps256_ps128(p1));
        _mm_storeu_ps(outp.add(2 * d + col), _mm256_castps256_ps128(p2));
        _mm_storeu_ps(outp.add(3 * d + col), _mm256_castps256_ps128(p3));
        _mm_storeu_ps(outp.add(4 * d + col), _mm256_extractf128_ps::<1>(p0));
        _mm_storeu_ps(outp.add(5 * d + col), _mm256_extractf128_ps::<1>(p1));
        _mm_storeu_ps(outp.add(6 * d + col), _mm256_extractf128_ps::<1>(p2));
        _mm_storeu_ps(outp.add(7 * d + col), _mm256_extractf128_ps::<1>(p3));
    }
    full * 4
}

/// [`decode_tile_iso`] with an in-register f16 store: identical math
/// (same registers, same op order) until the store transpose, where
/// each vector's reconstructed 4-float block converts via `vcvtps2ph`
/// with round-to-nearest-even — bit-identical to
/// `util::f16::f32_to_f16_bits` (including NaN quieting and
/// overflow-to-inf) — and stores as 8 bytes.
#[target_feature(enable = "avx2,f16c")]
pub(crate) unsafe fn decode_tile_iso_f16(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [u16],
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(posts.len(), 8);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 8 * n_codes);
    assert!(out.len() >= 7 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let levels = q.levels_padded();
    let lo = _mm256_loadu_ps(levels.as_ptr());
    let hi = _mm256_loadu_ps(levels.as_ptr().add(8));
    let postv = _mm256_loadu_ps(posts.as_ptr());
    let nc = n_codes as i32;
    let rows = _mm256_setr_epi32(0, nc, 2 * nc, 3 * nc, 4 * nc, 5 * nc, 6 * nc, 7 * nc);
    let base = codes_tile.as_ptr() as *const i32;
    let outp = out.as_mut_ptr();
    for b in 0..full {
        let vidx = _mm256_add_epi32(rows, _mm256_set1_epi32((4 * b) as i32));
        let dw = _mm256_i32gather_epi32::<1>(base, vidx);
        let (iw, ix, iy, iz) = unpack_code_dwords(dw);
        let yq = Q8 {
            w: lookup16(lo, hi, iw),
            x: lookup16(lo, hi, ix),
            y: lookup16(lo, hi, iy),
            z: lookup16(lo, hi, iz),
        };
        let lc = splat_quat(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b, true);
        let mut r = hamilton8(lc, yq);
        if use_right {
            let rp = splat_quat(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b, false);
            r = hamilton8(r, rp);
        }
        let o = Q8 {
            w: mul(r.w, postv),
            x: mul(r.x, postv),
            y: mul(r.y, postv),
            z: mul(r.z, postv),
        };
        // p_i holds vector i's block (low 128) and vector i+4's (high);
        // one cvtps2ph converts both, the halves store separately
        let (p0, p1, p2, p3) = soa_to_quads(o);
        let col = 4 * b;
        for (i, p) in [p0, p1, p2, p3].into_iter().enumerate() {
            let h = _mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(p);
            _mm_storel_epi64(outp.add(i * d + col) as *mut __m128i, h);
            _mm_storel_epi64(
                outp.add((i + 4) * d + col) as *mut __m128i,
                _mm_srli_si128::<8>(h),
            );
        }
    }
    full * 4
}

/// Tile encode: 8 vectors' rows at `x[v * d ..]` with per-vector `pre`
/// factors; code rows written to `codes_tile[v * n_codes ..]`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn encode_tile_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pres: &[f32],
    codes_tile: &mut [u8],
    n_codes: usize,
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(pres.len(), 8);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 8 * n_codes);
    assert!(x.len() >= 7 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = _mm256_loadu_ps(pres.as_ptr());
    let xp = x.as_ptr();
    for b in 0..full {
        let col = 4 * b;
        // gather each vector's block into lane v (pairs share a register)
        let q0 = _mm256_insertf128_ps::<1>(
            _mm256_castps128_ps256(_mm_loadu_ps(xp.add(col))),
            _mm_loadu_ps(xp.add(4 * d + col)),
        );
        let q1 = _mm256_insertf128_ps::<1>(
            _mm256_castps128_ps256(_mm_loadu_ps(xp.add(d + col))),
            _mm_loadu_ps(xp.add(5 * d + col)),
        );
        let q2 = _mm256_insertf128_ps::<1>(
            _mm256_castps128_ps256(_mm_loadu_ps(xp.add(2 * d + col))),
            _mm_loadu_ps(xp.add(6 * d + col)),
        );
        let q3 = _mm256_insertf128_ps::<1>(
            _mm256_castps128_ps256(_mm_loadu_ps(xp.add(3 * d + col))),
            _mm_loadu_ps(xp.add(7 * d + col)),
        );
        let v0 = lane_transpose(q0, q1, q2, q3);
        let v = Q8 {
            w: mul(v0.w, prev),
            x: mul(v0.x, prev),
            y: mul(v0.y, prev),
            z: mul(v0.z, prev),
        };
        let l = splat_quat(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b, false);
        let mut y = hamilton8(l, v);
        if use_right {
            let r = splat_quat(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b, true);
            y = hamilton8(y, r);
        }
        let packed = pack_code_dwords(
            encode_cmp(y.w, bounds, nb),
            encode_cmp(y.x, bounds, nb),
            encode_cmp(y.y, bounds, nb),
            encode_cmp(y.z, bounds, nb),
        );
        let mut buf = [0i32; 8];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, packed);
        for (v_i, &dword) in buf.iter().enumerate() {
            let off = v_i * n_codes + col;
            codes_tile[off..off + 4].copy_from_slice(&dword.to_le_bytes());
        }
    }
    full * 4
}

// ---------------------------------------------------------------------
// packed-code expansion (the SIMD unpack_into: 4-bit nibbles and 2-bit
// crumbs are radix expansions, vectorized as byte-shuffle interleaves)
// ---------------------------------------------------------------------

/// Expand the leading `n / 32 * 32` 4-bit codes of `data` into one code
/// byte each.  Per 16 input bytes: split into low/high nibbles and
/// interleave (`punpcklbw`/`punpckhbw`), which reproduces the scalar
/// order exactly (code 2i = byte i & 0xF, code 2i+1 = byte i >> 4).
/// Returns codes covered (a multiple of 32, so the scalar tail starts
/// byte-aligned).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn unpack4_prefix(data: &[u8], n: usize, out: &mut [u8]) -> usize {
    let chunks = n / 32;
    assert!(data.len() >= chunks * 16);
    assert!(out.len() >= chunks * 32);
    let mask = _mm_set1_epi8(0x0F);
    for c in 0..chunks {
        let src = _mm_loadu_si128(data.as_ptr().add(c * 16) as *const __m128i);
        let lo = _mm_and_si128(src, mask);
        // 16-bit shift leaks the neighbor byte's low bits into the
        // high nibble — masked right off
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(src), mask);
        let a = _mm_unpacklo_epi8(lo, hi);
        let b = _mm_unpackhi_epi8(lo, hi);
        _mm_storeu_si128(out.as_mut_ptr().add(c * 32) as *mut __m128i, a);
        _mm_storeu_si128(out.as_mut_ptr().add(c * 32 + 16) as *mut __m128i, b);
    }
    chunks * 32
}

/// Expand the leading `n / 64 * 64` 2-bit codes of `data`: the nibble
/// split above, applied twice (byte → nibbles → crumbs), keeps the
/// stream order at every stage.  Returns codes covered (a multiple of
/// 64).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn unpack2_prefix(data: &[u8], n: usize, out: &mut [u8]) -> usize {
    let chunks = n / 64;
    assert!(data.len() >= chunks * 16);
    assert!(out.len() >= chunks * 64);
    let m4 = _mm_set1_epi8(0x0F);
    let m2 = _mm_set1_epi8(0x03);
    for c in 0..chunks {
        let src = _mm_loadu_si128(data.as_ptr().add(c * 16) as *const __m128i);
        let nib_lo = _mm_and_si128(src, m4);
        let nib_hi = _mm_and_si128(_mm_srli_epi16::<4>(src), m4);
        // na covers input bytes 0..8 (codes 0..32), nb bytes 8..16
        let na = _mm_unpacklo_epi8(nib_lo, nib_hi);
        let nb = _mm_unpackhi_epi8(nib_lo, nib_hi);
        for (half, v) in [na, nb].into_iter().enumerate() {
            let cl = _mm_and_si128(v, m2);
            let ch = _mm_and_si128(_mm_srli_epi16::<2>(v), m2);
            let dst = out.as_mut_ptr().add(c * 64 + half * 32);
            _mm_storeu_si128(dst as *mut __m128i, _mm_unpacklo_epi8(cl, ch));
            _mm_storeu_si128(dst.add(16) as *mut __m128i, _mm_unpackhi_epi8(cl, ch));
        }
    }
    chunks * 64
}

// ---------------------------------------------------------------------
// Rotor3D baseline kernels (OddIntermediate only): 8 3-blocks per
// iteration in SoA lanes — the "3 blocks in 4 lanes" padding problem
// becomes a clean 3-register SoA shape once blocks go one-per-lane.
// ---------------------------------------------------------------------

/// Vertical `Rotor::apply` with the exact left-to-right association of
/// the scalar odd-intermediate sandwich (`math::rotor3::Rotor::apply`).
/// For `apply_inv`, pass the bivector components negated (`reverse()`
/// is an exact sign flip).
#[inline(always)]
unsafe fn rotor_apply8(
    s: __m256,
    b12: __m256,
    b13: __m256,
    b23: __m256,
    v1: __m256,
    v2: __m256,
    v3: __m256,
) -> (__m256, __m256, __m256) {
    let o1 = add(add(mul(s, v1), mul(b12, v2)), mul(b13, v3));
    let o2 = add(sub(mul(s, v2), mul(b12, v1)), mul(b23, v3));
    let o3 = sub(sub(mul(s, v3), mul(b13, v1)), mul(b23, v2));
    let o123 = add(sub(mul(b23, v1), mul(b13, v2)), mul(b12, v3));
    let r1 = add(add(add(mul(o1, s), mul(o2, b12)), mul(o3, b13)), mul(o123, b23));
    let r2 = add(sub(sub(mul(o2, s), mul(o1, b12)), mul(o123, b13)), mul(o3, b23));
    let r3 = sub(sub(add(mul(o3, s), mul(o123, b12)), mul(o1, b13)), mul(o2, b23));
    (r1, r2, r3)
}

/// Rotor3D rotate→quantize of the leading `8⌊(d/3)/8⌋` 3-blocks of one
/// vector; returns codes written.  The `d % 3` tail is always scalar
/// (it uses the separate k=2 tail quantizer).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn encode_rotor(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pre: f32,
    codes: &mut [u8],
) -> usize {
    let nfull = d / 3;
    let nsimd = nfull - nfull % 8;
    if nsimd == 0 {
        return 0;
    }
    assert!(x.len() >= nsimd * 3);
    assert!(codes.len() >= nsimd * 3);
    assert!(soa.rs.len() >= nsimd);
    let bounds = q.bounds_padded();
    let nb = q.n_levels() - 1;
    let prev = _mm256_set1_ps(pre);
    for b0 in (0..nsimd).step_by(8) {
        // stack-buffer deinterleave of 8 consecutive 3-blocks
        let mut v1b = [0.0f32; 8];
        let mut v2b = [0.0f32; 8];
        let mut v3b = [0.0f32; 8];
        for k in 0..8 {
            let p = (b0 + k) * 3;
            v1b[k] = x[p];
            v2b[k] = x[p + 1];
            v3b[k] = x[p + 2];
        }
        let v1 = mul(_mm256_loadu_ps(v1b.as_ptr()), prev);
        let v2 = mul(_mm256_loadu_ps(v2b.as_ptr()), prev);
        let v3 = mul(_mm256_loadu_ps(v3b.as_ptr()), prev);
        let s = _mm256_loadu_ps(soa.rs.as_ptr().add(b0));
        let b12 = _mm256_loadu_ps(soa.r12.as_ptr().add(b0));
        let b13 = _mm256_loadu_ps(soa.r13.as_ptr().add(b0));
        let b23 = _mm256_loadu_ps(soa.r23.as_ptr().add(b0));
        let (r1, r2, r3) = rotor_apply8(s, b12, b13, b23, v1, v2, v3);
        let mut c1 = [0i32; 8];
        let mut c2 = [0i32; 8];
        let mut c3 = [0i32; 8];
        _mm256_storeu_si256(c1.as_mut_ptr() as *mut __m256i, encode_cmp(r1, bounds, nb));
        _mm256_storeu_si256(c2.as_mut_ptr() as *mut __m256i, encode_cmp(r2, bounds, nb));
        _mm256_storeu_si256(c3.as_mut_ptr() as *mut __m256i, encode_cmp(r3, bounds, nb));
        for k in 0..8 {
            let p = (b0 + k) * 3;
            codes[p] = c1[k] as u8;
            codes[p + 1] = c2[k] as u8;
            codes[p + 2] = c3[k] as u8;
        }
    }
    nsimd * 3
}

/// Rotor3D dequantize→unrotate of the leading `8⌊(d/3)/8⌋` 3-blocks;
/// returns codes consumed.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_rotor(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes: &[u8],
    post: f32,
    out: &mut [f32],
) -> usize {
    let nfull = d / 3;
    let nsimd = nfull - nfull % 8;
    if nsimd == 0 {
        return 0;
    }
    assert!(codes.len() >= nsimd * 3);
    assert!(out.len() >= nsimd * 3);
    assert!(soa.rs.len() >= nsimd);
    let levels = q.levels_padded();
    let lo = _mm256_loadu_ps(levels.as_ptr());
    let hi = _mm256_loadu_ps(levels.as_ptr().add(8));
    let postv = _mm256_set1_ps(post);
    for b0 in (0..nsimd).step_by(8) {
        let mut i1 = [0i32; 8];
        let mut i2 = [0i32; 8];
        let mut i3 = [0i32; 8];
        for k in 0..8 {
            let p = (b0 + k) * 3;
            i1[k] = codes[p] as i32;
            i2[k] = codes[p + 1] as i32;
            i3[k] = codes[p + 2] as i32;
        }
        let y1 = lookup16(lo, hi, _mm256_loadu_si256(i1.as_ptr() as *const __m256i));
        let y2 = lookup16(lo, hi, _mm256_loadu_si256(i2.as_ptr() as *const __m256i));
        let y3 = lookup16(lo, hi, _mm256_loadu_si256(i3.as_ptr() as *const __m256i));
        // apply_inv = reverse().apply(): exact sign flip of the bivector
        let s = _mm256_loadu_ps(soa.rs.as_ptr().add(b0));
        let b12 = neg(_mm256_loadu_ps(soa.r12.as_ptr().add(b0)));
        let b13 = neg(_mm256_loadu_ps(soa.r13.as_ptr().add(b0)));
        let b23 = neg(_mm256_loadu_ps(soa.r23.as_ptr().add(b0)));
        let (r1, r2, r3) = rotor_apply8(s, b12, b13, b23, y1, y2, y3);
        let mut o1 = [0.0f32; 8];
        let mut o2 = [0.0f32; 8];
        let mut o3 = [0.0f32; 8];
        _mm256_storeu_ps(o1.as_mut_ptr(), mul(r1, postv));
        _mm256_storeu_ps(o2.as_mut_ptr(), mul(r2, postv));
        _mm256_storeu_ps(o3.as_mut_ptr(), mul(r3, postv));
        for k in 0..8 {
            let p = (b0 + k) * 3;
            out[p] = o1[k];
            out[p + 1] = o2[k];
            out[p + 2] = o3[k];
        }
    }
    nsimd * 3
}
