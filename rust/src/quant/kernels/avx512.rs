//! AVX-512 stage-1 kernels (x86_64) — the 16-lane generalization of the
//! AVX2 block-major tile kernels.
//!
//! Only the decode tile is implemented natively at 512-bit width: it is
//! the KV-gather hot loop, and 16 vectors per tile halves the number of
//! per-block iterations while the ≤16-entry level table now fits a
//! *single* `vpermps`-class register (`_mm512_permutexvar_ps` replaces
//! AVX2's two-register permute + blend).  Everything else — the
//! single-vector kernels, planar pairs, packed-code expansion, and the
//! encode tile (two 8-wide halves) — delegates to the AVX2 kernels:
//! [`super::KernelBackend::Avx512`] only resolves when *both* `avx512f`
//! and `avx2` were runtime-detected, so the delegation is always sound.
//!
//! The bit-exactness contract from the `kernels` module docs applies
//! unchanged: exact mul/add/sub (no FMA), the scalar operation order in
//! `hamilton16`, rank-count encode (delegated), table-select decode.
//! The f16 store variant converts in-register with `vcvtps2ph`
//! round-to-nearest-even, which is bit-identical to the software
//! `util::f16::f32_to_f16_bits` conversion (including NaN quieting and
//! overflow-to-inf), so the f16 gather output equals converting the f32
//! gather output elementwise.

#![allow(clippy::too_many_arguments)]

use std::arch::x86_64::*;

use super::{avx2, SoaBank};
use crate::quant::scalar::ScalarQuantizer;

#[inline(always)]
unsafe fn mul(a: __m512, b: __m512) -> __m512 {
    _mm512_mul_ps(a, b)
}

#[inline(always)]
unsafe fn add(a: __m512, b: __m512) -> __m512 {
    _mm512_add_ps(a, b)
}

#[inline(always)]
unsafe fn sub(a: __m512, b: __m512) -> __m512 {
    _mm512_sub_ps(a, b)
}

/// 16 independent quaternions, one per lane, in SoA registers.
#[derive(Clone, Copy)]
struct Q16 {
    w: __m512,
    x: __m512,
    y: __m512,
    z: __m512,
}

/// Vertical Hamilton product with the *exact* left-to-right operation
/// order of `math::quaternion::hamilton` (bit-exactness contract).
#[inline(always)]
unsafe fn hamilton16(a: Q16, b: Q16) -> Q16 {
    Q16 {
        w: sub(sub(sub(mul(a.w, b.w), mul(a.x, b.x)), mul(a.y, b.y)), mul(a.z, b.z)),
        x: sub(add(add(mul(a.w, b.x), mul(a.x, b.w)), mul(a.y, b.z)), mul(a.z, b.y)),
        y: add(add(sub(mul(a.w, b.y), mul(a.x, b.z)), mul(a.y, b.w)), mul(a.z, b.x)),
        z: add(sub(add(mul(a.w, b.z), mul(a.x, b.y)), mul(a.y, b.x)), mul(a.z, b.w)),
    }
}

/// `decode1` as a full-table in-register select: the 16-entry padded
/// level table lives in one `__m512`, and `vpermps` (zmm) indexes it
/// directly — no lo/hi split, no blend (codes are < 16).
#[inline(always)]
unsafe fn lookup16_full(table: __m512, idx: __m512i) -> __m512 {
    _mm512_permutexvar_ps(idx, table)
}

/// Split packed code dwords (one vector per lane, four packed code
/// bytes per dword) into four index registers.
#[inline(always)]
unsafe fn unpack_code_dwords16(dw: __m512i) -> (__m512i, __m512i, __m512i, __m512i) {
    let m = _mm512_set1_epi32(0xFF);
    (
        _mm512_and_si512(dw, m),
        _mm512_and_si512(_mm512_srli_epi32::<8>(dw), m),
        _mm512_and_si512(_mm512_srli_epi32::<16>(dw), m),
        _mm512_srli_epi32::<24>(dw),
    )
}

/// Broadcast quaternion `b`, conjugated when `conj`.
#[inline(always)]
unsafe fn splat_quat16(w: &[f32], x: &[f32], y: &[f32], z: &[f32], b: usize, conj: bool) -> Q16 {
    let s = if conj { -1.0f32 } else { 1.0 };
    Q16 {
        w: _mm512_set1_ps(w[b]),
        x: _mm512_set1_ps(s * x[b]),
        y: _mm512_set1_ps(s * y[b]),
        z: _mm512_set1_ps(s * z[b]),
    }
}

/// SoA -> four registers where 128-bit lane `j` of register `i` holds
/// vector `4j + i`'s contiguous (w,x,y,z) block — the 16-wide analogue
/// of the AVX2 `soa_to_quads` (unpack + shuffle act lane-wise on zmm,
/// so the 256-bit derivation applies per 128-bit lane).
#[inline(always)]
unsafe fn soa_to_quads16(v: Q16) -> (__m512, __m512, __m512, __m512) {
    let t0 = _mm512_unpacklo_ps(v.w, v.x); // lane j: [w4j x4j w4j+1 x4j+1]
    let t1 = _mm512_unpackhi_ps(v.w, v.x); // lane j: [w4j+2 x4j+2 w4j+3 x4j+3]
    let t2 = _mm512_unpacklo_ps(v.y, v.z);
    let t3 = _mm512_unpackhi_ps(v.y, v.z);
    (
        _mm512_shuffle_ps::<0b01_00_01_00>(t0, t2), // lane j: vector 4j
        _mm512_shuffle_ps::<0b11_10_11_10>(t0, t2), // lane j: vector 4j+1
        _mm512_shuffle_ps::<0b01_00_01_00>(t1, t3), // lane j: vector 4j+2
        _mm512_shuffle_ps::<0b11_10_11_10>(t1, t3), // lane j: vector 4j+3
    )
}

/// Tile decode: 16 vectors' unpacked code rows (row `v` at
/// `codes_tile[v * n_codes ..]`), per-vector `post` factors, output
/// rows at `out[v * d ..]`.  Covers all `d/4` full blocks; returns the
/// codes consumed per vector.
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn decode_tile_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [f32],
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(posts.len(), 16);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 16 * n_codes);
    assert!(out.len() >= 15 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let table = _mm512_loadu_ps(q.levels_padded().as_ptr());
    let postv = _mm512_loadu_ps(posts.as_ptr());
    let outp = out.as_mut_ptr();
    for b in 0..full {
        let col = 4 * b;
        let o = decode_block16(soa, table, postv, codes_tile, n_codes, col, use_right, b);
        let (q0, q1, q2, q3) = soa_to_quads16(o);
        // 128-bit lane j of q_i is vector (4j + i)'s reconstructed block
        _mm_storeu_ps(outp.add(col), _mm512_extractf32x4_ps::<0>(q0));
        _mm_storeu_ps(outp.add(d + col), _mm512_extractf32x4_ps::<0>(q1));
        _mm_storeu_ps(outp.add(2 * d + col), _mm512_extractf32x4_ps::<0>(q2));
        _mm_storeu_ps(outp.add(3 * d + col), _mm512_extractf32x4_ps::<0>(q3));
        _mm_storeu_ps(outp.add(4 * d + col), _mm512_extractf32x4_ps::<1>(q0));
        _mm_storeu_ps(outp.add(5 * d + col), _mm512_extractf32x4_ps::<1>(q1));
        _mm_storeu_ps(outp.add(6 * d + col), _mm512_extractf32x4_ps::<1>(q2));
        _mm_storeu_ps(outp.add(7 * d + col), _mm512_extractf32x4_ps::<1>(q3));
        _mm_storeu_ps(outp.add(8 * d + col), _mm512_extractf32x4_ps::<2>(q0));
        _mm_storeu_ps(outp.add(9 * d + col), _mm512_extractf32x4_ps::<2>(q1));
        _mm_storeu_ps(outp.add(10 * d + col), _mm512_extractf32x4_ps::<2>(q2));
        _mm_storeu_ps(outp.add(11 * d + col), _mm512_extractf32x4_ps::<2>(q3));
        _mm_storeu_ps(outp.add(12 * d + col), _mm512_extractf32x4_ps::<3>(q0));
        _mm_storeu_ps(outp.add(13 * d + col), _mm512_extractf32x4_ps::<3>(q1));
        _mm_storeu_ps(outp.add(14 * d + col), _mm512_extractf32x4_ps::<3>(q2));
        _mm_storeu_ps(outp.add(15 * d + col), _mm512_extractf32x4_ps::<3>(q3));
    }
    full * 4
}

/// [`decode_tile_iso`] with an in-register f16 store: each vector's
/// reconstructed 4-float block converts via `vcvtps2ph` (RNE — bit
/// identical to `util::f16::f32_to_f16_bits`) and stores as 8 bytes.
#[target_feature(enable = "avx512f,f16c")]
pub(super) unsafe fn decode_tile_iso_f16(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    codes_tile: &[u8],
    n_codes: usize,
    posts: &[f32],
    out: &mut [u16],
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(posts.len(), 16);
    assert!(n_codes >= full * 4);
    assert!(codes_tile.len() >= 16 * n_codes);
    assert!(out.len() >= 15 * d + full * 4);
    assert!(soa.lw.len() >= full);
    let table = _mm512_loadu_ps(q.levels_padded().as_ptr());
    let postv = _mm512_loadu_ps(posts.as_ptr());
    let outp = out.as_mut_ptr();
    for b in 0..full {
        let col = 4 * b;
        let o = decode_block16(soa, table, postv, codes_tile, n_codes, col, use_right, b);
        let (q0, q1, q2, q3) = soa_to_quads16(o);
        // convert each 128-bit lane (one vector's block) to 4×f16 and
        // store the low 64 bits of the conversion
        macro_rules! store_f16 {
            ($qi:expr, $lane:literal, $row:expr) => {
                _mm_storel_epi64(
                    outp.add($row * d + col) as *mut __m128i,
                    _mm_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(_mm512_extractf32x4_ps::<$lane>(
                        $qi,
                    )),
                );
            };
        }
        store_f16!(q0, 0, 0);
        store_f16!(q1, 0, 1);
        store_f16!(q2, 0, 2);
        store_f16!(q3, 0, 3);
        store_f16!(q0, 1, 4);
        store_f16!(q1, 1, 5);
        store_f16!(q2, 1, 6);
        store_f16!(q3, 1, 7);
        store_f16!(q0, 2, 8);
        store_f16!(q1, 2, 9);
        store_f16!(q2, 2, 10);
        store_f16!(q3, 2, 11);
        store_f16!(q0, 3, 12);
        store_f16!(q1, 3, 13);
        store_f16!(q2, 3, 14);
        store_f16!(q3, 3, 15);
    }
    full * 4
}

/// Shared decode body of one block across 16 vectors: gather the code
/// dwords, table-select the levels, run the inverse sandwich, and scale
/// by the per-vector post factors.
#[inline(always)]
unsafe fn decode_block16(
    soa: &SoaBank,
    table: __m512,
    postv: __m512,
    codes_tile: &[u8],
    n_codes: usize,
    col: usize,
    use_right: bool,
    b: usize,
) -> Q16 {
    // lane v = vector v's four packed code bytes for block b (scalar
    // stack-buffer gather: the rows are short and stride n_codes)
    let mut rows = [0i32; 16];
    for (v, r) in rows.iter_mut().enumerate() {
        let off = v * n_codes + col;
        *r = i32::from_le_bytes([
            codes_tile[off],
            codes_tile[off + 1],
            codes_tile[off + 2],
            codes_tile[off + 3],
        ]);
    }
    let dw = _mm512_loadu_epi32(rows.as_ptr());
    let (iw, ix, iy, iz) = unpack_code_dwords16(dw);
    let yq = Q16 {
        w: lookup16_full(table, iw),
        x: lookup16_full(table, ix),
        y: lookup16_full(table, iy),
        z: lookup16_full(table, iz),
    };
    let lc = splat_quat16(&soa.lw, &soa.lx, &soa.ly, &soa.lz, b, true);
    let mut r = hamilton16(lc, yq);
    if use_right {
        let rp = splat_quat16(&soa.rw, &soa.rx, &soa.ry, &soa.rz, b, false);
        r = hamilton16(r, rp);
    }
    Q16 {
        w: mul(r.w, postv),
        x: mul(r.x, postv),
        y: mul(r.y, postv),
        z: mul(r.z, postv),
    }
}

/// Tile encode at width 16: two 8-wide AVX2 tile encodes over the split
/// halves (encode is off the gather hot path; the 16-lane tile's win is
/// decode-side).  Sound because `Resolved::Avx512` implies the `avx2`
/// runtime probe also succeeded.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn encode_tile_iso(
    soa: &SoaBank,
    q: &ScalarQuantizer,
    d: usize,
    x: &[f32],
    pres: &[f32],
    codes_tile: &mut [u8],
    n_codes: usize,
    use_right: bool,
) -> usize {
    let full = d / 4;
    if full == 0 {
        return 0;
    }
    assert_eq!(pres.len(), 16);
    assert!(codes_tile.len() >= 16 * n_codes);
    assert!(x.len() >= 15 * d + full * 4);
    let (xa, xb) = x.split_at(8 * d);
    let (ca, cb) = codes_tile.split_at_mut(8 * n_codes);
    let a = avx2::encode_tile_iso(soa, q, d, xa, &pres[..8], ca, n_codes, use_right);
    let b = avx2::encode_tile_iso(soa, q, d, xb, &pres[8..], cb, n_codes, use_right);
    debug_assert_eq!(a, b);
    a
}
